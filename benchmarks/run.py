"""Benchmark harness — one function per paper table / figure.

Prints ``name,param,value,derived`` CSV rows.  ``--quick`` (default) shrinks
text sizes for CI; ``--full`` reproduces paper-scale measurements on a larger
machine.  Mapping to the paper:

  tab5            Tab. 5   — NFA/DFA/ME-DFA state counts for e(k)
  fig20           Fig. 20  — segment count vs RE size over random REs
  generation      Sect.5.2 — parser-generation time per benchmark RE
  parse_times     Fig. 15  — absolute parsing time (serial DFA / engine c=1/8)
  speedup         Fig.16/18— two-phase work model + measured phase ratio
  batched_throughput      — texts/sec of the bucketed batch front-end,
                            jnp vs pallas-interpret, batch 1/8/64
  streaming_append        — amortized cost per appended byte of the
                            StreamingParser prefix cache vs a cold full
                            re-parse per append (``--smoke`` = CI-tiny sizes)
  edit_splice             — mid-text splice cost of the product segment tree
                            vs a linear cold re-parse: ~log(n) growth gate +
                            ≥4× speedup at the largest prefix + bit-identity
                            at every size; writes BENCH_edit_splice.json
  sharded_throughput      — distributed runtime: 1-device vs all-host-device
                            mesh at fixed batch (+ one long chunk-sharded
                            text); run under
                            XLA_FLAGS=--xla_force_host_platform_device_count=8
  packed_throughput       — bit-packed uint32 backend vs jnp f32 at ℓ=257
                            states: bit-identity gate + SLPF-path bytes
                            moved (≥8× cut gate; packing gives 32×)
  speculation_throughput  — sparse feasible-start backend vs dense packed at
                            ℓ=257: bit-identity gate + strictly-fewer
                            product-path bytes on REs whose feasible width
                            < ℓp/2; writes BENCH_speculation.json
  multi_tenant_throughput — ParserFleet: T=32 mixed regexes served by ONE
                            tenant-batched device program vs a per-tenant
                            serial Parser loop: bit-identity gate + ≥4×
                            throughput + compile count O(#buckets);
                            writes BENCH_multi_tenant.json
  recognizer      Fig. 16r — recognition cost (reach+join only)
  memory          App. C   — SLPF bytes/char, packed and compressed
  engine_roofline §Roofline— per-cell terms (from the dry-run JSON)

All parse-RUNTIME access goes through the public facade (``repro.api``:
``Parser`` / ``ParserConfig`` — the supported surface, see ROADMAP "Public
API"); only the paper-faithful measurement ORACLES (``core/reference``,
``core/serial``, REgen) are still imported from their internal modules —
they are baselines, not the runtime.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_tab5(rows):
    from repro.api import Parser

    for k in range(1, 10):
        art = Parser(f"(a|b)*a(a|b){{{k}}}").artifacts
        rows.append(("tab5.segments", k, art.table.n, "count (2k+7; see EXPERIMENTS §Paper-validation)"))
        rows.append(("tab5.dfa_states", k, art.dfa.n_states, f"paper={2**(k+1)+1}"))
        rows.append(("tab5.medfa_states", k, art.medfa.n_states, "count"))
        rows.append(("tab5.medfa_entries", k, len(art.medfa.initial), "=segments (linear in k)"))


def bench_fig20(rows, quick):
    from benchmarks.benchmark_res import regen_suite
    from repro.core.numbering import number_regex
    from repro.core.segments import compute_segments
    from repro.core import regex as rx

    n = 40 if quick else 200
    suite = regen_suite(n, 5, 60, seed=7)
    sizes, segs = [], []
    for _, ast in suite:
        numbered = number_regex(ast)
        t = compute_segments(numbered)
        sizes.append(rx.node_size(ast))
        segs.append(t.n)
    sizes = np.array(sizes, float)
    segs = np.array(segs, float)
    slope = float((sizes * segs).sum() / (sizes * sizes).sum())
    corr = float(np.corrcoef(sizes, segs)[0, 1])
    rows.append(("fig20.n_res", 0, len(sizes), "count"))
    rows.append(("fig20.seg_per_size_slope", 0, round(slope, 3), "paper~3.2"))
    rows.append(("fig20.pearson", 0, round(corr, 3), "paper~0.52"))
    rows.append(("fig20.seg_range", 0, f"{int(segs.min())}-{int(segs.max())}", "paper 8-1435"))


def bench_generation(rows):
    from benchmarks.benchmark_res import BENCHMARKS
    from repro.api import Parser

    for name, pattern in BENCHMARKS.items():
        # full generation through the facade: matrices + all automata
        dt = _time(lambda: Parser(pattern).artifacts, reps=3)
        art = Parser(pattern).artifacts
        rows.append((f"generation.{name}", art.table.n, round(dt * 1e3, 2), "ms (paper 5-29ms)"))


def bench_parse_times(rows, quick):
    from benchmarks.benchmark_res import BENCHMARKS, make_text_exact
    from repro.api import Parser, ParserConfig
    from repro.core.serial import parse_serial_dfa

    # NOTE: engine times include the bucketed shape padding (parse rounds the
    # chunk length up to a power of two — up to ~2x cells near a bucket edge),
    # the compile-free steady-state cost a serving deployment actually pays.
    # Chunk policy is declarative: n_chunks=1 is PaREM's serial split,
    # n_chunks=8 the chunked one.
    n = 20_000 if quick else 2_000_000
    for name in BENCHMARKS:
        p1 = Parser(ParserConfig(regex=BENCHMARKS[name], n_chunks=1))
        p8 = Parser(ParserConfig(regex=BENCHMARKS[name], n_chunks=8))
        art = p1.artifacts
        text = make_text_exact(name, n, seed=1)
        t_dfa = _time(lambda: parse_serial_dfa(art.matrices, text, art.dfa, art.rdfa, art.nfa), reps=1)
        t_eng1 = _time(lambda: p1.parse(text), reps=2)
        t_eng8 = _time(lambda: p8.parse(text), reps=2)
        rows.append((f"parse.{name}.serial_dfa", len(text), round(t_dfa * 1e3, 1), "ms"))
        rows.append((f"parse.{name}.engine_c1", len(text), round(t_eng1 * 1e3, 1), "ms"))
        rows.append((f"parse.{name}.engine_c8", len(text), round(t_eng8 * 1e3, 1), "ms"))


def bench_speedup(rows, quick):
    """Paper Fig. 16/18.  Wall-clock multi-core speed-up is unobservable on
    this 1-core container; we measure the reach/build phase-work ratio of the
    paper-faithful reference and evaluate the paper's own two-stage model:
    speedup(c) ≈ c / (1 + w_reach/w_build) with both phases serialized —
    ≈ c/2 when the phases weigh the same (paper Sect. 5.2 'Discussion'); the
    transpose-backward variant (DESIGN §2) halves reach work → ceiling 2c/3."""
    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact
    from repro.core.reference import ParallelArtifacts, build_phase, reach_phase

    art = ParallelArtifacts.generate(BIGDATA_RE)
    text = make_text_exact("BIGDATA", 4_000 if quick else 100_000, seed=2)
    classes = art.matrices.classes_of_text(text)
    ell = art.table.n

    chunk = classes[:2000]
    t_reach = _time(lambda: reach_phase(art.medfa, chunk, ell), reps=2)
    t_build = _time(
        lambda: build_phase(art.dfa, art.nfa, frozenset(range(ell)), chunk, ell),
        reps=2,
    )
    w = t_reach / max(t_build, 1e-9)
    rows.append(("speedup.reach_over_build_work", len(chunk), round(w, 2), "measured phase ratio"))
    for c in (2, 4, 8, 16, 32, 64):
        paper = c / (1.0 + 1.0)            # reach ≈ build&merge (paper model)
        ours = c / (1.0 + w / 2.0)         # bwd reach free (DESIGN §2)
        rows.append((f"speedup.model.c{c}", c,
                     f"paper~{paper:.1f}x ours~{ours:.1f}",
                     "two-stage model"))


def bench_batched_throughput(rows, quick):
    """Batched serving throughput (texts/sec) of the shape-bucketed front-end.

    Measures ``ParserEngine.parse_batch`` at batch 1 / 8 / 64 on both phase
    backends — ``jnp`` (pure-XLA device program) and ``pallas`` (the Mosaic
    kernels; interpret mode on CPU, so its numbers here gauge correctness
    cost only, not TPU speed).  ``compiles`` in the derived column is the
    engine's cumulative program count: it grows only when a new
    (chunk-bucket, batch-slot) shape first appears — roughly one per batch
    size plus one per length bucket the jittered lengths straddle — and the
    timed repeat calls add none (no per-length or per-call re-jit).
    """
    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact
    from repro.api import Parser, ParserConfig

    import jax

    # keep targets clear of the pow2 bucket edge: make_text_exact may overshoot
    # by a few records, which at n=2^m would spill one text into the next
    # (double-width) bucket and pollute the timed batch with a straggler.
    n = 240 if quick else 16_000
    for backend in ("jnp", "pallas"):
        if backend == "pallas" and not quick and jax.default_backend() != "tpu":
            # full-size interpret-mode grids (k≈4096) take hours on CPU and
            # measure nothing the quick run doesn't already cover.
            rows.append(("batched.pallas.skipped", 0, 0,
                         "full pallas bench needs a TPU (interpret too slow)"))
            continue
        parser = Parser(ParserConfig(
            regex=BIGDATA_RE, backend=backend, n_chunks=4, max_batch=64
        ))
        for batch in (1, 8, 64):
            texts = [
                make_text_exact("BIGDATA", n - (i % 7), seed=i) for i in range(batch)
            ]
            parser.parse_batch(texts)                   # warm the program cache
            dt = _time(lambda: parser.parse_batch(texts), reps=2)
            rows.append((
                f"batched.{backend}.b{batch}", batch,
                round(batch / max(dt, 1e-9), 1),
                f"texts/s n~{n} compiles={parser.compile_count}",
            ))


def bench_streaming_append(rows, quick, smoke=False):
    """Streaming append cost (core/stream.py) vs cold full re-parse.

    Streams a text in fixed-size appends and reports, at geometric prefix
    checkpoints, the per-byte append cost inside that window — flat across
    checkpoints ⇒ the amortized incremental work is sublinear in prefix
    length (the prefix cache only re-reaches the appended piece + an
    O(log n) join) — against the cost a naive server pays to re-parse the
    whole prefix on every append.  A warm pass runs first so the numbers
    exclude one-time bucket compiles (``compiles`` column shows the total).
    """
    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact
    from repro.api import Parser, ParserConfig

    parser = Parser(ParserConfig(regex=BIGDATA_RE))
    n_target = 1_500 if smoke else (12_000 if quick else 400_000)
    step = 50 if smoke else (100 if quick else 1_000)
    text = make_text_exact("BIGDATA", n_target, seed=5)
    n = len(text)
    checkpoints = sorted({n // 4, n // 2, n})

    def stream_pass():
        stream = parser.open_stream()
        total, done, nxt, marks = 0.0, 0, 0, []
        for lo in range(0, n, step):
            piece = text[lo : lo + step]
            t0 = time.perf_counter()
            stream.append(piece)
            stream.accepted              # drain THIS session + O(1) join query
            total += time.perf_counter() - t0
            done += len(piece)
            while nxt < len(checkpoints) and done >= checkpoints[nxt]:
                marks.append((done, total))
                nxt += 1
        return stream, marks

    warm, _ = stream_pass()              # warm: traces every bucketed shape
    warm.close()
    stream, marks = stream_pass()

    prev_n, prev_t = 0, 0.0
    for cp_n, cp_t in marks:
        win_bytes = max(cp_n - prev_n, 1)
        win_per_byte = (cp_t - prev_t) / win_bytes
        rows.append((f"streaming.append_us_per_byte.n{cp_n}", cp_n,
                     round(win_per_byte * 1e6, 3),
                     "flat across checkpoints => sublinear in prefix"))
        prefix = text[:cp_n]
        parser.parse(prefix)             # warm this parse bucket (same engine)
        t_cold = _time(lambda: parser.parse(prefix), reps=2)
        per_append = (cp_t - prev_t) / max(win_bytes / step, 1)
        rows.append((f"streaming.reparse_speedup.n{cp_n}", cp_n,
                     round(t_cold / max(per_append, 1e-9), 1),
                     f"cold reparse {t_cold*1e3:.1f}ms vs "
                     f"{per_append*1e6:.0f}us/append"))
        prev_n, prev_t = cp_n, cp_t
    rows.append(("streaming.amortized_us_per_byte", n,
                 round(marks[-1][1] / n * 1e6, 3),
                 f"{step}B appends; compiles={parser.compile_count}; "
                 f"{stream.n_sealed_chunks} sealed chunks"))
    ok = np.array_equal(
        stream.result().forest.pack(), parser.parse(text).forest.pack()
    )
    stream.close()
    rows.append(("streaming.bit_identical", n, int(ok),
                 "stream SLPF == cold parse (must be 1)"))
    if not ok:
        raise SystemExit(
            "streaming_append: stream SLPF diverged from cold parse"
        )  # make the CI smoke invocation a real gate, not a printout


def bench_edit_splice(rows, quick, smoke=False):
    """Mid-text splice cost (the product segment tree) vs linear re-parse.

    For geometrically growing prefix sizes n, times a fixed-width
    ``ParserStream.edit`` (splice + acceptance query) at spread positions
    and the cold re-parse an editor without the tree would pay.  Two gates:
    the edit cost must grow ~log(n) — far below the x(n_hi/n_lo) a linear
    re-join would show — and at the largest prefix the splice must beat the
    cold re-parse >= 4x.  Same-bytes replacements keep the text constant, so
    the edited stream's SLPF is byte-compared against the cold parse at
    every size (a real gate, not a printout).
    """
    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact
    from repro.api import Parser, ParserConfig

    parser = Parser(ParserConfig(
        regex=BIGDATA_RE, first_seal_len=32, max_seal_len=64
    ))
    sizes = [512, 2048, 8192] if quick else [2048, 8192, 32768]
    span, reps = 8, 12
    edit_t, speedup, t_cold = {}, {}, {}
    for n in sizes:
        text = make_text_exact("BIGDATA", n, seed=9)
        stream = parser.open_stream()
        stream.append(text)
        stream.accepted                     # drain + warm the query path
        for i in (0, 1):                    # warm the splice piece buckets
            stream.edit(i, i + span, text[i : i + span])
        ts = []
        for i in range(reps):
            lo = (i * 2654435761) % (n - span)   # deterministic spread
            repl = text[lo : lo + span]          # same bytes: text invariant
            t0 = time.perf_counter()
            stream.edit(lo, lo + span, repl)
            stream.accepted
            ts.append(time.perf_counter() - t0)
        edit_t[n] = sorted(ts)[len(ts) // 2]     # median: compile-spike-proof
        parser.parse(text)                       # warm the cold bucket
        t_cold[n] = _time(lambda: parser.parse(text), reps=2)
        speedup[n] = t_cold[n] / max(edit_t[n], 1e-9)
        rows.append((f"edit.us_per_edit.n{n}", n, round(edit_t[n] * 1e6, 1),
                     f"{span}-char splice + acceptance query"))
        rows.append((f"edit.reparse_speedup.n{n}", n, round(speedup[n], 1),
                     f"cold reparse {t_cold[n]*1e3:.2f}ms vs "
                     f"{edit_t[n]*1e6:.0f}us/edit"))
        ok = np.array_equal(
            stream.result().forest.pack(), parser.parse(text).forest.pack()
        )
        stream.close()
        rows.append((f"edit.bit_identical.n{n}", n, int(ok),
                     "edited stream SLPF == cold parse (must be 1)"))
        if not ok:
            raise SystemExit(
                "edit_splice: edited stream SLPF diverged from cold parse"
            )
    n_lo, n_hi = sizes[0], sizes[-1]
    growth = edit_t[n_hi] / max(edit_t[n_lo], 1e-9)
    linear = n_hi / n_lo
    rows.append(("edit.cost_growth", n_hi, round(growth, 2),
                 f"splice cost x{growth:.1f} over a x{linear:.0f} prefix "
                 f"(log-like; linear would be ~x{linear:.0f}, "
                 f"gate <= x{linear / 2:.0f})"))
    rows.append(("edit.edit_throughput", n_hi,
                 round(1.0 / max(edit_t[n_hi], 1e-9), 1),
                 f"edits/s at n={n_hi} ({span}-char splice + acceptance)"))
    if growth > linear / 2:
        raise SystemExit(
            f"edit_splice: splice cost grew x{growth:.1f} over a "
            f"x{linear:.0f} prefix — not O(log n) "
            f"(gate <= x{linear / 2:.0f})"
        )
    if speedup[n_hi] < 4.0:
        raise SystemExit(
            f"edit_splice: splice only {speedup[n_hi]:.1f}x faster than cold "
            f"re-parse at n={n_hi} (gate >= 4x)"
        )


def bench_sharded_throughput(rows, quick, smoke=False):
    """Distributed parse runtime: 1-device vs multi-device mesh, fixed batch.

    Measures ``parse_batch`` (batch over 'data' × chunks over 'pod',
    ``core/distributed.py``) and the single-long-text chunk-sharded route on
    a plain engine vs a ``ParserEngine(mesh=...)`` over every host device.
    Needs >1 device — CI runs it under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, where the
    "devices" share the same CPU cores: the numbers gauge partitioning
    overhead, not speedup (real scaling needs a TPU pod slice).  ``--smoke``
    additionally gates on bit-identity vs the single-device engine.
    """
    import jax
    import numpy as np

    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact
    from repro.api import Parser, ParserConfig

    n_dev = len(jax.devices())
    if n_dev < 2:
        rows.append(("sharded.skipped", n_dev, 0,
                     "needs XLA_FLAGS=--xla_force_host_platform_device_count=8"))
        return
    n = 200 if smoke else (2_000 if quick else 64_000)
    batch = 8
    texts = [make_text_exact("BIGDATA", n - (i % 5), seed=i) for i in range(batch)]
    long_text = make_text_exact("BIGDATA", 4 * n, seed=99)

    # distribution is declarative on the facade: mesh=None vs mesh="host"
    cfg = ParserConfig(regex=BIGDATA_RE, n_chunks=8, max_batch=batch)
    p1 = Parser(cfg)
    pM = Parser(cfg.replace(mesh="host"))
    mesh = pM.engine.mesh

    base = p1.parse_batch(texts)                      # warm + reference
    got = pM.parse_batch(texts)
    ok = all(
        np.array_equal(g.forest.pack(), b.forest.pack())
        for g, b in zip(got, base)
    )
    ok = ok and np.array_equal(
        pM.parse(long_text).forest.pack(), p1.parse(long_text).forest.pack()
    )
    rows.append(("sharded.bit_identical", n_dev, int(ok),
                 "mesh == 1-device SLPF (must be 1)"))
    if not ok:
        raise SystemExit("sharded_throughput: mesh parse diverged from 1-device")

    dt1 = _time(lambda: p1.parse_batch(texts), reps=2)
    dtM = _time(lambda: pM.parse_batch(texts), reps=2)
    rows.append((f"sharded.batch.1dev.b{batch}", 1,
                 round(batch / max(dt1, 1e-9), 1), f"texts/s n~{n}"))
    rows.append((f"sharded.batch.mesh{n_dev}dev.b{batch}", n_dev,
                 round(batch / max(dtM, 1e-9), 1),
                 f"texts/s ratio={dt1 / max(dtM, 1e-9):.2f}x "
                 f"mesh={dict(mesh.shape)}"))
    dl1 = _time(lambda: p1.parse(long_text), reps=2)
    dlM = _time(lambda: pM.parse(long_text), reps=2)
    rows.append((f"sharded.long.1dev", len(long_text),
                 round(dl1 * 1e3, 1), "ms single long text"))
    rows.append((f"sharded.long.mesh{n_dev}dev", len(long_text),
                 round(dlM * 1e3, 1),
                 f"ms chunk-sharded ratio={dl1 / max(dlM, 1e-9):.2f}x"))


def bench_packed_throughput(rows, quick, smoke=False):
    """Bit-packed uint32 backend vs jnp f32 at production automaton scale.

    Uses the e(k) family (2k+7 segments; Tab. 5) at k=125 → ℓ = 257 ≥ 256
    states, built WITHOUT the exponential DFA (segments + matrices only), and

      * gates on bit-identity packed vs jnp on a random a/b text (always —
        the CI smoke invocation is a real gate);
      * reports SLPF-path bytes moved — the chunk-product boundary that is
        the reach output, the join input, the streaming cache entry AND the
        distributed all-gather payload — for both layouts, gating on the
        acceptance bar (≥ 8× reduction at ℓ ≥ 256; the uint32 packing gives
        exactly 32×), measured off the real device arrays, plus the packed
        vs f32 transition-table traffic of one reach step;
      * times parse on both backends (CPU wall-clock favors the f32 path's
        BLAS matmuls — the bytes rows are the TPU-relevant signal; VPU
        word-op throughput needs the real-TPU ROADMAP item).
    """
    import jax.numpy as jnp

    from repro.api import Parser, ParserConfig
    from repro.core.segments import compute_segments

    # e(k) at k=125 has an exponential DFA — build from segments only
    # (``from_matrices``: the facade path for pre-generated tables)
    table = compute_segments("(a|b)*a(a|b){125}")
    ell = table.n
    p_j = Parser.from_matrices(table, ParserConfig(regex="<e125>", n_chunks=8))
    p_p = Parser.from_matrices(
        p_j.matrices, ParserConfig(regex="<e125>", backend="packed", n_chunks=8)
    )
    n = 300 if smoke else (2_000 if quick else 50_000)
    rng = np.random.default_rng(0)
    text = bytes(rng.choice([97, 98], size=n))

    base = p_j.parse(text)
    got = p_p.parse(text)
    ok = np.array_equal(base.forest.pack(), got.forest.pack())
    rows.append(("packed.bit_identical", ell, int(ok),
                 "packed == jnp SLPF (must be 1)"))
    if not ok:
        raise SystemExit("packed_throughput: packed backend diverged from jnp")

    # SLPF-path bytes: stacked chunk products from each backend's real reach
    eng_j, eng_p = p_j.engine, p_p.engine
    classes = eng_j.classes_of_text(text)
    c, k = eng_j.bucket_shape(len(classes), 8)
    chunks = jnp.asarray(eng_j._pad_to(classes, c, k))
    P_f32 = eng_j.phases.reach(eng_j.tables.N, chunks)
    P_pck = eng_p.phases.reach(eng_p.tables.N, chunks)
    b_f32 = int(P_f32.size) * P_f32.dtype.itemsize
    b_pck = int(P_pck.size) * P_pck.dtype.itemsize
    ratio = b_f32 / b_pck
    rows.append(("packed.product_stack_bytes.f32", ell, b_f32,
                 f"(c={c}) reach→join boundary / all-gather payload"))
    rows.append(("packed.product_stack_bytes.packed", ell, b_pck,
                 f"{ratio:.0f}x fewer bytes moved (gate ≥8x at ℓ≥256)"))
    if ell >= 256 and ratio < 8.0:
        raise SystemExit(
            f"packed_throughput: bytes reduction {ratio:.1f}x < 8x at ℓ={ell}"
        )
    # per-step transition-row traffic of the reach loop (N[class] per char)
    lp = eng_j.tables.ell_pad
    rows.append(("packed.reach_step_bytes", ell,
                 f"{lp * lp * 4}->{lp * (lp // 32) * 4}",
                 "f32 vs packed N-row bytes per reach char"))

    for name, p in (("jnp", p_j), ("packed", p_p)):
        p.parse(text)                          # warm the bucket program
        dt = _time(lambda: p.parse(text), reps=2)
        rows.append((f"packed.parse_ms.{name}", n, round(dt * 1e3, 1),
                     f"ms n={n} compiles={p.compile_count}"))


def bench_speculation_throughput(rows, quick, smoke=False):
    """Speculation-width reduction: sparse feasible-start backend at ℓ=257.

    Two benchmark REs at exactly ℓ = 257 segments (ℓp = 288, W = 9):

      e125    ``(a|b)*a(a|b){125}``  — a 2-letter automaton whose classes
              admit ~ℓ/2 start states (width 129 < ℓp/2 = 144: a qualifying
              but near-worst case for the reduction);
      cyc25   a 25-letter cyclic literal tuned to ℓ = 257 — each class
              admits ~ℓ/25 states (width 12), the PaREM regime where
              boundary information prunes speculation hard.

    Gates (the CI smoke invocation runs all of them):
      * sparse SLPF bit-identical to the jnp oracle on both REs;
      * product-path bytes moved (reach output = join input = streaming
        cache entry = all-gather payload) STRICTLY below the dense packed
        backend at ℓ=257 on every RE whose measured feasible width < ℓp/2
        — the acceptance bar; both REs qualify.

    Also reports measured speculation width (mean/max vs ℓp) and parse
    wall-clock per backend (CPU numbers gauge overhead only; the bytes rows
    are the TPU-relevant signal).  Returns the structured measurement set;
    ``main()`` writes it under ``metrics["report"]`` of the schema-shared
    ``BENCH_speculation.json`` at the repo root — the perf trajectory entry
    ROADMAP asks for, now validated by ``repro.obs.export``.
    """
    import string

    import jax.numpy as jnp

    from repro.api import Parser, ParserConfig
    from repro.core.matrices import feasible_start_widths
    from repro.core.segments import compute_segments

    unit25 = string.ascii_lowercase[:25] * 10 + "abcd"   # tuned: ℓ = 257
    cases = {
        "e125": ("(a|b)*a(a|b){125}",
                 lambda rng, n: bytes(rng.choice([97, 98], size=n))),
        "cyc25": (f"({unit25})*",
                  lambda rng, n: (unit25.encode()
                                  * (n // len(unit25) + 1))[: n - n % len(unit25)]),
    }
    n = 300 if smoke else (2_000 if quick else 50_000)
    report = {"ell_target": 257, "n_chars": n, "cases": {}}

    for cname, (pattern, make_text) in cases.items():
        table = compute_segments(pattern)
        ell = table.n
        p_j = Parser.from_matrices(
            table, ParserConfig(regex=f"<{cname}>", n_chunks=8)
        )
        p_p = Parser.from_matrices(
            p_j.matrices,
            ParserConfig(regex=f"<{cname}>", backend="packed", n_chunks=8),
        )
        p_s = Parser.from_matrices(
            p_j.matrices,
            ParserConfig(regex=f"<{cname}>", backend="sparse", n_chunks=8),
        )
        rng = np.random.default_rng(0)
        text = make_text(rng, n)

        base = p_j.parse(text)
        got = p_s.parse(text)
        ok = np.array_equal(base.forest.pack(), got.forest.pack())
        rows.append((f"speculation.{cname}.bit_identical", ell, int(ok),
                     "sparse == jnp SLPF (must be 1)"))
        if not ok:
            raise SystemExit(
                f"speculation_throughput: sparse diverged from jnp on {cname}"
            )

        # product-path bytes: stacked chunk products off each backend's reach
        eng_p, eng_s = p_p.engine, p_s.engine
        classes = eng_p.classes_of_text(text)
        c, k = eng_p.bucket_shape(len(classes), 8)
        chunks = jnp.asarray(eng_p._pad_to(classes, c, k))
        P_pck = eng_p.phases.reach(eng_p.tables.N, chunks)
        P_sp = eng_s.phases.reach(eng_s.tables.N, chunks)
        b_pck = int(P_pck.size) * P_pck.dtype.itemsize
        b_sp = int(P_sp.size) * P_sp.dtype.itemsize
        lp = int(eng_s.tables.ell_pad)
        S = int(eng_s.backend._width)
        widths = feasible_start_widths(eng_s.tables.N, np.asarray(chunks))
        real = widths[widths >= 0]
        w_mean = float(real.mean()) if real.size else 0.0
        w_max = int(real.max()) if real.size else 0
        rows.append((f"speculation.{cname}.width", ell,
                     f"mean={w_mean:.1f} max={w_max}",
                     f"feasible-start states vs ℓp={lp} (rows carried S={S})"))
        rows.append((f"speculation.{cname}.product_stack_bytes.packed", ell,
                     b_pck, f"(c={c}) dense packed product path"))
        rows.append((f"speculation.{cname}.product_stack_bytes.sparse", ell,
                     b_sp,
                     f"{b_pck / b_sp:.2f}x fewer bytes (gate: strict < at "
                     f"ℓ=257 when width < ℓp/2)"))
        if w_max < lp // 2 and b_sp >= b_pck:
            raise SystemExit(
                f"speculation_throughput: sparse bytes {b_sp} not strictly "
                f"below packed {b_pck} on {cname} (width {w_max} < ℓp/2)"
            )

        timings = {}
        for bname, p in (("packed", p_p), ("sparse", p_s)):
            p.parse(text)                      # warm the bucket program
            dt = _time(lambda: p.parse(text), reps=2)
            timings[bname] = dt
            rows.append((f"speculation.{cname}.parse_ms.{bname}", n,
                         round(dt * 1e3, 1),
                         f"ms n={n} compiles={p.compile_count}"))

        report["cases"][cname] = {
            "pattern": pattern,
            "ell": ell,
            "ell_pad": lp,
            "product_rows": S,
            "bit_identical": bool(ok),
            "speculation_width": {"mean": w_mean, "max": w_max,
                                  "n_chunks_real": int(real.size)},
            "bytes_moved": {
                "packed": b_pck,
                "sparse": b_sp,
                "ratio_packed_over_sparse": b_pck / b_sp,
                "n_stacked_chunks": int(c),
            },
            "throughput": {
                bname: {"parse_s": dt, "chars_per_s": n / max(dt, 1e-9)}
                for bname, dt in timings.items()
            },
        }

    rows.append(("speculation.json", 0, "BENCH_speculation.json",
                 "machine-readable perf trajectory entry"))
    return report


def bench_multi_tenant_throughput(rows, quick, smoke=False):
    """Multi-tenant fleet: tenant-batched device programs vs per-tenant loop.

    T=32 tenants over 8 distinct patterns of the e(k) family (ℓ = 2k+7 for
    k = 1..8 — mixed true ℓ, one shared (Ab, ℓp) automaton bucket), each
    with its own text.  Two routes, both warm:

      serial   one solo ``Parser`` per tenant, 32 separate device dispatches
               per sweep — the pre-fleet deployment model;
      fleet    ``ParserFleet.parse_batch`` — ONE tenant-batched device
               program serves all 32 (tenant axis vmapped like the batch
               axis; ``core/fleet.py``).

    Gates (the CI smoke invocation runs all of them):
      * every fleet result bit-identical to its tenant's solo oracle;
      * fleet throughput ≥ 4× the serial loop at T=32 (CPU/interpret);
      * fleet compile count O(#buckets): ≤ 2 programs per automaton bucket
        (NOT per tenant), and table-cache misses = #distinct patterns.

    Returns the structured report written under ``metrics["report"]`` of
    ``BENCH_multi_tenant.json`` — the perf-trajectory entry
    ``scripts/bench_trend.py`` tracks.
    """
    from repro.api import Parser, ParserConfig, ParserFleet
    from repro.core.fleet import clear_table_cache

    T = 32
    # short texts are the regime this feature exists for (thousands of
    # small per-tenant requests, per-dispatch overhead dominant); modes
    # scale timing repetitions, not text length
    n = 16
    reps = 3 if quick else 5   # best-of; smoke keeps 3 (timing noise guard)
    patterns = [f"(a|b)*a(a|b){{{k}}}" for k in range(1, 9)]
    configs = {
        f"t{i:02d}": ParserConfig(regex=patterns[i % len(patterns)], n_chunks=2)
        for i in range(T)
    }
    rng = np.random.default_rng(42)
    texts = {
        tid: bytes(rng.choice([97, 98], size=n - (i % 5)).astype(np.uint8))
        for i, tid in enumerate(configs)
    }
    items = [(tid, texts[tid]) for tid in configs]

    clear_table_cache()                        # deterministic cache counters
    fleet = ParserFleet(configs, max_batch=T)
    solos = {tid: Parser(cfg) for tid, cfg in configs.items()}

    # bit-identity gate: the tenant-batched route vs each tenant's oracle
    got = fleet.parse_batch(items)             # also warms the fleet program
    oracle = {tid: solos[tid].parse(texts[tid]) for tid in configs}  # + warms
    ok = all(
        np.array_equal(r.forest.pack(), oracle[tid].forest.pack())
        for (tid, _), r in zip(items, got)
    )
    rows.append(("multi_tenant.bit_identical", T, int(ok),
                 "fleet == per-tenant solo SLPF (must be 1)"))
    if not ok:
        raise SystemExit(
            "multi_tenant_throughput: fleet diverged from per-tenant oracles"
        )

    def serial_sweep():
        for tid in configs:
            solos[tid].parse(texts[tid])

    dt_serial = _time(serial_sweep, reps=reps)
    dt_fleet = _time(lambda: fleet.parse_batch(items), reps=reps)
    thr_serial = T / max(dt_serial, 1e-9)
    thr_fleet = T / max(dt_fleet, 1e-9)
    speedup = dt_serial / max(dt_fleet, 1e-9)
    rows.append(("multi_tenant.serial_throughput", T,
                 round(thr_serial, 1), f"texts/s n~{n} (32 dispatches/sweep)"))
    rows.append(("multi_tenant.fleet_throughput", T,
                 round(thr_fleet, 1),
                 f"texts/s n~{n} (tenant-batched, 1 dispatch/sweep)"))
    rows.append(("multi_tenant.speedup", T, round(speedup, 2),
                 "fleet vs per-tenant serial loop (gate ≥4x at T=32)"))
    if speedup < 4.0:
        raise SystemExit(
            f"multi_tenant_throughput: fleet speedup {speedup:.2f}x < 4x "
            f"at T={T}"
        )

    # compile economy gates: programs per BUCKET, table builds per PATTERN
    n_buckets = fleet.engine.n_buckets
    compiles = fleet.compile_count
    rows.append(("multi_tenant.buckets", T, n_buckets,
                 f"automaton buckets for {T} tenants"))
    rows.append(("multi_tenant.compile_count", T, compiles,
                 "device programs (gate: ≤ 2 per bucket, not per tenant)"))
    if compiles > 2 * n_buckets:
        raise SystemExit(
            f"multi_tenant_throughput: {compiles} compiled programs for "
            f"{n_buckets} buckets — compile count is not O(#buckets)"
        )
    snap = {str(k): v for k, v in fleet.obs.metrics.snapshot().items()}
    misses = snap.get("table_cache_misses_total", [{"value": 0}])[0]["value"]
    hits = snap.get("table_cache_hits_total", [{"value": 0}])[0]["value"]
    rows.append(("multi_tenant.table_cache", T,
                 f"miss={int(misses)} hit={int(hits)}",
                 f"builds = {len(patterns)} distinct patterns (gate)"))
    if int(misses) != len(patterns):
        raise SystemExit(
            f"multi_tenant_throughput: {int(misses)} table builds for "
            f"{len(patterns)} distinct patterns"
        )

    return {
        "tenants": T,
        "n_chars": n,
        "distinct_patterns": len(patterns),
        "bit_identical": bool(ok),
        "buckets": n_buckets,
        "compile_count": int(compiles),
        "table_cache": {"misses": int(misses), "hits": int(hits)},
        "throughput": {
            "serial": {"sweep_s": dt_serial, "texts_per_s": thr_serial},
            "fleet": {"sweep_s": dt_fleet, "texts_per_s": thr_fleet},
            "speedup_fleet_over_serial": speedup,
        },
    }


def bench_recognizer(rows, quick):
    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact
    from repro.core.reference import ParallelArtifacts
    from repro.core.serial import recognize

    art = ParallelArtifacts.generate(BIGDATA_RE)
    text = make_text_exact("BIGDATA", 20_000 if quick else 500_000, seed=3)
    t_rec = _time(lambda: recognize(art.matrices, text, art.dfa), reps=2)
    rows.append(("recognizer.serial_dfa", len(text), round(t_rec * 1e3, 1), "ms"))


def bench_memory(rows, quick):
    from benchmarks.benchmark_res import BIGDATA_RE, make_text_exact

    import repro

    parser = repro.Parser(BIGDATA_RE)
    sizes = (1_000, 10_000) if quick else (10_000, 100_000, 1_000_000)
    for n in sizes:
        text = make_text_exact("BIGDATA", n, seed=4)
        s = parser.parse(text).forest
        packed = s.pack()
        comp = repro.compress(s)
        rows.append((f"memory.packed_bytes_per_char.n{n}", n,
                     round(packed.nbytes / max(len(text), 1), 3), "B/char"))
        rows.append((f"memory.compressed_bytes_per_char.n{n}", n,
                     round(comp.nbytes() / max(len(text), 1), 4),
                     f"{len(comp.states)} states; {len(comp.overrides)} overrides"))


def bench_engine_roofline(rows):
    p = Path(__file__).resolve().parents[1] / "experiments" / "dryrun_results.json"
    if not p.exists():
        rows.append(("engine_roofline.missing", 0, 0, "run repro.launch.dryrun first"))
        return
    d = json.loads(p.read_text())
    for k, v in sorted(d.items()):
        if not v.get("ok") or v.get("skipped"):
            continue
        rows.append(
            (f"roofline.{k}", v["chips"],
             round(v.get("roofline_fraction", 0.0), 4),
             f"bottleneck={v.get('bottleneck')}")
        )


def _json_value(v):
    """Coerce a CSV-row value to a JSON-native type (numpy scalars -> python)."""
    if hasattr(v, "item"):
        v = v.item()
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-tiny sizes (implies --quick)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-bench-json", dest="bench_json", action="store_false",
                    default=True,
                    help="skip writing BENCH_<gate>.json perf-trajectory files")
    args = ap.parse_args(argv)
    if args.smoke:
        args.quick = True

    rows = []
    benches = {
        "tab5": lambda: bench_tab5(rows),
        "fig20": lambda: bench_fig20(rows, args.quick),
        "generation": lambda: bench_generation(rows),
        "parse_times": lambda: bench_parse_times(rows, args.quick),
        "speedup": lambda: bench_speedup(rows, args.quick),
        "batched_throughput": lambda: bench_batched_throughput(rows, args.quick),
        "streaming_append": lambda: bench_streaming_append(
            rows, args.quick, args.smoke
        ),
        "edit_splice": lambda: bench_edit_splice(
            rows, args.quick, args.smoke
        ),
        "sharded_throughput": lambda: bench_sharded_throughput(
            rows, args.quick, args.smoke
        ),
        "packed_throughput": lambda: bench_packed_throughput(
            rows, args.quick, args.smoke
        ),
        "speculation_throughput": lambda: bench_speculation_throughput(
            rows, args.quick, args.smoke
        ),
        "multi_tenant_throughput": lambda: bench_multi_tenant_throughput(
            rows, args.quick, args.smoke
        ),
        "recognizer": lambda: bench_recognizer(rows, args.quick),
        "memory": lambda: bench_memory(rows, args.quick),
        "engine_roofline": lambda: bench_engine_roofline(rows),
    }
    from repro.obs.export import write_bench_json

    repo_root = Path(__file__).resolve().parents[1]
    config = {"quick": args.quick, "smoke": args.smoke, "only": args.only}
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        start = len(rows)
        extra = fn()
        wall_s = time.time() - t0
        print(f"# {name} done in {wall_s:.1f}s", file=sys.stderr)
        if not args.bench_json:
            continue
        # every gate leaves one BENCH_<gate>.json perf-trajectory entry with
        # the shared {name, timestamp, config, metrics} schema; the CSV rows
        # the gate produced go under metrics["rows"], richer per-gate
        # structures (the speculation report) under metrics["report"]
        metrics = {
            "rows": [
                {"name": r, "param": _json_value(p), "value": _json_value(v),
                 "derived": str(d)}
                for r, p, v, d in rows[start:]
            ],
            "wall_s": round(wall_s, 3),
        }
        if extra is not None:
            metrics["report"] = extra
        bench_name = {
            "speculation_throughput": "speculation",
            "multi_tenant_throughput": "multi_tenant",
        }.get(name, name)
        out = write_bench_json(bench_name, config=config, metrics=metrics,
                               out_dir=repo_root)
        print(f"# wrote {out.name}", file=sys.stderr)
    print("name,param,value,derived")
    for name, param, value, derived in rows:
        print(f"{name},{param},{value},{derived}")


if __name__ == "__main__":
    main()
