"""Benchmark REs and text corpora (paper Tab. 7 stand-ins, self-generated).

The paper's corpora (BIBLE html, FASTA, TRAFFIC syslog, REgen) are external;
we synthesize structurally equivalent ones so every benchmark is hermetic:

  BIGDATA  small random RE (size ~9) + random valid text   [Tab. 7 row 1]
  BIBLE    mid RE (~31 syms): h3-title search in html-ish text
  FASTA    large RE (~102 syms): DNA records in FASTA format
  TRAFFIC  large RE (~123 syms): GET/POST request log lines
  REGEN    random REs of growing size + valid texts          [Tab. 7 row 5]
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.data.regen import random_regex, sample_string
from repro.core import regex as rx

BIGDATA_RE = "(ab|ba|b)+"
BIBLE_RE = r"(<h3>(a|b|c|d| )+</h3>|(a|b|c|d|<|>|/| )+)+"
FASTA_RE = r"(>(\w| )+\n([ACGT]+\n)+)+"
TRAFFIC_RE = (
    r"((GET|POST|PUT) /([a-z0-9]|/)* ([0-9]{3}) (ok|err|-)\n)+"
)

BENCHMARKS: Dict[str, str] = {
    "BIGDATA": BIGDATA_RE,
    "BIBLE": BIBLE_RE,
    "FASTA": FASTA_RE,
    "TRAFFIC": TRAFFIC_RE,
}


def make_text(name: str, target_len: int, seed: int = 0) -> bytes:
    rng = np.random.Generator(np.random.Philox(seed))
    out = []
    n = 0
    ast = rx.parse_regex(BENCHMARKS[name])
    # sample the top-level Plus body repeatedly for steady record streams
    body = ast.item if isinstance(ast, rx.Plus) else ast
    while n < target_len:
        rec = sample_string(body, rng, max_rep=6)
        if not rec:
            continue
        out.append(rec)
        n += len(rec)
    return b"".join(out)[: target_len or None]


def make_text_exact(name: str, target_len: int, seed: int = 0) -> bytes:
    """Valid text close to target_len (never truncated mid-record)."""
    rng = np.random.Generator(np.random.Philox(seed))
    ast = rx.parse_regex(BENCHMARKS[name])
    body = ast.item if isinstance(ast, rx.Plus) else ast
    out = []
    n = 0
    while n < target_len:
        rec = sample_string(body, rng, max_rep=6)
        if not rec:
            continue
        out.append(rec)
        n += len(rec)
    return b"".join(out)


def regen_suite(n_res: int, size_lo: int, size_hi: int, seed: int = 0):
    rng = np.random.Generator(np.random.Philox(seed))
    suite = []
    for i in range(n_res):
        size = int(size_lo + (size_hi - size_lo) * i / max(n_res - 1, 1))
        ast = random_regex(size, rng)
        suite.append((size, ast))
    return suite
