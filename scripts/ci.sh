#!/usr/bin/env bash
# CI smoke gate: tier-1 test suite + a quick benchmark sanity pass.
#
#   scripts/ci.sh            # full tier-1 + tab5 smoke bench
#   scripts/ci.sh --fast     # skip slow (subprocess/multi-device) tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

# tier-1 suite (includes the streaming modules tests/test_stream.py and
# tests/test_stream_service.py — every incremental state vs the oracles)
python -m pytest "${PYTEST_ARGS[@]}"

# streaming smoke gate: amortized append cost + bit-identity vs cold parse
python -m benchmarks.run --only streaming_append --smoke

# distributed runtime gate on an 8-device host mesh: the mesh tests run
# in-process (device count is locked at jax init, hence the fresh
# interpreters), then the sharded bench's bit-identity smoke
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest tests/test_distributed.py -q -m "not slow"
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.run --only sharded_throughput --smoke

python -m benchmarks.run --quick --only tab5
