#!/usr/bin/env bash
# CI smoke gate: tier-1 test suite + a quick benchmark sanity pass.
#
#   scripts/ci.sh            # full tier-1 + tab5 smoke bench
#   scripts/ci.sh --fast     # skip slow (subprocess/multi-device) tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

# tier-1 suite (includes the streaming modules tests/test_stream.py and
# tests/test_stream_service.py — every incremental state vs the oracles —
# and the backend-parametrized matrix in tests/test_backend.py, which
# covers jnp, pallas-interpret AND the bit-packed uint32 backend).  The
# conformance/packed/sparse modules are ignored HERE only because the
# explicit gate below runs them — they stay tier-1 members for a plain
# `pytest`.
python -m pytest "${PYTEST_ARGS[@]}" \
    --ignore=tests/test_conformance.py --ignore=tests/test_packed.py \
    --ignore=tests/test_sparse.py

# cross-backend conformance harness: every registered backend (jnp, pallas,
# packed AND sparse — the registry is enumerated at runtime) bit-identical
# to the oracle across fused / phase-split / streaming / 1-device-mesh
# routes, plus the packed-semiring property tests and the sparse
# representation/edge-case tests (an explicit named gate so a backend
# regression fails CI even if the tier-1 invocation changes)
python -m pytest tests/test_conformance.py tests/test_packed.py \
    tests/test_sparse.py -q

# streaming smoke gate: amortized append cost + bit-identity vs cold parse
python -m benchmarks.run --only streaming_append --smoke

# edit-splice smoke gate: mid-text splices through the product segment tree
# must stay ~log(n) (cost-growth gate), beat a cold linear re-parse ≥4× at
# the largest prefix, and land bit-identical to the cold parse at every
# size; refreshes BENCH_edit_splice.json
python -m benchmarks.run --only edit_splice --smoke

# packed-backend smoke gate: bit-identity vs the jnp backend + the ≥8×
# SLPF-path bytes-moved reduction at ℓ ≥ 256 states (real gate, not printout)
python -m benchmarks.run --only packed_throughput --smoke

# speculation smoke gate: sparse feasible-start backend bit-identical to the
# jnp oracle at ℓ=257 + product-path bytes strictly below dense packed on
# every RE whose measured feasible width < ℓp/2; refreshes
# BENCH_speculation.json (the machine-readable perf trajectory)
python -m benchmarks.run --only speculation_throughput --smoke

# multi-tenant fleet smoke gate: T=32 mixed regexes served by one
# tenant-batched device program — bit-identical to each tenant's solo
# Parser, ≥4× the per-tenant serial loop, compile count O(#buckets);
# refreshes BENCH_multi_tenant.json
python -m benchmarks.run --only multi_tenant_throughput --smoke

# distributed runtime gate on an 8-device host mesh: the mesh tests run
# in-process (device count is locked at jax init, hence the fresh
# interpreters), then the sharded bench's bit-identity smoke
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest tests/test_distributed.py -q -m "not slow"
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.run --only sharded_throughput --smoke

# examples smoke gate: every example runs end-to-end on tiny inputs through
# the public facade ONLY — repo-internal DeprecationWarnings (messages are
# "repro: ..."-prefixed) escalate to errors, so a call site that regressed
# onto resolve_engine / direct service construction fails CI here
EXAMPLES_WORKDIR="$(mktemp -d)"
trap 'rm -rf "$EXAMPLES_WORKDIR"' EXIT
for ex in examples/*.py; do
    echo "## example smoke: $ex"
    case "$ex" in
        examples/train_lm.py)
            python -W "error:repro:DeprecationWarning" "$ex" --smoke \
                --workdir "$EXAMPLES_WORKDIR/train" ;;
        *)
            python -W "error:repro:DeprecationWarning" "$ex" --smoke ;;
    esac
done

python -m benchmarks.run --quick --only tab5

# observability smoke gate: traced parses on every registered backend leave
# schema-valid span trees in the JSONL log (direct + ticket routes), metric
# names stay inside METRIC_CATALOG, the Prometheus rendering is non-empty,
# fleet compile counts scale with buckets (not tenants), and every
# BENCH_*.json the gates above refreshed matches the shared
# {name, timestamp, config, metrics} perf-trajectory schema
python scripts/obs_smoke.py

# static-analysis gate: jaxpr/HLO lint over every registered backend's
# compiled phase programs (no host callbacks, no f64 promotion, no dynamic
# shapes), seeded f64/callback violations prove the lint still catches, the
# fleet compile count stays O(#buckets), and backend="auto" parses
# bit-identically to the backend the analyzer picks
python scripts/analyze_gate.py

# perf-trajectory trend gate: the BENCH_*.json files the gates above
# regenerated vs the copies committed at HEAD — a >25% drop in any
# throughput metric (at matching bench config) fails CI
python scripts/bench_trend.py
