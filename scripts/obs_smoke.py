"""CI obs smoke gate: the observability layer end-to-end on tiny inputs.

    PYTHONPATH=src python scripts/obs_smoke.py

Four checks, all through the public facade (``repro.Parser`` with
``ParserConfig(obs=...)``):

  1. traced parse on EVERY registered backend — the direct ``parse`` route
     and the ``submit``/ticket route both leave a complete span tree in the
     JSONL log (one root, parents resolve, child durations bounded by the
     root: ``validate_span_tree``);
  2. the span taxonomy holds — ``parse.request`` roots with phase children
     (reach/join/build&merge) on the direct route, queue-wait + batch-compute
     children on the ticket route;
  3. metric-name rot guard — every name in every registry snapshot is in
     ``METRIC_CATALOG`` (``validate_metric_names``), and ``prometheus_text``
     renders the snapshot;
  4. stream edits — mid-text splices through ``ParserStream.edit`` leave
     ``stream.edit`` span trees and move the ``stream_edits_total`` counter
     and ``stream_edit_recompose_depth`` histogram, all rendering in the
     Prometheus text;
  5. fleet compile economy — a ``ParserFleet`` with many tenants over few
     (backend, ℓp-bucket) pairs compiles one program per BUCKET (not per
     tenant), and the table-compile cache counters
     (``table_cache_hits_total`` / ``table_cache_misses_total``) count
     distinct (pattern, backend) builds and render in the snapshot;
  6. analyzer metrics — construction-time analysis verdict counters
     (``analyzer_verdicts_total``) and ``backend="auto"`` selection counters
     (``auto_backend_selected_total``) stay inside ``METRIC_CATALOG`` and
     render in the Prometheus text;
  7. every ``BENCH_*.json`` at the repo root parses against the shared
     perf-trajectory schema (``validate_bench_report``).

Exits non-zero on the first violated invariant, printing which one.
"""

import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import repro
from repro.obs import (
    prometheus_text,
    read_spans_jsonl,
    validate_bench_report,
    validate_metric_names,
    validate_span_tree,
)

PHASE_SPANS = {"phase.reach", "phase.join", "phase.build_merge",
               "phase.host_build"}


def check_backend(backend: str, workdir: Path) -> None:
    log = workdir / f"spans_{backend}.jsonl"
    cfg = repro.ParserConfig(
        regex="(a|b|ab)+", backend=backend, n_chunks=4,
        obs={"enabled": True, "span_log": str(log)},
    )
    with repro.Parser(cfg) as p:
        direct = p.parse("abab" * 8)
        assert direct.ok, f"{backend}: traced parse rejected a valid text"
        assert direct.trace_id, f"{backend}: traced parse has no trace_id"

        ticket = p.submit("abab" * 4)
        served = ticket.result()
        assert served.ok and served.trace_id, \
            f"{backend}: ticket route lost its trace"
        assert served.trace_id != direct.trace_id, \
            f"{backend}: trace_id reused across requests"

        snap = p.stats()["metrics"]
        validate_metric_names(snap)
        assert prometheus_text(snap).strip(), \
            f"{backend}: empty prometheus rendering"
        p.obs.close()

    spans = read_spans_jsonl(log)
    for tid, route in ((direct.trace_id, "direct"),
                       (served.trace_id, "ticket")):
        tree = validate_span_tree(spans, tid)
        root = tree["root"]
        assert root["name"] == "parse.request", \
            f"{backend}/{route}: root span is {root['name']!r}"
        children = {s["name"] for s in spans
                    if s["trace_id"] == tid and s["parent_id"] is not None}
        want = (PHASE_SPANS if route == "direct"
                else {"parse.queue_wait", "parse.batch_compute"})
        missing = want - children
        assert not missing, f"{backend}/{route}: missing spans {sorted(missing)}"
    print(f"ok: {backend:7s} — {len(spans)} spans, both routes form valid trees")


def check_stream_edit(workdir: Path) -> None:
    log = workdir / "spans_edit.jsonl"
    cfg = repro.ParserConfig(
        regex="(a|b|ab)+", n_chunks=4, first_seal_len=4, max_seal_len=8,
        obs={"enabled": True, "span_log": str(log)},
    )
    with repro.Parser(cfg) as p:
        with p.open_stream() as stream:
            stream.append("ab" * 12)
            assert stream.accepted, "edit: stream rejected a valid prefix"
            stream.edit(5, 9, "ba")           # mid-text splice
            stream.delete(0, 2)               # pure delete
            stream.insert(4, "ab")            # zero-width insert
            assert stream.result().ok, "edit: edited stream rejected"
        snap = p.stats()["metrics"]
        validate_metric_names(snap)
        flat = {str(k): v for k, v in snap.items()}
        edits = flat["stream_edits_total"][0]["value"]
        assert edits == 3, f"edit: stream_edits_total={edits}, expected 3"
        depth = flat["stream_edit_recompose_depth"][0]["value"]
        assert depth["count"] == 3, \
            f"edit: recompose-depth histogram count={depth['count']}, expected 3"
        rendered = prometheus_text(snap)
        for name in ("stream_edits_total", "stream_edit_recompose_depth"):
            assert name in rendered, f"edit: {name} missing from rendering"
        p.obs.close()
    spans = read_spans_jsonl(log)
    roots = [s for s in spans if s["name"] == "stream.edit"]
    assert len(roots) == 3, f"edit: {len(roots)} stream.edit spans, expected 3"
    for root in roots:
        assert root["parent_id"] is None, "edit: stream.edit span not a root"
        for attr in ("lo", "hi", "repl_chars", "n_chars"):
            assert attr in root["attrs"], f"edit: span missing attr {attr!r}"
        assert root["duration_s"] >= 0.0, "edit: span never closed"
    print(f"ok: edit    — 3 splices traced, recompose-depth histogram + "
          f"counter rendered")


def check_fleet() -> None:
    from repro.core.fleet import clear_table_cache

    clear_table_cache()
    # 8 tenants, but only 3 (backend, class, ℓp) automaton buckets:
    # six jnp tenants share one pattern/bucket, one jnp tenant has a long
    # pattern (own ℓp bucket), one runs the shared pattern on sparse
    tenants = {
        f"t{i}": repro.ParserConfig(regex="(a|b)*abb", n_chunks=4)
        for i in range(6)
    }
    tenants["long"] = repro.ParserConfig(regex="a" * 40, n_chunks=4)
    tenants["sp"] = repro.ParserConfig(
        regex="(a|b)*abb", backend="sparse", n_chunks=4
    )
    with repro.ParserFleet(tenants) as fleet:
        fleet.parse_batch([(tid, "ababb") for tid in tenants])
        n_buckets = fleet.engine.n_buckets
        assert n_buckets == 3, f"fleet: expected 3 buckets, got {n_buckets}"
        assert fleet.compile_count == n_buckets, (
            f"fleet: {fleet.compile_count} compiled programs for "
            f"{n_buckets} buckets and {len(tenants)} tenants — compile "
            f"count must scale with buckets, not tenants"
        )
        snap = fleet.stats()["metrics"]
        validate_metric_names(snap)
        flat = {str(k): v for k, v in snap.items()}
        misses = flat["table_cache_misses_total"][0]["value"]
        hits = flat["table_cache_hits_total"][0]["value"]
        # 3 distinct (pattern, backend) builds; the 5 repeat jnp tenants hit
        assert misses == 3, f"fleet: {misses} table builds, expected 3"
        assert hits == 5, f"fleet: {hits} table-cache hits, expected 5"
        assert flat["fleet_tenants"][0]["value"] == len(tenants)
        assert flat["fleet_buckets"][0]["value"] == n_buckets
        rendered = prometheus_text(snap)
        for name in ("table_cache_misses_total", "table_cache_hits_total"):
            assert name in rendered, f"fleet: {name} missing from rendering"
    print(f"ok: fleet   — {len(tenants)} tenants -> {n_buckets} buckets, "
          f"{int(misses)} table builds (+{int(hits)} cache hits)")


def check_analyzer() -> None:
    """Analyzer metrics (repro.analyze leg 1) stay inside METRIC_CATALOG and
    render in the Prometheus text: verdict counters from construction-time
    analysis, auto-backend selection counters from backend="auto"."""
    with repro.Parser(
        repro.ParserConfig(regex="(a|b|ab)+", backend="auto", n_chunks=4)
    ) as p:
        assert p.parse("abab").ok, "analyzer: auto-backend parse rejected"
        snap = p.stats()["metrics"]
        validate_metric_names(snap)
        flat = {str(k): v for k, v in snap.items()}
        verdicts = flat.get("analyzer_verdicts_total")
        assert verdicts and verdicts[0]["labels"].get("verdict") == "ok", \
            "analyzer: analyzer_verdicts_total{verdict=ok} not recorded"
        selected = flat.get("auto_backend_selected_total")
        assert selected and selected[0]["value"] == 1, \
            "analyzer: auto_backend_selected_total not recorded"
        chosen = selected[0]["labels"].get("backend")
        assert chosen == p.backend_name, (
            f"analyzer: selection counter says {chosen!r} but the parser "
            f"runs {p.backend_name!r}"
        )
        rendered = prometheus_text(snap)
        for name in ("analyzer_verdicts_total", "auto_backend_selected_total"):
            assert name in rendered, f"analyzer: {name} missing from rendering"
    print(f"ok: analyze — verdict + auto-selection counters "
          f"(backend={chosen!r}) in catalog and rendering")


def check_bench_reports(repo_root: Path) -> None:
    reports = sorted(repo_root.glob("BENCH_*.json"))
    assert reports, "no BENCH_*.json at repo root (run benchmarks/run.py)"
    for path in reports:
        try:
            validate_bench_report(json.loads(path.read_text()))
        except ValueError as e:
            raise SystemExit(f"{path.name}: schema violation: {e}")
        print(f"ok: {path.name} matches the perf-trajectory schema")


def main() -> None:
    repo_root = Path(__file__).resolve().parents[1]
    with tempfile.TemporaryDirectory() as tmp:
        for backend in repro.list_backends():
            check_backend(backend, Path(tmp))
        check_stream_edit(Path(tmp))
    check_fleet()
    check_analyzer()
    check_bench_reports(repo_root)
    print("obs smoke gate: all checks passed")


if __name__ == "__main__":
    main()
