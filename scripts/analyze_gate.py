"""CI static-analysis gate: lint every compiled phase program, prove the
lint can still catch violations, and hold the analyzer's public promises.

    PYTHONPATH=src python scripts/analyze_gate.py

Four checks:

  1. program lint (``repro.analyze.program``) over EVERY registered backend
     at two (c, k) buckets — no host callbacks inside jitted phase bodies,
     no f64/c128 promotion, no dynamic shapes.  Any finding fails the gate
     with the offending program named.
  2. seeded-violation self-tests — a throwaway program with an injected f64
     promotion and one with an injected ``pure_callback`` MUST be flagged;
     if either slips through, the lint itself has rotted and the gate fails.
  3. fleet compile economy: tenants over few automaton buckets compile
     O(#buckets) programs, never O(#tenants) — the invariant the shared
     jitted programs exist to provide.
  4. ``backend="auto"`` resolution is sound: the analyzer picks a registered
     backend and the auto parser's forest is bit-identical to the same
     config with the chosen backend named explicitly.

Exits non-zero on the first violated invariant, printing which one.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

import repro
from repro.analyze import lint_engine, lint_jaxpr, lint_program, lint_report

GATE_PATTERN = "(a|b|ab)+"
GATE_BUCKETS = ((4, 32), (8, 32))


def check_backends() -> None:
    for backend in repro.list_backends():
        p = repro.Parser(repro.ParserConfig(regex=GATE_PATTERN, backend=backend))
        findings = lint_engine(p.engine, buckets=GATE_BUCKETS, label=backend)
        assert not findings, (
            f"{backend}: compiled phase programs violate lint invariants:\n"
            + lint_report(findings)
        )
        n = len(GATE_BUCKETS) * 3
        print(f"ok: {backend:7s} — {n} phase programs clean at "
              f"{'/'.join(f'{c}x{k}' for c, k in GATE_BUCKETS)}")


def check_seeded_violations() -> None:
    import jax
    import jax.numpy as jnp

    # f64 promotion: must surface in BOTH the jaxpr walk and the HLO scan
    with jax.experimental.enable_x64():
        prog = jax.jit(lambda x: x.astype(jnp.float64) * 2.0)
        args = (jax.ShapeDtypeStruct((8, 8), jnp.float32),)
        findings = lint_program(prog, args, "selftest:f64")
    rules = {f.rule for f in findings}
    assert "f64" in rules, (
        "seeded f64 promotion was NOT caught — the lint has rotted "
        f"(findings: {lint_report(findings) or 'none'})"
    )
    print(f"ok: selftest — seeded f64 promotion caught "
          f"({len(findings)} findings)")

    # host callback: must surface in the jaxpr walk
    def cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct((8,), jnp.float32), x
        )

    findings = lint_jaxpr(jax.make_jaxpr(jax.jit(cb))(jnp.ones(8)), "selftest:cb")
    rules = {f.rule for f in findings}
    assert "host-callback" in rules, (
        "seeded pure_callback was NOT caught — the lint has rotted "
        f"(findings: {lint_report(findings) or 'none'})"
    )
    print(f"ok: selftest — seeded host callback caught "
          f"({len(findings)} findings)")


def check_fleet_compile_economy() -> None:
    from repro.core.fleet import clear_table_cache

    clear_table_cache()
    tenants = {
        f"t{i}": repro.ParserConfig(regex="(a|b)*abb", n_chunks=4)
        for i in range(5)
    }
    tenants["sp"] = repro.ParserConfig(
        regex="(a|b)*abb", backend="sparse", n_chunks=4
    )
    with repro.ParserFleet(tenants) as fleet:
        fleet.parse_batch([(tid, "ababb") for tid in tenants])
        n_buckets = fleet.engine.n_buckets
        assert fleet.compile_count == n_buckets, (
            f"fleet compiled {fleet.compile_count} programs for {n_buckets} "
            f"buckets over {len(tenants)} tenants — compile count must be "
            "O(#buckets), not O(#tenants)"
        )
    print(f"ok: fleet   — {len(tenants)} tenants -> {n_buckets} buckets -> "
          f"{n_buckets} compiled programs")


def check_auto_backend() -> None:
    auto = repro.Parser(repro.ParserConfig(regex=GATE_PATTERN, backend="auto"))
    chosen = auto.backend_name
    assert chosen in repro.list_backends(), (
        f'backend="auto" resolved to unregistered backend {chosen!r}'
    )
    explicit = repro.Parser(
        repro.ParserConfig(regex=GATE_PATTERN, backend=chosen)
    )
    for text in ("abab" * 8, "ba" * 7, "a", "abba" * 5):
        fa = auto.parse(text).forest
        fe = explicit.parse(text).forest
        assert np.array_equal(fa.columns, fe.columns) and np.array_equal(
            fa.classes, fe.classes
        ), f'backend="auto" forest diverged from {chosen!r} on {text!r}'
    print(f'ok: auto    — resolves to {chosen!r}, bit-identical forests')


def main() -> None:
    check_backends()
    check_seeded_violations()
    check_fleet_compile_economy()
    check_auto_backend()
    print("analyze gate: all checks passed")


if __name__ == "__main__":
    main()
