#!/usr/bin/env python
"""Perf-trajectory guard: fresh BENCH_<gate>.json vs the committed copy.

The benchmark harness (``benchmarks/run.py``) writes one ``BENCH_<gate>.json``
per gate, and the files are committed — so ``git show HEAD:<file>`` is the
performance record of the last landed change.  This script re-reads the fresh
working-tree copies after a CI bench run and compares every *throughput* row
(higher is better) against the committed baseline:

  * rows are matched by ``name``; a row counts as throughput-like when its
    name contains ``throughput`` or its derived note mentions ``texts/s`` /
    ``chars/s`` — ratio metrics (``speedup``) and pass/fail flags
    (``bit_identical``) are excluded;
  * a fresh value below ``--threshold`` (default 0.75, i.e. a >25% drop) of
    the baseline is a regression — all regressions are reported, then the
    script exits non-zero so CI fails;
  * a file whose recorded ``config`` differs from the baseline's (full vs
    smoke sizes, different ``--only``) is skipped: those numbers are not
    comparable;
  * a ``BENCH_<gate>.json`` present in the working tree but absent at
    ``--base`` is a NEW gate (the PR that introduces a benchmark): its fresh
    throughput rows are printed informationally as the baseline-to-be, and
    the run stays green — new gates are never failures.

Usage:  python scripts/bench_trend.py [--base HEAD] [--threshold 0.75]
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _throughput_rows(doc: dict) -> dict:
    """name → float value for every throughput-like row of one BENCH doc."""
    out = {}
    for row in doc.get("metrics", {}).get("rows", []):
        name = str(row.get("name", ""))
        derived = str(row.get("derived", ""))
        if "speedup" in name or "bit_identical" in name:
            continue
        if "throughput" not in name and not any(
            tag in derived for tag in ("texts/s", "chars/s")
        ):
            continue
        try:
            out[name] = float(row.get("value"))
        except (TypeError, ValueError):
            continue
    return out


def _committed(path: Path, base: str) -> dict | None:
    proc = subprocess.run(
        ["git", "show", f"{base}:{path.name}"],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
    )
    if proc.returncode != 0:  # new file this change: no baseline yet
        return None
    try:
        return json.loads(proc.stdout)
    except json.JSONDecodeError:
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base", default="HEAD",
                    help="git ref holding the baseline BENCH files")
    ap.add_argument("--threshold", type=float, default=0.75,
                    help="fresh/baseline ratio below this fails (0.75 = "
                         "fail on a >25%% throughput drop)")
    args = ap.parse_args(argv)

    regressions = []
    compared = 0
    new_gates = 0
    for path in sorted(REPO_ROOT.glob("BENCH_*.json")):
        base_doc = _committed(path, args.base)
        fresh_doc = json.loads(path.read_text())
        if base_doc is None:
            # gate introduced by this change: nothing to compare against —
            # print the fresh rows as the baseline-to-be (informational)
            new_gates += 1
            rows = _throughput_rows(fresh_doc)
            print(f"{path.name}: new gate (no baseline at {args.base}) — "
                  f"{len(rows)} throughput metric(s) recorded, informational")
            for name, value in sorted(rows.items()):
                print(f"{path.name}: {name}  (new) -> {value:.1f}")
            continue
        if fresh_doc.get("config") != base_doc.get("config"):
            print(f"{path.name}: config changed "
                  f"({base_doc.get('config')} -> {fresh_doc.get('config')}) "
                  f"— not comparable, skip")
            continue
        base_rows = _throughput_rows(base_doc)
        fresh_rows = _throughput_rows(fresh_doc)
        for name, base_v in sorted(base_rows.items()):
            fresh_v = fresh_rows.get(name)
            if fresh_v is None or base_v <= 0:
                continue
            ratio = fresh_v / base_v
            compared += 1
            marker = "REGRESSION" if ratio < args.threshold else "ok"
            print(f"{path.name}: {name}  {base_v:.1f} -> {fresh_v:.1f}  "
                  f"({ratio:.2f}x)  {marker}")
            if ratio < args.threshold:
                regressions.append((path.name, name, base_v, fresh_v, ratio))

    if regressions:
        print(f"\n{len(regressions)} throughput regression(s) worse than "
              f"{(1 - args.threshold) * 100:.0f}%:", file=sys.stderr)
        for fname, name, base_v, fresh_v, ratio in regressions:
            print(f"  {fname}: {name} {base_v:.1f} -> {fresh_v:.1f} "
                  f"({ratio:.2f}x)", file=sys.stderr)
        return 1
    suffix = f" (+{new_gates} new gate(s))" if new_gates else ""
    print(f"\nbench trend clean: {compared} throughput metrics within "
          f"{(1 - args.threshold) * 100:.0f}% of {args.base}{suffix}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
