"""Roofline-term extraction from compiled dry-run artifacts (§Roofline).

Home of the repo's ONE analysis layer's hardware model: this module carries
the machine constants (``PEAK_FLOPS`` / ``HBM_BW`` / ``ICI_BW``) and the
``Roofline`` term extraction that both legs of ``repro.analyze`` build on —
``analyze/pattern.py``'s static per-backend cost model and the launch
tooling's compiled-artifact analysis.  ``repro.launch.analysis`` re-exports
everything here for compatibility (it was this file's original home).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs   / (chips · PEAK_FLOPS)
    memory     = HLO_bytes   / (chips · HBM_BW)
    collective = coll_bytes  / (chips · ICI_BW)

``cost_analysis()`` provides HLO FLOPs and bytes accessed.  Collective bytes
are NOT in cost_analysis — we parse the post-optimization HLO text and sum the
output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (ring traffic ≈ output bytes per
participating device; the constant factors are absorbed into the comparison,
which is relative across iterations).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO result-type string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind from post-optimization HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.:  %all-reduce.3 = bf16[4096,5120]{1,0} all-reduce(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        # strip "-start"/"-done" async suffixes; count only starts
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _shape_bytes(m.group(1))
            counts[base] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0
    memory_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — fraction of compiled compute that is
        'useful' model math (catches remat recompute and padding waste).
        Both totals are global (hlo_flops = per-device analyzer total × chips)."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization if the dominant term were the runtime:
        (model_flops / chips / PEAK) / max(term) — the score we hillclimb."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / t

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def model_train_flops(n_params_active: int, n_tokens: int) -> float:
    """6·N·D (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * n_tokens


def model_forward_flops(n_params_active: int, n_tokens: int) -> float:
    return 2.0 * n_params_active * n_tokens


def model_attn_flops(cfg, seq_len: int, n_tokens: int, *, train: bool, decode: bool = False) -> float:
    """Quadratic attention term (not in 6·N·D; dominates at 32k+ context):
    4·T_ctx·(h·hd) per token per attention layer forward (QKᵀ + AV), ×3 for
    training (fwd+bwd).  Sliding windows cap the context; SSM layers have no
    quadratic term (their state math is inside the param count)."""
    kinds = cfg.layer_kinds
    n_attn = sum(1 for k in kinds if k in ("attn", "moe"))
    if cfg.shared_attn_every:
        n_attn += len(kinds) // cfg.shared_attn_every
    if n_attn == 0:
        return 0.0
    d_attn = cfg.n_heads * cfg.resolved_head_dim
    ctx = seq_len if cfg.sliding_window is None else min(seq_len, cfg.sliding_window)
    eff_ctx = ctx if decode else ctx / 2.0  # causal averaging over positions
    per_token = 4.0 * eff_ctx * d_attn * n_attn
    return per_token * n_tokens * (3.0 if train else 1.0)


def analyze_compiled(
    compiled, *, arch: str, shape: str, mesh_name: str, chips: int, model_flops: float
) -> Roofline:
    """Trip-count-aware analysis of the partitioned module (``hlo_stats``).

    The optimized HLO text is the per-device program; totals below are global
    (per-device × chips).  ``cost_analysis()`` is recorded for reference but
    under-counts ``while`` bodies (counted once), hence the custom analyzer.
    """
    from ..launch.hlo_stats import analyze_hlo_text

    stats = analyze_hlo_text(compiled.as_text())
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        pass
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=stats.flops * chips,
        hlo_bytes=stats.bytes * chips,
        coll_bytes=stats.coll_bytes * chips,
        coll_detail={
            **{k: v * chips for k, v in stats.coll.items()},
            "coll_ops_per_device": stats.coll_count,
            "unknown_trip_loops": stats.unknown_trips,
            "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
            "xla_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        },
        model_flops=model_flops,
        memory_per_device=mem,
    )
