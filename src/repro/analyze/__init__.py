"""``repro.analyze`` — the repo's ONE static-analysis layer.

Three modules, two legs plus the shared hardware model:

  ``pattern``    jax-free RE/automaton diagnostics: feasible-start width
                 bounds, ambiguity verdicts, chunk-product density, the
                 per-backend cost model behind ``backend="auto"`` and the
                 ``analyze=`` admission knob (leg 1).
  ``program``    jaxpr/HLO lint over compiled phase programs — no host
                 callbacks, no f64 promotion, no dynamic shapes — run by
                 ``scripts/analyze_gate.py`` in CI (leg 2).
  ``roofline``   machine constants and compiled-artifact roofline terms
                 (moved here from ``launch/analysis.py``, which re-exports).
"""

from __future__ import annotations

from .pattern import (  # noqa: F401
    AnalysisReport,
    analyze_matrices,
    analyze_pattern,
    backend_cost_model,
    cached_report,
    choose_backend,
    density_profile,
    feasible_width_bounds,
    nfa_ambiguous,
    resolve_auto_backend,
    sparse_width_bucket,
)
from .program import (  # noqa: F401
    LintFinding,
    lint_engine,
    lint_hlo_text,
    lint_jaxpr,
    lint_program,
    lint_report,
)
from .roofline import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    analyze_compiled,
    collective_bytes,
)

__all__ = [
    "AnalysisReport",
    "HBM_BW",
    "ICI_BW",
    "LintFinding",
    "PEAK_FLOPS",
    "Roofline",
    "analyze_compiled",
    "analyze_matrices",
    "analyze_pattern",
    "backend_cost_model",
    "cached_report",
    "choose_backend",
    "collective_bytes",
    "density_profile",
    "feasible_width_bounds",
    "lint_engine",
    "lint_hlo_text",
    "lint_jaxpr",
    "lint_program",
    "lint_report",
    "nfa_ambiguous",
    "resolve_auto_backend",
    "sparse_width_bucket",
]
