"""Static RE/automaton diagnostics — everything knowable before any text.

The paper's parallelism story rests on quantities fixed by the pattern alone:
how many start states a chunk processor must speculate on (PaREM's feasible
start set), whether the forest stays bounded (ambiguity), how full the chunk
products run (density).  PR 6 *observes* the first of these at runtime
(``ParseResult.speculation``); this module computes all of them host-side
from the transition matrices, with no jax import, so they can gate admission
and pick backends before the first character arrives.

Four legs, all surfaced as one typed ``AnalysisReport``:

  feasible widths   ``feasible_width_bounds``: for each prefix depth d, the
                    max over length-d class sequences of the feasible
                    start-set size — the exact quantity
                    ``core/matrices.py::feasible_start_widths`` measures per
                    chunk at runtime, bounded statically by a frontier
                    fixpoint over backward set images (sound under a frontier
                    cap: capped depths carry the previous depth's bound,
                    which dominates by monotonicity).  ``width_bucket``
                    replays ``SparseBackend.bind_shape``'s pow2 + dense-
                    fallback rule on the depth-1 bound, so the report states
                    the S the sparse backend will actually carry.

  ambiguity         three-way verdict.  ``pathological`` = the AST has an
                    iterator with a nullable body (paper footnote 3: infinite
                    ambiguity — a single text with unboundedly many parse
                    trees).  Otherwise the position NFA's self-product
                    decides ``unambiguous`` vs ``finite``: two distinct
                    accepting runs on one word exist iff an off-diagonal
                    state pair is both reachable from the initial pairs and
                    co-reachable to the final pairs (Weber–Seidl).  The pair
                    search is budgeted; over budget the verdict degrades to
                    ``finite`` with ``ambiguity_exact=False`` (never to
                    ``unambiguous`` — the inexact path only over-reports).

  density           nnz densities of the per-class transition matrices, of
                    their union, and of the union's transitive saturation —
                    the worst-case fill of a long chunk product.

  cost model        per-backend per-character roofline terms from closed-form
                    op/byte counts (the same counts the backend docstrings
                    state) against ``analyze/roofline.py``'s machine
                    constants.  ``recommended_backend`` — the static choice
                    behind ``ParserConfig(backend="auto")`` — is the argmin
                    of the modeled time over {sparse (only when the width
                    bucket actually reduces), packed, jnp}; pallas is a
                    kernel variant of the dense path, selected explicitly,
                    never by auto.  Every candidate is bit-identical by the
                    conformance harness, so the choice is pure performance.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .roofline import HBM_BW, PEAK_FLOPS

#: Modeled uint32 lane-op throughput of the word backends (packed / sparse):
#: bitwise OR-AND folds run on the vector unit, not the MXU — modeled at
#: PEAK_FLOPS/8 lane ops/s (each op still touches 32 automaton cells, so the
#: word path nets out far ahead of dense matmul on both terms).
WORD_OPS = PEAK_FLOPS / 8.0

#: ``core/backend.py`` lane alignments, mirrored here so the analyzer stays
#: jax-free (validated against the real backends in tests/test_analyze.py).
_MIN_LANE_PAD = {"jnp": 32, "pallas": 128, "packed": 32, "sparse": 32}

#: ``SparseBackend``'s default width-bucket floor (core/backend.py).
_SPARSE_MIN_WIDTH = 8

#: Frontier cap of the per-depth width fixpoint: deeper refinement stops once
#: the set of distinct feasible sets exceeds this (the previous depth's bound
#: is carried — sound by monotonicity).
_WIDTH_FRONTIER_CAP = 512

#: Pair-search budget of the exact ambiguity test (visited product states).
_AMBIG_PAIR_BUDGET = 1 << 16


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def _lane_pad(ell: int, lane: int) -> int:
    return max(lane, ((ell + lane - 1) // lane) * lane)


# ---------------------------------------------------------- feasible widths


def feasible_width_bounds(
    N: np.ndarray, depth: int, cap: int = _WIDTH_FRONTIER_CAP
) -> Tuple[List[int], bool]:
    """Per-depth static feasible-start width bounds of one automaton.

    ``bounds[d-1]`` = max over all length-d REAL-class sequences of the
    feasible start-set size |{s : the sequence is readable from s}| — the
    static ceiling on what ``feasible_start_widths`` observes for any chunk
    whose first d characters are real.  (A chunk with r < d real leading
    characters is bounded by ``bounds[r-1]``: trailing PADs are identity, so
    its feasible set IS a depth-r set.  ``bounds[0]`` bounds every chunk.)

    Computed as a frontier fixpoint: the depth-d feasible sets are exactly
    the backward images ``pre_a(S)`` of the depth-(d-1) sets.  Feasible sets
    shrink with depth (each length-d set is contained in its length-(d-1)
    prefix's set), so the per-depth max is non-increasing — when the
    deduplicated frontier outgrows ``cap``, refinement stops and the previous
    bound carries forward, keeping the result sound.  Returns
    ``(bounds, exact)``; ``exact`` is False once a carry happened.
    """
    N = np.asarray(N) > 0
    real = N[:-1]                       # PAD (last class index) excluded
    A = real.shape[0]
    L = real.shape[-1]
    if A == 0 or depth < 1:
        return [L] * max(depth, 0), True
    frontier = {np.ones(L, dtype=bool).tobytes()}
    bounds: List[int] = []
    exact = True
    for _ in range(depth):
        new: Dict[bytes, int] = {}
        for key in frontier:
            S = np.frombuffer(key, dtype=bool)
            for a in range(A):
                # pre_a(S) = {j : ∃ i ∈ S with N[a][i, j]} — the same
                # backward step feasible_start_widths folds per chunk
                T = real[a][S].any(axis=0)
                new.setdefault(T.tobytes(), int(T.sum()))
        bound = max(new.values()) if new else 0
        if bounds and bound > bounds[-1]:   # numeric safety; monotone by math
            bound = bounds[-1]
        bounds.append(bound)
        if len(new) > cap:
            exact = False
            bounds.extend([bound] * (depth - len(bounds)))
            break
        frontier = set(new)
    return bounds, exact


def sparse_width_bucket(
    raw_width: int, ell_pad: int, min_width: int = _SPARSE_MIN_WIDTH
) -> int:
    """``SparseBackend.bind_shape``'s static product-row count S, replayed
    host-side: pow2 bucket of the depth-1 bound (floor ``min_width``), dense
    fallback S = ℓp once the bucket reaches ℓp."""
    S = _next_pow2(max(min_width, int(raw_width), 1))
    return ell_pad if S >= ell_pad else S


# ---------------------------------------------------------------- ambiguity


def _product_closure(
    delta: List[Dict[int, Tuple[int, ...]]],
    seeds,
    alive: np.ndarray,
    budget: int,
):
    """Reachable pair set of the NFA self-product from ``seeds`` (pairs are
    stored with p <= q; the product is symmetric).  Returns (pairs, complete):
    ``complete`` False when the budget stopped the search."""
    seen = set()
    stack = []
    for p, q in seeds:
        if not (alive[p] and alive[q]):
            continue
        pair = (p, q) if p <= q else (q, p)
        if pair not in seen:
            seen.add(pair)
            stack.append(pair)
    while stack:
        if len(seen) > budget:
            return seen, False
        p, q = stack.pop()
        dp, dq = delta[p], delta[q]
        for cls, ps in dp.items():
            qs = dq.get(cls)
            if qs is None:
                continue
            for np_ in ps:
                if not alive[np_]:
                    continue
                for nq in qs:
                    if not alive[nq]:
                        continue
                    pair = (np_, nq) if np_ <= nq else (nq, np_)
                    if pair not in seen:
                        seen.add(pair)
                        stack.append(pair)
    return seen, True


def nfa_ambiguous(nfa, budget: int = _AMBIG_PAIR_BUDGET) -> Tuple[bool, bool]:
    """(ambiguous, exact) — does some word have two distinct accepting runs?

    Standard self-product criterion on the trimmed automaton: ambiguous iff
    an off-diagonal pair is reachable from the initial pairs AND co-reachable
    to the final pairs.  Budgeted: an overflowing pair search returns
    ``(True, False)`` — conservatively ambiguous, never falsely unambiguous.
    """
    # trim to useful states: forward-reachable ∧ co-reachable
    fwd = np.zeros(nfa.n_states, dtype=bool)
    stack = list(nfa.initial)
    for s in stack:
        fwd[s] = True
    while stack:
        s = stack.pop()
        for targets in nfa.delta[s].values():
            for t in targets:
                if not fwd[t]:
                    fwd[t] = True
                    stack.append(t)
    rev = nfa.reverse()
    bwd = np.zeros(nfa.n_states, dtype=bool)
    stack = list(rev.initial)
    for s in stack:
        bwd[s] = True
    while stack:
        s = stack.pop()
        for targets in rev.delta[s].values():
            for t in targets:
                if not bwd[t]:
                    bwd[t] = True
                    stack.append(t)
    alive = fwd & bwd

    starts = [s for s in nfa.initial if alive[s]]
    finals = [s for s in nfa.final if alive[s]]
    reach, r_ok = _product_closure(
        nfa.delta, ((p, q) for p in starts for q in starts), alive, budget
    )
    coreach, c_ok = _product_closure(
        rev.delta, ((p, q) for p in finals for q in finals), alive, budget
    )
    if not (r_ok and c_ok):
        return True, False
    both = reach & coreach
    return any(p != q for p, q in both), True


# ------------------------------------------------------------------ density


def density_profile(N: np.ndarray, max_iters: int = 8) -> Dict[str, float]:
    """Chunk-product fill model: per-class / union / saturated densities.

    ``saturation`` is the density of the transitive closure of the all-class
    union — the worst-case nnz fraction any chunk product ``N[y_k] ⊗ … ⊗
    N[y_1]`` can reach, however long the chunk (products only combine the
    per-class supports).  Iterated boolean squaring converges in ≤ log₂(ℓ)
    steps; ``max_iters`` caps the host work on degenerate automata.
    """
    N = np.asarray(N) > 0
    real = N[:-1]
    L = real.shape[-1]
    if real.shape[0] == 0 or L == 0:
        return {"class_mean": 0.0, "class_max": 0.0, "union": 0.0,
                "saturation": 0.0}
    per_class = real.reshape(real.shape[0], -1).mean(axis=1)
    union = real.any(axis=0)
    sat = union
    for _ in range(max_iters):
        f = sat.astype(np.float32)
        grown = sat | ((f @ f) > 0)
        if (grown == sat).all():
            break
        sat = grown
    return {
        "class_mean": float(per_class.mean()),
        "class_max": float(per_class.max()),
        "union": float(union.mean()),
        "saturation": float(sat.mean()),
    }


# --------------------------------------------------------------- cost model


def backend_cost_model(ell: int, width_bucket_32: int) -> Dict[str, Dict[str, float]]:
    """Per-character roofline terms of every registered backend, closed form.

    Op/byte counts per reach step (the dominant phase) follow each backend's
    stated complexity (``core/backend.py`` docstrings): dense 2ℓp³ flops over
    3 ℓp² f32 arrays; packed ℓp²·W uint32 lane ops over ~3 ℓp·W words;
    sparse S·ℓp·W lane ops over S·(1+W) product words + the ℓp·W table row.
    Dense flops rate ``PEAK_FLOPS``; word-op rate ``WORD_OPS``; bytes rate
    ``HBM_BW``.  ``t_total`` = max(compute, memory) — the roofline time the
    auto-selection minimizes.
    """
    out: Dict[str, Dict[str, float]] = {}
    for name in ("jnp", "pallas", "packed", "sparse"):
        lp = _lane_pad(ell, _MIN_LANE_PAD[name])
        W = lp // 32
        if name in ("jnp", "pallas"):
            ops = 2.0 * lp**3
            bytes_ = 3.0 * 4.0 * lp**2
            t_compute = ops / PEAK_FLOPS
        elif name == "packed":
            ops = float(lp * lp * W)
            bytes_ = 3.0 * 4.0 * lp * W
            t_compute = ops / WORD_OPS
        else:  # sparse: S product rows instead of ℓp (dense fallback S = ℓp)
            S = sparse_width_bucket(width_bucket_32, lp) if lp == _lane_pad(
                ell, 32
            ) else lp
            S = min(S, lp)
            ops = float(S * lp * W)
            bytes_ = 4.0 * (2.0 * S * (1 + W) + lp * W)
            t_compute = ops / WORD_OPS
        t_memory = bytes_ / HBM_BW
        out[name] = {
            "ops_per_char": ops,
            "bytes_per_char": bytes_,
            "t_compute": t_compute,
            "t_memory": t_memory,
            "t_total": max(t_compute, t_memory),
            "bottleneck": "compute" if t_compute >= t_memory else "memory",
        }
    return out


#: auto-selection candidates, in tie-break order (most reduced first);
#: pallas is a kernel variant of the dense path and is never auto-picked.
_AUTO_CANDIDATES = ("sparse", "packed", "jnp")


def choose_backend(cost: Dict[str, Dict[str, float]], reduced: bool) -> str:
    """Static backend choice: modeled-roofline argmin over the candidates.

    ``sparse`` competes only when ``reduced`` (its width bucket is strictly
    below ℓp — otherwise it IS dense packed with gather overhead).
    """
    candidates = [
        b for b in _AUTO_CANDIDATES if b != "sparse" or reduced
    ]
    return min(
        candidates,
        key=lambda b: (cost[b]["t_total"], _AUTO_CANDIDATES.index(b)),
    )


# ------------------------------------------------------------------- report


@dataclasses.dataclass
class AnalysisReport:
    """Typed static-analysis result — ``Parser.stats()["analysis"]``.

    Every field is computed from the pattern/matrices alone (host-side,
    jax-free); ``to_dict()`` is the JSON-able schema the ROADMAP documents.
    """

    pattern: Optional[str]        # None when only matrices were available
    ell: int                      # true segment count
    ell_pad: int                  # 32-lane padded ℓp (dense/packed/sparse)
    n_classes: int                # real char classes (PAD excluded)
    nullable: bool                # pattern accepts the empty text
    ambiguity: str                # "unambiguous" | "finite" | "pathological"
    ambiguity_exact: bool         # False: budgeted search degraded the verdict
    width_bounds: Tuple[int, ...]  # per-depth feasible-start bounds (d=1..D)
    width_exact: bool             # False: frontier cap carried a bound
    width_bucket: int             # sparse S: pow2 bucket of width_bounds[0]
    density: Dict[str, float]
    cost: Dict[str, Dict[str, float]]
    recommended_backend: str
    verdict: str                  # "ok" | "pathological"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["width_bounds"] = list(self.width_bounds)
        return d


def analyze_matrices(
    matrices,
    *,
    pattern: Optional[str] = None,
    depth: int = 4,
) -> AnalysisReport:
    """Analyze one automaton (``ParserMatrices``), optionally with its RE.

    ``pattern`` feeds the AST legs (nullability, the pathological-iterator
    check); without it those default to the matrices-only safe answers.
    ``depth`` is how many feasible-width bounds to compute (≥ the configured
    ``feasible_depth`` when driven by the facade).
    """
    from ..core.automata import build_nfa
    from ..core.regex import infinitely_ambiguous, nullable as re_nullable, parse_regex

    N = np.asarray(matrices.N)
    ell = matrices.n_segments
    n_real_classes = N.shape[0] - 1
    ell_pad = _lane_pad(ell, 32)

    ast = None
    if pattern is not None:
        try:
            ast = parse_regex(pattern)
        except Exception:
            ast = None
    is_nullable = re_nullable(ast) if ast is not None else bool(
        float(np.dot(matrices.I, matrices.F)) > 0
    )
    pathological = infinitely_ambiguous(ast) if ast is not None else False

    if pathological:
        ambiguity, exact = "pathological", True
    else:
        ambiguous, exact = nfa_ambiguous(build_nfa(matrices.table))
        ambiguity = "finite" if ambiguous else "unambiguous"

    depth = max(1, int(depth))
    bounds, width_exact = feasible_width_bounds(N, depth)
    bucket = sparse_width_bucket(bounds[0], ell_pad)
    cost = backend_cost_model(ell, bounds[0])
    recommended = choose_backend(cost, reduced=bucket < ell_pad)

    return AnalysisReport(
        pattern=pattern,
        ell=ell,
        ell_pad=ell_pad,
        n_classes=n_real_classes,
        nullable=bool(is_nullable),
        ambiguity=ambiguity,
        ambiguity_exact=exact,
        width_bounds=tuple(int(b) for b in bounds),
        width_exact=width_exact,
        width_bucket=int(bucket),
        density=density_profile(N),
        cost=cost,
        recommended_backend=recommended,
        verdict="pathological" if ambiguity == "pathological" else "ok",
    )


def analyze_pattern(pattern: str, *, depth: int = 4) -> AnalysisReport:
    """Analyze an RE string: build its matrices, then ``analyze_matrices``."""
    from ..core.matrices import build_matrices
    from ..core.segments import compute_segments

    return analyze_matrices(
        build_matrices(compute_segments(pattern)), pattern=pattern, depth=depth
    )


@lru_cache(maxsize=256)
def cached_report(pattern: str, depth: int = 4) -> AnalysisReport:
    """Pattern-keyed memoized report for repeat callers (fleet admission,
    ``backend="auto"`` resolution).  Treat the result as read-only — it is
    shared across callers."""
    return analyze_pattern(pattern, depth=depth)


def resolve_auto_backend(pattern: str, depth: int = 1) -> str:
    """``backend="auto"`` resolution for pattern-keyed callers (the fleet):
    the report's ``recommended_backend``, memoized per (pattern, depth)."""
    return cached_report(pattern, max(4, depth)).recommended_backend
