"""Compiled-program lint: invariants every jitted phase program must hold.

The fleet shares its jitted programs across tenants (one per shape bucket —
that is the whole compile-count story), so one rotted program slows every
tenant on its bucket.  Three classes of rot have bitten jax codebases of this
shape, all detectable statically from the jaxpr / optimized HLO without
running a single batch:

  host-callback   a ``pure_callback`` / ``io_callback`` / debug print left
                  inside a jitted phase body forces a device→host round trip
                  per invocation — instrumentation must stay at trace time
                  (the engine's ``notify()`` pattern) or on the host side of
                  the phase seams.
  f64             a stray float64 / complex128 promotion (x64 mode leaking
                  in, a numpy scalar widening a weak type) doubles reach's
                  bytes and halves MXU throughput.
  dynamic-shape   a non-static dimension breaks the shape-bucketing contract
                  (programs are compiled per (c, k) bucket; dynamic dims
                  would recompile per input or fall off the fast path).

``lint_engine`` walks every phase program of an engine at given buckets,
linting both the traced jaxpr (recursively through pjit/scan/cond
sub-jaxprs) and the backend-compiled optimized HLO text, and returns typed
``LintFinding``s.  ``scripts/analyze_gate.py`` runs it over every registered
backend and fails CI on any finding; its seeded self-tests push known-bad
programs through the same functions so the gate itself cannot rot.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Iterator, List, Sequence, Tuple

_BAD_DTYPES = ("float64", "complex128")

#: substrings of HLO custom-call lines that indicate a host round trip
_HLO_CALLBACK_MARKERS = ("callback", "outside_compilation", "host_compute")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One violated invariant in one compiled program."""

    rule: str      # "host-callback" | "f64" | "dynamic-shape"
    program: str   # e.g. "packed:reach@4x32"
    detail: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.program}: {self.detail}"


# ------------------------------------------------------------- jaxpr walk


def _sub_jaxprs(params: Dict[str, Any]) -> Iterator[Any]:
    """Yield every inner Jaxpr hiding in an eqn's params (pjit's ``jaxpr``,
    scan/while bodies, cond ``branches``, custom_jvp ``call_jaxpr`` …) —
    duck-typed so it tracks jax versions."""
    for v in params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for item in items:
            if hasattr(item, "eqns"):          # raw Jaxpr
                yield item
            elif hasattr(item, "jaxpr"):       # ClosedJaxpr
                yield item.jaxpr


def _walk_eqns(jaxpr) -> Iterator[Any]:
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from _walk_eqns(sub)


def lint_jaxpr(closed_jaxpr, program: str) -> List[LintFinding]:
    """Lint one traced program (a ClosedJaxpr) against all three rules."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    findings: List[LintFinding] = []

    def check_aval(aval, where: str) -> None:
        dtype = getattr(aval, "dtype", None)
        if dtype is not None and str(dtype) in _BAD_DTYPES:
            findings.append(
                LintFinding("f64", program, f"{where} has dtype {dtype}")
            )
        for dim in getattr(aval, "shape", ()):
            if not isinstance(dim, int):
                findings.append(
                    LintFinding(
                        "dynamic-shape",
                        program,
                        f"{where} has non-static dim {dim!r}",
                    )
                )

    for var in jaxpr.invars + jaxpr.outvars:
        check_aval(getattr(var, "aval", None) or var, "program boundary")

    for eqn in _walk_eqns(jaxpr):
        name = eqn.primitive.name
        if "callback" in name or name in ("outside_call", "host_call"):
            findings.append(
                LintFinding(
                    "host-callback",
                    program,
                    f"primitive '{name}' runs on the host inside the jitted body",
                )
            )
        for var in eqn.outvars:
            check_aval(getattr(var, "aval", None), f"'{name}' output")
    return findings


# --------------------------------------------------------------- HLO scan


def lint_hlo_text(hlo_text: str, program: str) -> List[LintFinding]:
    """Lint optimized HLO text: catches promotions the compiler *kept* (a
    jaxpr-level f64 constant-folded away is fine; one surviving to HLO is
    real bytes) and host custom-calls that entered below the jaxpr level."""
    findings: List[LintFinding] = []
    for lineno, line in enumerate(hlo_text.splitlines(), 1):
        if "f64[" in line or "c128[" in line:
            findings.append(
                LintFinding(
                    "f64", program, f"HLO line {lineno}: {line.strip()[:120]}"
                )
            )
        if "custom-call" in line and any(
            marker in line for marker in _HLO_CALLBACK_MARKERS
        ):
            findings.append(
                LintFinding(
                    "host-callback",
                    program,
                    f"HLO line {lineno}: {line.strip()[:120]}",
                )
            )
    return findings


# ------------------------------------------------------------ engine lint


def _phase_programs(engine, c: int, k: int):
    """The engine's separately-jitted phase programs with abstract args at
    bucket (c, k) — the exact lowering recipe of
    ``ParserEngine.phase_static_cost``."""
    import jax
    import jax.numpy as jnp

    t = engine.tables
    eye = engine.backend.identity_product(t.ell_pad, dtype=t.N.dtype)
    chunks_sds = jax.ShapeDtypeStruct((c, k), jnp.int32)
    P_sds = jax.ShapeDtypeStruct((c,) + eye.shape, eye.dtype)
    J_sds = jax.ShapeDtypeStruct((c, t.ell_pad), jnp.float32)
    phases = engine.phases
    return {
        "reach": (phases.reach, (t.N, chunks_sds)),
        "join": (phases.join, (P_sds, t.I, t.F)),
        "build_merge": (phases.build_merge, (t.N, chunks_sds, J_sds, J_sds)),
    }


def lint_program(prog, args: Tuple, program: str) -> List[LintFinding]:
    """Lint one jittable callable at abstract args: jaxpr walk + compiled
    optimized-HLO scan.  ``args`` may mix concrete arrays and
    ``ShapeDtypeStruct``s (anything ``.lower`` accepts)."""
    import jax

    findings = lint_jaxpr(jax.make_jaxpr(prog)(*args), program)
    findings += lint_hlo_text(prog.lower(*args).compile().as_text(), program)
    return findings


def lint_engine(
    engine,
    buckets: Sequence[Tuple[int, int]] = ((4, 32),),
    label: str = "",
) -> List[LintFinding]:
    """Lint every phase program of one engine at the given (c, k) buckets.

    Programs are named ``<label>:<phase>@<c>x<k>``.  Each novel bucket costs
    one trace + compile per phase (the same programs real traffic at that
    bucket would compile anyway — jit caches by shape, so a warm engine
    pays nothing extra).
    """
    findings: List[LintFinding] = []
    for c, k in buckets:
        for phase, (prog, args) in _phase_programs(engine, int(c), int(k)).items():
            findings += lint_program(prog, args, f"{label}:{phase}@{c}x{k}")
    return findings


def lint_report(findings: Iterable[LintFinding]) -> str:
    """Human-readable multi-line summary (empty string when clean)."""
    return "\n".join(str(f) for f in findings)
