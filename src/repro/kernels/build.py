"""Fused builder&merger kernels (paper Fig. 14) — one chunk, one M buffer.

Two sequential ``pallas_call``s sharing one buffer via input/output aliasing
(the paper's single-array memory optimization, expressed safely for TPU —
Pallas output blocks are not reloaded on revisit, so the read-modify-write
backward pass must take M as an aliased *input*):

  build_fwd   forward frontier mat-vec scan from the join entry ``J_{i-1}``;
              grid step t writes ``M[t] = clamp(N[x_t] @ frontier)``.
  merge_bwd   backward scan with *transposed* matrices from the next chunk's
              backward entry ``Ĵ_{i+1}``; grid step s visits t = k-1-s and
              ANDs in place: ``M[t] *= β_{t+1}``, then ``β ← N[x_t]ᵀ β``.

Transition matrices are scalar-prefetch-selected per step (the chunk's class
ids drive the BlockSpec index_map), so the next N block is DMA'd while the
current mat-vec runs — the DMA/compute overlap a CPU table-walk cannot express.
The frontier is a (1, ℓ) VMEM scratch carried across grid steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _build_fwd_kernel(ids_ref, n_ref, jf_ref, m_ref, fr_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        fr_ref[...] = jf_ref[...]

    # frontier <- clamp(N[x_t] @ frontier)  (row-vector form: fr @ Nᵀ)
    nf = jnp.minimum(
        jnp.dot(fr_ref[...], n_ref[0].T, preferred_element_type=jnp.float32), 1.0
    )
    fr_ref[...] = nf
    m_ref[...] = nf.astype(m_ref.dtype)


def _merge_bwd_kernel(ids_ref, n_ref, jb_ref, m_in_ref, m_ref, fr_ref):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        fr_ref[...] = jb_ref[...]

    # visiting t = k-1-s:  M[t] *= β_{t+1};  β ← clamp(N[x_t]ᵀ @ β)
    m_ref[...] = m_in_ref[...] * fr_ref[...].astype(m_ref.dtype)
    nb = jnp.minimum(
        jnp.dot(fr_ref[...], n_ref[0], preferred_element_type=jnp.float32), 1.0
    )
    fr_ref[...] = nb


def build_merge_chunk(
    N: jnp.ndarray,          # (A+1, ℓ, ℓ) {0,1} — PAD class = identity
    ids: jnp.ndarray,        # (k,) int32 char classes of the chunk
    entry_f: jnp.ndarray,    # (ℓ,) forward join entry J_{i-1}
    entry_b: jnp.ndarray,    # (ℓ,) backward join entry Ĵ_{i+1}
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Clean SLPF columns M (k, ℓ) of one chunk (paper Fig. 14)."""
    _, ell, _ = N.shape
    k = ids.shape[0]

    fwd_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, ell, ell), lambda t, ids: (ids[t], 0, 0)),
            pl.BlockSpec((1, ell), lambda t, ids: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ell), lambda t, ids: (t, 0)),
        scratch_shapes=[pltpu.VMEM((1, ell), jnp.float32)],
    )
    m_fwd = pl.pallas_call(
        _build_fwd_kernel,
        grid_spec=fwd_spec,
        out_shape=jax.ShapeDtypeStruct((k, ell), N.dtype),
        interpret=interpret,
    )(ids, N, entry_f[None])

    bwd_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, ell, ell), lambda s, ids: (ids[k - 1 - s], 0, 0)),
            pl.BlockSpec((1, ell), lambda s, ids: (0, 0)),
            pl.BlockSpec((1, ell), lambda s, ids: (k - 1 - s, 0)),   # M (aliased in)
        ],
        out_specs=pl.BlockSpec((1, ell), lambda s, ids: (k - 1 - s, 0)),
        scratch_shapes=[pltpu.VMEM((1, ell), jnp.float32)],
    )
    return pl.pallas_call(
        _merge_bwd_kernel,
        grid_spec=bwd_spec,
        out_shape=jax.ShapeDtypeStruct((k, ell), N.dtype),
        input_output_aliases={3: 0},  # M buffer written in place (+1 for prefetch arg)
        interpret=interpret,
    )(ids, N, entry_b[None], m_fwd)
