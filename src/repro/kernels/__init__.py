"""Pallas TPU kernels for the parser's two hot loops (DESIGN §2).

The paper's compute hot-spots are the reach phase (per-chunk ME-DFA
speculation ≡ Boolean-semiring matrix chain product) and the fused
builder&merger (Fig. 14).  ``packed_reach.py`` is the word-native (uint32
OR-AND) form of the reach kernel for the bit-packed backend — 32× less
HBM↔VMEM traffic per step.  Each kernel ships with:

  * ``<name>.py``  — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling;
  * ``ops.py``     — jit'd public wrappers (interpret=True on CPU);
  * ``ref.py``     — pure-jnp oracles the kernels are verified against.
"""
