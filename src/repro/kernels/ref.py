"""Pure-jnp oracles for the Pallas kernels (verified in tests/test_kernels.py).

These mirror the engine's reference implementations with the kernels' exact
signatures, so every kernel sweep asserts ``kernel(...) ≈ ref(...)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def semiring_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(
        jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)), 1.0
    ).astype(a.dtype)


def reach_chunk_product_ref(N: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    ell = N.shape[-1]

    def step(P, cls):
        return jnp.minimum(
            jnp.dot(N[cls].astype(jnp.float32), P, preferred_element_type=jnp.float32),
            1.0,
        ), None

    P, _ = jax.lax.scan(step, jnp.eye(ell, dtype=jnp.float32), ids)
    return P.astype(N.dtype)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window=None,
) -> jnp.ndarray:
    """Naive softmax attention oracle: q/k/v (b, L, h, hd), kv == q heads."""
    import math

    b, L, h, hd = q.shape
    Lk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(hd)
    qpos = jnp.arange(L)[:, None]
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((L, Lk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(q.dtype)


def ssd_chunk_ref(xdt, cs, B, C, S_prev):
    """Oracle for the fused SSD intra-chunk kernel (per flattened program)."""
    q = xdt.shape[1]
    csq = cs[..., 0]                                            # (P, q)
    Lm = csq[:, :, None] - csq[:, None, :]
    iota = jnp.arange(q)
    Lmask = jnp.where(iota[:, None] >= iota[None, :], jnp.exp(Lm), 0.0)
    CB = jnp.einsum("pin,pjn->pij", C.astype(jnp.float32), B.astype(jnp.float32))
    y_intra = jnp.einsum("pij,pjh->pih", Lmask * CB, xdt.astype(jnp.float32))
    y_inter = jnp.exp(csq)[..., None] * jnp.einsum(
        "pin,phn->pih", C.astype(jnp.float32), S_prev.astype(jnp.float32)
    )
    w = jnp.exp(csq[:, -1:] - csq)                              # (P, q)
    S_c = jnp.einsum("pqn,pqh->pnh", w[..., None] * B.astype(jnp.float32),
                     xdt.astype(jnp.float32))
    return y_intra + y_inter, S_c


def build_merge_chunk_ref(
    N: jnp.ndarray, ids: jnp.ndarray, entry_f: jnp.ndarray, entry_b: jnp.ndarray
) -> jnp.ndarray:
    Nf = N.astype(jnp.float32)

    def fstep(v, cls):
        nv = jnp.minimum(Nf[cls] @ v, 1.0)
        return nv, nv

    _, fwd = jax.lax.scan(fstep, entry_f.astype(jnp.float32), ids)

    def bstep(v, cls):
        nv = jnp.minimum(Nf[cls].T @ v, 1.0)
        return nv, nv

    _, bwd_rev = jax.lax.scan(bstep, entry_b.astype(jnp.float32), ids[::-1])
    bwd = bwd_rev[::-1]
    bwd_for_merge = jnp.concatenate(
        [bwd[1:], entry_b.astype(jnp.float32)[None]], axis=0
    )
    return (fwd * bwd_for_merge).astype(N.dtype)
