"""Fused flash-attention forward kernel (Pallas TPU).

§Perf identified the memory-bound attention cells' structural fix: the
pure-jnp blockwise attention materializes ~6 score-sized f32 buffers per
(q, kv) block between XLA fusion boundaries (arithmetic intensity ≈ 3
flops/byte vs the ≈ 240 balance point of a v5e).  This kernel fuses the
whole inner loop — scores, mask, online softmax, AV accumulation — into one
VMEM-resident pipeline: HBM traffic collapses to reading each q/k/v block
once and writing each output block once.

Structure: grid (b·h, nq, nk), innermost nk sequential; BlockSpec tiles
q (qb, hd), k/v (kb, hd) in VMEM; the online-softmax state (m, l, acc) lives
in VMEM scratch across the nk loop and the normalized output is written on
the last nk step.  Masks are built from block-local iota + program ids —
inside the kernel there is nothing for XLA to hoist (§Perf H2 by
construction).  Causal + sliding-window supported; fully-masked blocks skip
their matmuls via ``pl.when`` (the TPU grid is sequential, so skipped steps
cost only the (prefetched) DMA).

Backward: ``flash_attention`` in ops.py wraps this forward in a
``jax.custom_vjp`` whose backward recomputes via the pure-jnp oracle
(flash-style recompute — the standard memory/compute trade), so the kernel
is usable under ``jax.grad`` today; a fused Pallas backward is the
documented next kernel.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, nk: int, qb: int, kb: int, causal: bool, window: Optional[int],
    scale: float, softcap: Optional[float],
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * qb
    k_start = ki * kb
    # block-level reachability: any (qpos >= kpos) within window?
    live = True
    if causal:
        live = k_start <= q_start + qb - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + kb - 1 > q_start - window)

    @pl.when(live)
    def _step():
        s = jax.lax.dot_general(
            q_ref[...], k_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                           # (qb, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        mask = jnp.ones((qb, kb), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                 # (qb, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                              # masked → ~0
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,            # (bh, Lq, hd) — batch·heads flattened
    k: jnp.ndarray,            # (bh, Lk, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 512,
    k_block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, Lq, hd = q.shape
    Lk = k.shape[1]
    qb = min(q_block, Lq)
    while Lq % qb:
        qb //= 2
    kb = min(k_block, Lk)
    while Lk % kb:
        kb //= 2
    nq, nk = Lq // qb, Lk // kb
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_fwd_kernel, nk=nk, qb=qb, kb=kb, causal=causal,
        window=window, scale=scale, softcap=None,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((None, qb, hd), lambda b, i, j: (b, i, 0)),   # None: squeeze
            pl.BlockSpec((None, kb, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, kb, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, qb, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, Lq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
