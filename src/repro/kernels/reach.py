"""Reach-phase kernel: per-chunk transition chain product, scalar-prefetched.

Computes ``P = N[x_k] ⊗ … ⊗ N[x_1]`` for one chunk — the paper's Eq. (6) with
all ℓ ME-DFA entries evaluated simultaneously as matrix columns (DESIGN §2).

TPU-native structure: the chunk's char-class ids are a *scalar-prefetch*
operand; the grid walks the chunk sequentially and each step's BlockSpec
index_map selects ``N[x_t]`` — so the next step's transition matrix is DMA'd
from HBM into VMEM while the current product runs on the MXU (the classic
lookahead the paper's table-walk cannot express).  The running product lives
in a VMEM scratch across grid steps.

For ℓ ≤ ~1024 an (ℓ, ℓ) fp32 tile fits VMEM (1024²·4 = 4 MiB); larger
automata shard the segment dimension over 'model' (engine) before kerneling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reach_kernel(ids_ref, n_ref, out_ref, acc_ref, *, k: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        ell = acc_ref.shape[0]
        eye = (
            jax.lax.broadcasted_iota(jnp.int32, (ell, ell), 0)
            == jax.lax.broadcasted_iota(jnp.int32, (ell, ell), 1)
        )
        acc_ref[...] = eye.astype(jnp.float32)

    # P <- N[x_t] ⊗ P   (OR-AND: fp32 matmul + clamp)
    acc_ref[...] = jnp.minimum(
        jnp.dot(n_ref[0], acc_ref[...], preferred_element_type=jnp.float32), 1.0
    )

    @pl.when(t == k - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def reach_chunk_product(
    N: jnp.ndarray,          # (A+1, ℓ, ℓ) {0,1} — PAD class = identity
    ids: jnp.ndarray,        # (k,) int32 char classes of the chunk
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chunk product P (ℓ, ℓ).  ℓ must be 128-aligned (EngineTables pad)."""
    _, ell, ell2 = N.shape
    assert ell == ell2
    k = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            # one (1, ℓ, ℓ) block of N per step, chosen by the prefetched ids
            pl.BlockSpec((1, ell, ell), lambda t, ids: (ids[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((ell, ell), lambda t, ids: (0, 0)),
        scratch_shapes=[pltpu.VMEM((ell, ell), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_reach_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ell, ell), N.dtype),
        interpret=interpret,
    )(ids, N)
