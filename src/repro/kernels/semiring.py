"""Boolean OR-AND semiring matmul on the MXU — the reach phase's combine op.

``C = clamp(A ⊗ B)`` over {0,1} matrices: ``matmul`` with fp32 accumulation
followed by ``min(acc, 1)`` — exact (counts never exceed ℓ < 2²⁴).  This is
the TPU-native replacement for the paper's per-entry DFA lookups: one matrix
product evaluates all ℓ speculative ME-DFA entries simultaneously (DESIGN §2).

Tiling: grid (M/bm, N/bn, K/bk); A tiles (bm, bk), B tiles (bk, bn) in VMEM,
fp32 accumulator lives in a VMEM scratch across the K-loop (the innermost grid
dim is sequential on TPU), clamped and written on the last K step.  Block
sizes default to 128 — MXU-aligned (128×128 systolic array).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _semiring_mm_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = jnp.minimum(acc_ref[...], 1.0).astype(out_ref.dtype)


def semiring_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Boolean-semiring product of (m, k) ⊗ (k, n) {0,1} matrices."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shapes ({m},{k})x({k},{n}) must tile by ({bm},{bk},{bn}); "
        "pad with EngineTables(lane_pad=128)"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_semiring_mm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
