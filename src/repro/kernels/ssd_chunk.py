"""Fused SSD intra-chunk kernel (Pallas TPU) — Mamba-2's reach/build hot spot.

One program computes, for one (batch, head, chunk) triple, entirely in VMEM:

    CB   = C Bᵀ                      (q, q)   MXU
    L    = exp(cs_i − cs_j) · [i≥j]  (q, q)   VPU (block-local iota mask)
    y    = (L ∘ CB) · xdt  +  exp(cs) ∘ (C · S_prevᵀ)      — intra + inter
    S_c  = (exp(cs_last − cs) ∘ B)ᵀ · xdt                   — state contribution

which is exactly the "reach" (chunk summary S_c) and "build" (output y given
the joined entry state S_prev) of the paper's schema on the SSD monoid
(DESIGN §4).  The pure-jnp path (models/mamba.py) materializes L, CB and the
masked product to HBM between fusions; here they never leave VMEM.

Footprint per program (q=256, hp=64, n=128, f32): two (q,q) tiles + operands
≈ 0.9 MiB — comfortably inside VMEM; all matmul dims are 128-multiples.

The inter-chunk join (exclusive scan of (decay, S_c) pairs) stays in
``core/scan.py`` — it is the cross-device phase and belongs to the runtime,
not the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(xdt_ref, cs_ref, b_ref, c_ref, sprev_ref, y_ref, snew_ref):
    q, hp = xdt_ref.shape
    n = b_ref.shape[1]
    cs = cs_ref[...]                                       # (q, 1) f32
    # decay-masked quadratic form
    Lm = cs - cs.reshape(1, q)                             # cs_i - cs_j
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(Lm), 0.0)      # (q, q)
    CB = jax.lax.dot_general(
        c_ref[...], b_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (q, q)
    y_intra = jax.lax.dot_general(
        (L * CB).astype(xdt_ref.dtype), xdt_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                                      # (q, hp)
    # inter-chunk: exp(cs_i) · (C_i · S_prevᵀ)
    y_inter = jnp.exp(cs) * jax.lax.dot_general(
        c_ref[...], sprev_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (q, hp)
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)
    # state contribution: S_c = (w ∘ B)ᵀ · xdt   with w_j = exp(cs_last − cs_j)
    w = jnp.exp(cs[q - 1, 0] - cs)                         # (q, 1)
    snew_ref[...] = jax.lax.dot_general(
        (w * b_ref[...].astype(jnp.float32)).astype(xdt_ref.dtype), xdt_ref[...],
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(snew_ref.dtype)                               # (n, hp)


def ssd_chunk(
    xdt: jnp.ndarray,      # (P, q, hp) — P = b·nc·h flattened programs
    cs: jnp.ndarray,       # (P, q, 1) f32 cumulative decay logs
    B: jnp.ndarray,        # (P, q, n)
    C: jnp.ndarray,        # (P, q, n)
    S_prev: jnp.ndarray,   # (P, hp, n) joined entry states
    *,
    interpret: bool = False,
):
    """Returns (y (P, q, hp), S_c (P, n, hp))."""
    P, q, hp = xdt.shape
    n = B.shape[-1]
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((None, q, hp), lambda p: (p, 0, 0)),
            pl.BlockSpec((None, q, 1), lambda p: (p, 0, 0)),
            pl.BlockSpec((None, q, n), lambda p: (p, 0, 0)),
            pl.BlockSpec((None, q, n), lambda p: (p, 0, 0)),
            pl.BlockSpec((None, hp, n), lambda p: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, q, hp), lambda p: (p, 0, 0)),
            pl.BlockSpec((None, n, hp), lambda p: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, q, hp), jnp.float32),
            jax.ShapeDtypeStruct((P, n, hp), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, cs, B, C, S_prev)
