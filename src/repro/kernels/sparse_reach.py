"""Sparse reach kernel: gathered feasible-start rows, packed OR-AND fold.

The speculation-width-reduced twin of ``kernels/packed_reach.py``: instead of
folding all ℓp packed product rows through the chunk, it folds only the S
gathered feasible-start rows (``core/backend.py``'s sparse contract — the
rows whose start states survive the chunk's leading characters).  The caller
computes the feasible index set and materialises the start rows
``R0 = packed e_idx`` (S, W); the kernel owns the per-character fold

    R'[j] = OR_k bit_k(R[j]) · N_packed[x_t][k]

— identical word arithmetic to the packed kernel but over an (S, W) running
block, so each step's VPU work and VMEM residency shrink by ℓp/S.

TPU-native structure mirrors the packed kernel: the chunk's char-class ids
are a *scalar-prefetch* operand, the BlockSpec index map selects
``N_packed[x_t]`` per step (next class's rows DMA while the current step
computes), and the running (S, W) row block lives in a VMEM scratch across
grid steps, seeded from the R0 input at step 0.  HBM↔VMEM traffic per step
is unchanged (the ℓp·W transition rows still stream in); the *product* side
— scratch, output, and everything downstream (join stacks, streaming cache,
mesh all-gather) — pays S rows instead of ℓp.

Verified in interpret mode on CPU (bit-identical to the jnp gathered fold);
the (S, W) minor-dim retiling for real-TPU lane layouts rides the ROADMAP's
TPU benchmarking item with the other kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_WORD = 32


def _sparse_reach_kernel(ids_ref, r0_ref, np_ref, out_ref, acc_ref, *, k: int):
    t = pl.program_id(0)
    S, W = acc_ref.shape

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = r0_ref[...]          # packed e_idx feasible-start rows

    block = np_ref[0]                       # (ℓp, W) packed rows of N[x_t]
    acc = acc_ref[...]                      # (S, W) running gathered rows
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, _WORD), 1)

    def word_block(wk, new):
        # bits k = 32·wk … 32·wk+31 of every gathered row's target set
        words = jax.lax.dynamic_slice_in_dim(acc, wk, 1, 1)          # (S, 1)
        bits = (words >> shifts) & jnp.uint32(1)                     # (S, 32)
        mask = jnp.uint32(0) - bits
        rows = jax.lax.dynamic_slice_in_dim(block, wk * _WORD, _WORD, 0)
        sel = mask[:, :, None] & rows[None, :, :]                    # (S, 32, W)
        return new | jax.lax.reduce(
            sel, jnp.uint32(0), jax.lax.bitwise_or, (1,)
        )

    acc_ref[...] = jax.lax.fori_loop(0, W, word_block, jnp.zeros_like(acc))

    @pl.when(t == k - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def sparse_reach_rows(
    Np: jnp.ndarray,         # (A+1, ℓp, W) uint32 packed transition rows
    ids: jnp.ndarray,        # (k,) int32 char classes of the chunk
    R0: jnp.ndarray,         # (S, W) uint32 packed feasible-start rows e_idx
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gathered-row chunk fold (S, W) uint32.  ℓp must equal 32·W."""
    _, ell, W = Np.shape
    assert ell == W * _WORD, (Np.shape, "ℓp must be a multiple of 32")
    S = R0.shape[0]
    k = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            # the start rows, resident every step (read once at t == 0)
            pl.BlockSpec((S, W), lambda t, ids: (0, 0)),
            # one (1, ℓp, W) block of packed rows per step, chosen by the ids
            pl.BlockSpec((1, ell, W), lambda t, ids: (ids[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((S, W), lambda t, ids: (0, 0)),
        scratch_shapes=[pltpu.VMEM((S, W), jnp.uint32)],
    )
    return pl.pallas_call(
        functools.partial(_sparse_reach_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, W), jnp.uint32),
        interpret=interpret,
    )(ids, R0, Np)
