"""Packed reach kernel: per-chunk OR-AND chain product on uint32 bit-words.

The word-native twin of ``kernels/reach.py``: computes the chunk product
``P = N[x_k] ⊗ … ⊗ N[x_1]`` with every matrix in the bit-packed layout of
``core/matrices.py``'s packed semiring — ``(ℓp, W = ℓp/32)`` uint32 rows,
row ``col`` holding the packed target set of source ``col``.  One grid step
per character; the step is pure VPU word arithmetic (AND / OR / shift), no
MXU involved:

    P'[j] = OR_k bit_k(P[j]) · N_packed[x_t][k]

evaluated as a ``fori_loop`` over 32-bit word blocks of k so the live
unpacked intermediate is (ℓp, 32, W) words — one f32 matrix's worth of VMEM,
never ℓp³.

TPU-native structure mirrors the f32 kernel: the chunk's char-class ids are a
*scalar-prefetch* operand, the BlockSpec index map selects ``N_packed[x_t]``
per step (the next class's packed rows DMA while the current step computes),
and the running packed product lives in a VMEM scratch across grid steps.
The HBM↔VMEM traffic — the bandwidth-bound term of the reach phase — is 32×
smaller than the f32 kernel's: each step moves ℓp·W·4 = ℓp²/8 bytes of
transition rows instead of 4ℓp².

Verified in interpret mode on CPU (bit-identical to the jnp packed fold and
to the f32 oracle); on a real TPU the (ℓp, W) minor dim wants retiling to
the 128-lane layout for large ℓp — the ROADMAP's TPU benchmarking item.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.matrices import packed_identity

_WORD = 32


def _packed_reach_kernel(ids_ref, np_ref, out_ref, acc_ref, *, k: int):
    t = pl.program_id(0)
    lp, W = acc_ref.shape

    @pl.when(t == 0)
    def _init():
        # THE packed identity (plain jnp iota/where — legal in a kernel body)
        acc_ref[...] = packed_identity(lp)

    block = np_ref[0]                    # (ℓp, W) packed rows of N[x_t]
    acc = acc_ref[...]                   # (ℓp, W) running packed product
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, _WORD), 1)

    def word_block(wk, new):
        # bits k = 32·wk … 32·wk+31 of every product column
        words = jax.lax.dynamic_slice_in_dim(acc, wk, 1, 1)          # (ℓp, 1)
        bits = (words >> shifts) & jnp.uint32(1)                     # (ℓp, 32)
        mask = jnp.uint32(0) - bits
        rows = jax.lax.dynamic_slice_in_dim(block, wk * _WORD, _WORD, 0)
        sel = mask[:, :, None] & rows[None, :, :]                    # (ℓp, 32, W)
        return new | jax.lax.reduce(
            sel, jnp.uint32(0), jax.lax.bitwise_or, (1,)
        )

    acc_ref[...] = jax.lax.fori_loop(0, W, word_block, jnp.zeros_like(acc))

    @pl.when(t == k - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def packed_reach_chunk_product(
    Np: jnp.ndarray,         # (A+1, ℓp, W) uint32 packed transition rows
    ids: jnp.ndarray,        # (k,) int32 char classes of the chunk
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed chunk product (ℓp, W) uint32.  ℓp must equal 32·W."""
    _, ell, W = Np.shape
    assert ell == W * _WORD, (Np.shape, "ℓp must be a multiple of 32")
    k = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            # one (1, ℓp, W) block of packed rows per step, chosen by the ids
            pl.BlockSpec((1, ell, W), lambda t, ids: (ids[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((ell, W), lambda t, ids: (0, 0)),
        scratch_shapes=[pltpu.VMEM((ell, W), jnp.uint32)],
    )
    return pl.pallas_call(
        functools.partial(_packed_reach_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ell, W), jnp.uint32),
        interpret=interpret,
    )(ids, Np)
