"""Public jit'd wrappers for the parser kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python per grid step, validating the BlockSpec tiling and
index maps against the pure-jnp oracles.  On TPU backends the same calls lower
to Mosaic.  ``use_interpret()`` picks automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from typing import Optional

from . import build as _build
from . import flash_attention as _flash
from . import packed_reach as _packed_reach
from . import reach as _reach
from . import semiring as _semiring
from . import ssd_chunk as _ssd
from .ref import (
    build_merge_chunk_ref,
    flash_attention_ref,
    reach_chunk_product_ref,
    semiring_matmul_ref,
    ssd_chunk_ref,
)


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def semiring_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128):
    return _semiring.semiring_matmul(
        a, b, bm=bm, bn=bn, bk=bk, interpret=use_interpret()
    )


@jax.jit
def reach_chunk_product(N, ids):
    return _reach.reach_chunk_product(N, ids, interpret=use_interpret())


@jax.jit
def packed_reach_chunk_product(Np, ids):
    """Word-packed chunk product (uint32 OR-AND) — see packed_reach.py."""
    return _packed_reach.packed_reach_chunk_product(
        Np, ids, interpret=use_interpret()
    )


@jax.jit
def build_merge_chunk(N, ids, entry_f, entry_b):
    return _build.build_merge_chunk(
        N, ids, entry_f, entry_b, interpret=use_interpret()
    )


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention(q, k, v, causal=True, window=None, q_block=512, k_block=512):
    """Fused flash-attention forward (Pallas) with recompute backward.

    q/k/v: (b, L, h, hd); kv must already match the query head count (use the
    model's repeat/grouped layout upstream).  Under ``jax.grad`` the backward
    pass recomputes via the pure-jnp oracle (flash-style recompute)."""
    return _flash_fwd_public(q, k, v, causal, window, q_block, k_block)


def _flash_fwd_public(q, k, v, causal, window, q_block, k_block):
    b, L, h, hd = q.shape
    Lk = k.shape[1]
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, L, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, Lk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, Lk, hd)
    of = _flash.flash_attention_fwd(
        qf, kf, vf, causal=causal, window=window,
        q_block=q_block, k_block=k_block, interpret=use_interpret(),
    )
    return jnp.moveaxis(of.reshape(b, h, L, hd), 1, 2)


def _flash_fwd_vjp(q, k, v, causal, window, q_block, k_block):
    out = _flash_fwd_public(q, k, v, causal, window, q_block, k_block)
    return out, (q, k, v)


def _flash_bwd_vjp(causal, window, q_block, k_block, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal, window=window),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_flash_fwd_vjp, _flash_bwd_vjp)


@jax.jit
def ssd_chunk(xdt, cs, B, C, S_prev):
    """Fused SSD intra-chunk compute (y, state contribution) — see ssd_chunk.py."""
    return _ssd.ssd_chunk(xdt, cs, B, C, S_prev, interpret=use_interpret())


__all__ = [
    "semiring_matmul",
    "reach_chunk_product",
    "packed_reach_chunk_product",
    "build_merge_chunk",
    "flash_attention",
    "ssd_chunk",
    "ssd_chunk_ref",
    "semiring_matmul_ref",
    "reach_chunk_product_ref",
    "build_merge_chunk_ref",
    "flash_attention_ref",
    "use_interpret",
]
