"""Compatibility re-export: the roofline layer moved to ``repro.analyze``.

There is ONE analysis layer now — ``repro.analyze`` owns the hardware
constants, the ``Roofline`` dataclass and the compiled-artifact term
extraction (``analyze/roofline.py``); the static pattern/automaton analyzer
(``analyze/pattern.py``) and the compiled-program lint
(``analyze/program.py``) build on the same constants.  Existing
``repro.launch.analysis`` imports keep working through this shim.
"""

from __future__ import annotations

from ..analyze.roofline import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    collective_bytes,
    analyze_compiled,
    model_attn_flops,
    model_forward_flops,
    model_train_flops,
)

__all__ = [
    "HBM_BW",
    "ICI_BW",
    "PEAK_FLOPS",
    "Roofline",
    "analyze_compiled",
    "collective_bytes",
    "model_attn_flops",
    "model_forward_flops",
    "model_train_flops",
]
