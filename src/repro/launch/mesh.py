"""Production mesh construction (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  Shapes: single-pod ``(16, 16) = ('data', 'model')`` — one
v5e pod, 256 chips; multi-pod ``(2, 16, 16) = ('pod', 'data', 'model')`` —
512 chips.  The 'pod' axis carries batch (pure DP) and the parser's chunk
axis; it generalizes to any pod count (1000+ nodes) because nothing in the
sharding rules binds to its extent.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

try:  # jax ≥ 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def make_mesh_compat(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types across jax versions."""
    kwargs = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (1,), axes: Tuple[str, ...] = ("data",)):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = 1
    for s in shape:
        n *= s
    avail = len(jax.devices())
    if n > avail:
        shape, axes = (avail,), ("data",)
    return make_mesh_compat(shape, axes)


def make_parse_mesh(*, max_pods: int = 2):
    """('pod', 'data') host mesh over every available device — the distributed
    parser's test/bench shape (chunks over 'pod', batch over 'data').

    Uses ``max_pods`` pods when the device count divides evenly, else a single
    pod; a 1-device host degenerates to a (1, 1) mesh (the sharded programs
    still run, with no collectives resident)."""
    n = len(jax.devices())
    pods = max_pods if n >= max_pods and n % max_pods == 0 else 1
    return make_mesh_compat((pods, n // pods), ("pod", "data"))


def mesh_axes_size(mesh, axes) -> int:
    """Product of the named mesh axes' sizes (1 for the empty tuple)."""
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
