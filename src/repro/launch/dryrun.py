import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder host devices back the production meshes
(16×16 single-pod, 2×16×16 multi-pod).

Per cell this driver:
  1. builds the mesh and the shape-adapted sharding rules,
  2. materializes every input as a sharded ShapeDtypeStruct (no allocation),
  3. ``jit(step).lower(...).compile()`` — train_step for train shapes,
     prefill/decode serve steps for inference shapes,
  4. prints ``memory_analysis()`` (proves the program fits) and
     ``cost_analysis()`` (FLOPs / bytes for §Roofline),
  5. extracts collective bytes from the optimized HLO,
  6. appends the cell record to a JSON results file (resumable: cells already
     present are skipped unless --force).

Also includes the parser's own cell (``--arch regex-parser``): the multi-pod
chunked parse step over the production mesh (the paper's own workload).

Usage:
  python -m repro.launch.dryrun --all                     # every cell, both meshes
  python -m repro.launch.dryrun --arch mamba2-2.7b --shape long_500k --mesh pod
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np

RESULTS = Path(__file__).resolve().parents[3] / "experiments" / "dryrun_results.json"

PARSER_ARCH = "regex-parser"


def _load(path: Path) -> dict:
    if path.exists():
        return json.loads(path.read_text())
    return {}


def _save(path: Path, data: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(data, indent=1, sort_keys=True))
    tmp.replace(path)


def cell_key(arch: str, shape: str, mesh_name: str) -> str:
    return f"{arch}|{shape}|{mesh_name}"


def run_parser_cell(mesh, mesh_name: str, results: dict) -> None:
    """Dry-run the paper's own workload: chunked parallel parse over the mesh."""
    from ..core.engine import ParserEngine
    from ..core.reference import ParallelArtifacts
    from .analysis import analyze_compiled
    from .mesh import mesh_chips

    art = ParallelArtifacts.generate("(a|b|ab)+")
    eng = ParserEngine(art.matrices, lane_pad=128, mesh=mesh)
    tables = eng.tables
    chips = mesh_chips(mesh)
    # single-text route: the chunk dim takes every 'chunk' mesh axis
    chunk_rows = eng.dist.chunk_devices
    k = 1 << 20  # 1 Mi chars per chunk row
    t0 = time.time()
    lowered = eng.dist.chunk_program.lower(
        tables.N, tables.I, tables.F,
        jax.ShapeDtypeStruct((chunk_rows, k), np.int32),
    )
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis: flops={compiled.cost_analysis().get('flops', 0):.3e}")
    # "model flops" for the parser = the ME-DFA-equivalent useful work:
    # matvec build (2·n·ℓ²) fwd+bwd + reach matmul chain (2·n·ℓ³)
    ell = tables.ell_pad
    n = chunk_rows * k
    model_flops = 2.0 * n * ell * ell * (ell + 2)
    r = analyze_compiled(
        compiled, arch=PARSER_ARCH, shape=f"text_{chunk_rows}x{k}",
        mesh_name=mesh_name, chips=chips, model_flops=model_flops,
    )
    results[cell_key(PARSER_ARCH, f"text_{chunk_rows}x{k}", mesh_name)] = {
        **r.to_dict(), "compile_s": dt, "ok": True,
    }
    print(f"  [OK] {PARSER_ARCH} {mesh_name} compile={dt:.1f}s "
          f"bottleneck={r.bottleneck} frac={r.roofline_fraction:.3f}")


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, results: dict,
             seqs_per_device: int = 1) -> None:
    from ..configs import get_config
    from ..models.config import SHAPE_BY_NAME
    from ..parallel.sharding import MeshRules, adapt_rules_for
    from ..train.step import (
        abstract_decode_inputs,
        abstract_prefill_inputs,
        abstract_train_inputs,
        make_decode_step,
        make_prefill_step,
        make_train_step,
        plan_for,
    )
    from .analysis import (
        analyze_compiled,
        model_attn_flops,
        model_forward_flops,
        model_train_flops,
    )
    from .mesh import mesh_chips

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    skip = dict(cfg.skip_shapes).get(shape_name)
    key = cell_key(arch, shape_name, mesh_name)
    if skip:
        results[key] = {"ok": True, "skipped": skip}
        print(f"  [SKIP] {key}: {skip}")
        return

    rules = adapt_rules_for(cfg, mesh, MeshRules())
    tp = mesh.shape.get("model", 1)
    chips = mesh_chips(mesh)
    n_tokens = shape.global_batch * shape.seq_len

    t0 = time.time()
    if shape.kind == "train":
        plan = plan_for(cfg, shape, mesh, seqs_per_device=seqs_per_device)
        step = make_train_step(plan, mesh, rules)
        params, opt_state, batch = abstract_train_inputs(cfg, plan, mesh, rules)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt_state, batch)
        model_flops = model_train_flops(cfg.active_params(), n_tokens) + model_attn_flops(
            cfg, shape.seq_len, n_tokens, train=True
        )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh, rules, tp)
        params, tokens, extra = abstract_prefill_inputs(cfg, shape, mesh, rules, tp)
        args = (params, tokens) if extra is None else (params, tokens, extra)
        lowered = jax.jit(step).lower(*args)
        model_flops = model_forward_flops(cfg.active_params(), n_tokens) + model_attn_flops(
            cfg, shape.seq_len, n_tokens, train=False
        )
    else:  # decode
        step = make_decode_step(cfg, mesh, rules, tp)
        params, caches, token = abstract_decode_inputs(cfg, shape, mesh, rules, tp)
        lowered = jax.jit(step, donate_argnums=(1,)).lower(params, caches, token)
        # one new token per sequence; useful flops = 2·N_active·batch + cache attn
        model_flops = model_forward_flops(
            cfg.active_params(), shape.global_batch
        ) + model_attn_flops(
            cfg, shape.seq_len, shape.global_batch, train=False, decode=True
        )
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    print(f"  memory_analysis: {mem}")
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} bytes={ca.get('bytes accessed', 0):.3e}")
    r = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=model_flops,
    )
    results[key] = {**r.to_dict(), "compile_s": dt, "ok": True}
    print(
        f"  [OK] {key} compile={dt:.1f}s bottleneck={r.bottleneck} "
        f"t=(c {r.t_compute:.2e}, m {r.t_memory:.2e}, n {r.t_collective:.2e}) "
        f"useful={r.useful_ratio:.3f} frac={r.roofline_fraction:.3f}"
    )


def main(argv=None) -> int:
    from ..configs import ARCH_IDS
    from ..models.config import SHAPES
    from .mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help=f"one of {ARCH_IDS + [PARSER_ARCH]}")
    ap.add_argument("--shape", default=None, help="train_4k|prefill_32k|decode_32k|long_500k")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--seqs-per-device", type=int, default=1)
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args(argv)

    out = Path(args.out)
    results = _load(out)
    if args.list:
        for k, v in sorted(results.items()):
            status = "SKIP" if v.get("skipped") else ("OK" if v.get("ok") else "FAIL")
            print(f"{status:5s} {k}")
        return 0

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        for arch in archs:
            if arch == PARSER_ARCH:
                run_parser_cell(mesh, mesh_name, results)
                _save(out, results)
                continue
            for shape_name in shapes:
                key = cell_key(arch, shape_name, mesh_name)
                if not args.force and key in results and results[key].get("ok"):
                    print(f"  [CACHED] {key}")
                    continue
                print(f"== {key}")
                try:
                    run_cell(arch, shape_name, mesh, mesh_name, results,
                             seqs_per_device=args.seqs_per_device)
                except Exception as e:  # record failure, keep going
                    failures += 1
                    results[key] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
                    print(f"  [FAIL] {key}: {e}")
                    traceback.print_exc(limit=3)
                _save(out, results)
    _save(out, results)
    print(f"done; {failures} failures; results in {out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
