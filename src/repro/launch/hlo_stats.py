"""Trip-count-aware static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — with
scan-over-layers and microbatch accumulation that under-counts FLOPs by the
product of all trip counts (≈ 350× for a 22-layer, 16-microbatch step).  This
analyzer walks the computation graph of ``compiled.as_text()`` and returns
trip-count-weighted totals, per device (the text is the partitioned module):

  flops       2·M·N·K for dot ops (the compute-roofline term; elementwise ops
              are counted at 1 flop/output element — negligible next to dots
              but keeps vector-bound programs honest);
  bytes       HBM-traffic model: for every top-level op of a computation,
              operand bytes + output bytes; fusions count only their
              parameters/outputs (internals stay in registers/VMEM) — the
              memory-roofline term;
  collectives output bytes per collective kind (all-gather / all-reduce /
              reduce-scatter / all-to-all / collective-permute), trip-aware —
              the collective-roofline term.

Loop trip counts: scans lower to ``while`` whose condition compares the
induction variable against a constant; we take the largest integer constant in
the condition computation (exact for lax.scan/fori_loop with static bounds;
falls back to 1 and records the loop in ``unknown_trips``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0, "tuple": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

# op line:  %name = TYPE opcode(...)(operands), attrs
# NB: tuple result types may contain /*index=5*/ comments (with '='), but never
# nested parens — so the type is either "( ... first ')' )" or a single token.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$"
)
# computation headers sit at column 0 (optionally "ENTRY "), contain "->",
# and end with "{"; params may contain nested parens, so match loosely.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _array_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(total elements, total bytes) over all arrays in a type string."""
    elems = 0
    byts = 0
    for m in _ARRAY_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                      # operand list + attributes (raw)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # %name -> type str


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line and not line.startswith((" ", "\t")) and line.endswith("{") and "->" in line:
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = Computation(name=m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = Op(name=m.group(1), type_str=m.group(2), opcode=m.group(3), rest=m.group(4))
        # operand names: %foo tokens before the first "), " attr boundary
        paren = op.rest.split("),")[0]
        op.operands = re.findall(r"%([\w.\-]+)", paren)
        cur.ops.append(op)
        cur.shapes[op.name] = op.type_str
    return comps


def _called_comps(op: Op) -> List[str]:
    out = []
    for key in ("calls=", "body=", "condition=", "to_apply=", "branch_computations={"):
        for m in re.finditer(re.escape(key) + r"%?([\w.\-{}, %]+)", op.rest):
            blob = m.group(1)
            out.extend(re.findall(r"[\w.\-]+", blob.split(")")[0].split("}")[0]))
    return out


_ELEMENTWISE_ZERO = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "reshape",
    "broadcast", "transpose", "copy", "copy-start", "copy-done", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "reverse",
    "iota", "gather", "scatter", "sort", "rng", "rng-bit-generator",
    "after-all", "partition-id", "replica-id", "custom-call", "convert",
    "reduce", "select", "compare", "while", "conditional", "call", "fusion",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "optimization-barrier", "domain", "send", "recv",
    "send-done", "recv-done", "infeed", "outfeed",
}


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_elems, _ = _array_elems_bytes(op.type_str)
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if mc and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        am = _ARRAY_RE.search(lhs_type)
        if am and am.group(2):
            dims = [int(d) for d in am.group(2).split(",")]
            for ci in mc.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    # rough: 2 * output elems * (kernel spatial * in_features)
    out_elems, _ = _array_elems_bytes(op.type_str)
    if len(op.operands) >= 2:
        _, kb = _array_elems_bytes(shapes.get(op.operands[1], ""))
        ke, _ = _array_elems_bytes(shapes.get(op.operands[1], ""))
        return 2.0 * out_elems * max(ke, 1) ** 0.5  # conservative
    return 2.0 * out_elems


@dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_count: float = 0.0
    unknown_trips: int = 0

    def scaled(self, mult: float) -> "HloStats":
        return HloStats(
            flops=self.flops * mult,
            bytes=self.bytes * mult,
            coll={k: v * mult for k, v in self.coll.items()},
            coll_count=self.coll_count * mult,
            unknown_trips=self.unknown_trips,
        )

    def add(self, other: "HloStats") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] += v
        self.coll_count += other.coll_count
        self.unknown_trips += other.unknown_trips

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


class Analyzer:
    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        self._memo: Dict[str, HloStats] = {}
        self._trip_memo: Dict[str, int] = {}

    # ---- trip count of a while given its condition computation ------------
    def trip_count(self, cond_name: str) -> Optional[int]:
        if cond_name in self._trip_memo:
            return self._trip_memo[cond_name]
        comp = self.comps.get(cond_name)
        best: Optional[int] = None
        if comp is not None:
            consts = []
            for op in comp.ops:
                if op.opcode == "constant":
                    m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
                    if m:
                        consts.append(int(m.group(1)))
                if op.opcode == "fusion":
                    for sub in _called_comps(op):
                        c2 = self.comps.get(sub)
                        if c2:
                            for o2 in c2.ops:
                                if o2.opcode == "constant":
                                    m = re.search(r"constant\((-?\d+)\)", "constant(" + o2.rest)
                                    if m:
                                        consts.append(int(m.group(1)))
            pos = [c for c in consts if c > 0]
            if pos:
                best = max(pos)
        if best is not None:
            self._trip_memo[cond_name] = best
        return best

    # ---- flops INSIDE a computation (recursing into fusions) --------------
    def _fusion_flops(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, comp.shapes)
            elif op.opcode == "convolution":
                total += _conv_flops(op, comp.shapes)
            elif op.opcode == "fusion" or op.opcode == "call":
                for sub in _called_comps(op):
                    total += self._fusion_flops(sub)
            elif op.opcode not in _ELEMENTWISE_ZERO:
                elems, _ = _array_elems_bytes(op.type_str)
                total += float(elems)
            elif op.opcode in ("reduce", "select", "compare", "convert"):
                elems, _ = _array_elems_bytes(op.type_str)
                total += float(elems)
        return total

    # ---- slice-aware byte accounting ---------------------------------------
    # Scan carries lower to dynamic-update-slice on buffer-aliased arrays and
    # stacked weights are read via dynamic-slice: true HBM traffic per
    # iteration is the SLICE, not the whole buffer.  Counting fusion operands
    # wholesale would overcount loop programs by O(trip_count).

    def _param_names_by_index(self, called: Computation) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for o in called.ops:
            if o.opcode == "parameter":
                m = re.match(r"\s*(\d+)\)", o.rest)
                if m:
                    out[int(m.group(1))] = o.name
        return out

    _SLICE_READERS = {"dynamic-slice", "gather"}

    def _fusion_bytes(self, op: Op, comp: Computation) -> float:
        called_names = _called_comps(op)
        called = self.comps.get(called_names[0]) if called_names else None
        _, out_full = _array_elems_bytes(op.type_str)
        if called is None:
            ib = sum(
                _array_elems_bytes(comp.shapes.get(o, ""))[1] for o in op.operands
            )
            return ib + out_full
        params = self._param_names_by_index(called)
        reads = 0.0
        for j, operand in enumerate(op.operands):
            _, full = _array_elems_bytes(comp.shapes.get(operand, ""))
            pname = params.get(j)
            if pname is None:
                reads += full
                continue
            consumers = [o for o in called.ops if pname in o.operands]
            if consumers and all(o.opcode in self._SLICE_READERS for o in consumers):
                reads += sum(_array_elems_bytes(o.type_str)[1] for o in consumers)
            elif consumers and all(
                o.opcode == "dynamic-update-slice" and o.operands and o.operands[0] == pname
                for o in consumers
            ):
                pass  # in-place updated buffer: never read, only sliced-into
            elif not consumers:
                pass  # dead operand — no traffic
            else:
                reads += full
        writes = float(out_full)
        for o in called.ops:
            if o.opcode == "dynamic-update-slice":
                _, buf = _array_elems_bytes(o.type_str)
                upd = 0
                if len(o.operands) > 1:
                    _, upd = _array_elems_bytes(called.shapes.get(o.operands[1], ""))
                first = _ARRAY_RE.search(o.type_str)
                if first and first.group(0) in op.type_str:
                    writes -= buf - upd  # in-place update: write the slice only
            elif o.opcode == "scatter" and len(o.operands) > 2:
                _, buf = _array_elems_bytes(o.type_str)
                _, upd = _array_elems_bytes(called.shapes.get(o.operands[2], ""))
                first = _ARRAY_RE.search(o.type_str)
                if first and first.group(0) in op.type_str:
                    writes -= buf - upd
        return reads + max(writes, 0.0)

    def _leaf_bytes(self, op: Op, comp: Computation) -> float:
        oc = op.opcode
        _, ob = _array_elems_bytes(op.type_str)
        if oc in ("dynamic-slice", "gather"):
            return 2.0 * ob  # read slice + write slice (indices negligible)
        if oc == "dynamic-update-slice":
            upd = 0
            if len(op.operands) > 1:
                _, upd = _array_elems_bytes(comp.shapes.get(op.operands[1], ""))
            return 2.0 * upd
        if oc == "scatter":
            upd = 0
            if len(op.operands) > 2:
                _, upd = _array_elems_bytes(comp.shapes.get(op.operands[2], ""))
            return 2.0 * upd
        if oc == "fusion":
            return self._fusion_bytes(op, comp)
        ib = sum(_array_elems_bytes(comp.shapes.get(o, ""))[1] for o in op.operands)
        return float(ib + ob)

    # ---- stats of one computation's top level ------------------------------
    def analyze(self, comp_name: str) -> HloStats:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = HloStats()  # cycle guard
        comp = self.comps.get(comp_name)
        stats = HloStats()
        if comp is None:
            return stats
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "iota", "partition-id", "replica-id"):
                continue
            if oc == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                # XLA records static trip counts in backend_config — exact.
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                trips = int(mt.group(1)) if mt else None
                if trips is None and cond:
                    trips = self.trip_count(cond)
                if trips is None:
                    trips = 1
                    stats.unknown_trips += 1
                inner = HloStats()
                if body:
                    inner.add(self.analyze(body))
                stats.add(inner.scaled(trips))
                continue
            if oc in ("call", "conditional", "async-start"):
                for sub in _called_comps(op):
                    stats.add(self.analyze(sub))
                continue
            # leaf-ish op: slice-aware byte accounting
            _, ob = _array_elems_bytes(op.type_str)
            base = oc.replace("-start", "")
            if base in COLLECTIVE_KINDS and not oc.endswith("-done"):
                stats.coll[base] += ob
                stats.coll_count += 1
                stats.bytes += self._leaf_bytes(op, comp)
                continue
            if oc.endswith("-done"):
                continue
            stats.bytes += self._leaf_bytes(op, comp)
            if oc == "dot":
                stats.flops += _dot_flops(op, comp.shapes)
            elif oc == "convolution":
                stats.flops += _conv_flops(op, comp.shapes)
            elif oc == "fusion":
                for sub in _called_comps(op):
                    stats.flops += self._fusion_flops(sub)
            elif oc not in _ELEMENTWISE_ZERO:
                elems, _ = _array_elems_bytes(op.type_str)
                stats.flops += float(elems)
        self._memo[comp_name] = stats
        return stats


def entry_name(comps: Dict[str, Computation], text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        return m.group(1)
    return next(iter(comps)) if comps else None


def analyze_hlo_text(text: str) -> HloStats:
    comps = parse_hlo(text)
    entry = entry_name(comps, text)
    if entry is None:
        return HloStats()
    return Analyzer(comps).analyze(entry)
