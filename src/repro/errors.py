"""Typed exception hierarchy of the public parse API.

Every error the parse runtime raises on purpose derives from ``ParseError``,
so ``except repro.ParseError`` is the one catch-all a caller needs.  The
subclasses double-inherit from the builtin exceptions the pre-facade services
used to raise bare (``KeyError`` for unknown sessions, ``ValueError`` for
malformed/over-budget requests), so existing ``except KeyError`` /
``except ValueError`` call sites keep working one release longer.

This module is dependency-free on purpose: ``import repro`` exposes it
without paying the jax import cost (see ``repro/__init__``'s lazy exports).
"""

from __future__ import annotations

from typing import Optional, Tuple


class ParseError(Exception):
    """Base class of every typed error the parse runtime raises."""


class AdmissionError(ParseError):
    """Deadline-aware admission rejected a request.

    Raised at submit/append time — before any device work — when the
    request's shape bucket has an observed p99 latency that already exceeds
    the remaining deadline (or the deadline is already blown).  Carries the
    numbers the scheduler used, so callers can retry with a looser deadline
    or route the request elsewhere.
    """

    def __init__(
        self,
        message: str,
        *,
        bucket=None,
        deadline_s: Optional[float] = None,
        predicted_s: Optional[float] = None,
    ):
        super().__init__(message)
        self.bucket = bucket
        self.deadline_s = deadline_s
        self.predicted_s = predicted_s


class SessionNotFound(ParseError, KeyError):
    """A stream operation named a session id that is not open.

    Subclasses ``KeyError`` because ``StreamService`` used to raise the bare
    builtin — old ``except KeyError`` handlers still catch it.
    """

    def __init__(self, sid):
        super().__init__(f"no open stream session with id {sid!r}")
        self.sid = sid

    def __str__(self) -> str:  # KeyError would repr() the message
        return self.args[0]


class PathologicalPatternError(ParseError, ValueError):
    """The static analyzer rejected a pattern as pathologically ambiguous.

    Raised under ``analyze="strict"`` — at ``Parser`` construction, at
    ``ParserFleet.add``, and by the services' admission guards — when
    ``repro.analyze`` diagnoses infinite ambiguity (an iterator with a
    nullable body, e.g. ``(a*)*``): a single text then has unboundedly many
    parse trees, so forest size is not bounded by input length and no
    speculation-width bound holds.  Carries the pattern and the analyzer's
    verdict so multi-tenant callers can report which tenant was refused.

    Subclasses ``ValueError`` like the other malformed-request rejections,
    so blanket ``except ValueError`` admission handlers keep catching it.
    """

    def __init__(self, message: str, *, pattern: Optional[str] = None,
                 ambiguity: Optional[str] = None):
        super().__init__(message)
        self.pattern = pattern
        self.ambiguity = ambiguity


class BudgetExceeded(ParseError, ValueError):
    """A request was rejected because it would exceed a configured budget
    (queue depth, pending characters, seal-boundary piece size, …).

    Subclasses ``ValueError`` because the pre-facade paths raised the bare
    builtin for over-budget work — old handlers keep catching it.
    """

    def __init__(self, message: str, *, budget=None, requested=None):
        super().__init__(message)
        self.budget = budget
        self.requested = requested
