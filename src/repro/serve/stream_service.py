"""Session-level streaming parse service: many live streams, one engine.

``serve/parse_service.py`` batches *one-shot* texts; this module serves
*streams* — sessions that grow by appends and may ask for their SLPF at any
prefix.  It is the slot pattern a third time: host-side session state, a
small static set of device-program shapes, work admitted the moment it can
join a batch.

  sessions    each owns a ``core/stream.py`` ``StreamingParser`` (its
              persistent chunk-product prefix cache) over ONE shared
              ``ParserEngine`` — every session reuses the same compiled
              phase programs.
  batching    queued appends are split into seal-bounded pieces; ``step``
              picks the piece bucket of the least-virtual-time active
              session (weighted-fair — ``vtime`` advances by absorbed
              chars / the session's ``weight``, so one hot stream cannot
              starve the rest; equal weights degrade to arrival-order
              FIFO) and runs ONE batched reach for every same-bucket
              session's next piece (chunk axis = session axis; pad rows
              are all-PAD → identity products, discarded).  Each product
              then folds into its session's tail with one ``compose``.
  editing     ``edit(sid, lo, hi, replacement)`` splices one session's
              prefix through the parser's product segment tree — O(log n)
              device work, served out-of-band like queries (the session's
              own pending appends drain first so the offsets are stable).
  eviction    a bytes-cached budget over all sessions' device caches; when
              exceeded, tree-node products are dropped cost-aware —
              the nodes covering the MOST characters first (every product
              frees the same bytes — ℓp²·4 f32, or ℓp²/8 under the packed
              backend, whose itemized sizes the byte accounting reflects
              automatically — so the widest node frees the most cache per
              retained parse state; internal nodes cover whole subtrees
              and rebuild with ONE compose, so they rank ahead of leaves),
              least-recently-touched session as tie-break — falling back
              to whole-cache drops (``StreamingParser.drop_cache``) when
              per-node drops alone cannot meet the budget.  The budget loop
              decrements by the bytes each drop REPORTS freed (the first
              drop releases the session's join entries too), so it
              converges even when the budget is smaller than a join cache.
              Classes stay host-side and missing products rebuild
              transparently on next touch (counted per re-reached chunk in
              ``stats["rebuilds"]``), so eviction trades work, never
              correctness.

``stats`` mirrors ``ParseService.stats``: queue depth + per-bucket
served-count/latency aggregates (bucket key = piece chunk length k).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core.backend import ParserBackend
from ..core.engine import _next_pow2, _resolve_engine
from ..core.slpf import SLPF
from ..core.stream import StreamingParser
from ..errors import AdmissionError, BudgetExceeded, SessionNotFound
from .parse_service import BucketStats, bucket_stats_dict


@dataclasses.dataclass
class _PendingAppend:
    classes: np.ndarray
    offset: int = 0                      # chars already absorbed
    enqueued_at: float = 0.0
    # tracing: one trace per append request; the pre-minted root span id
    # parents the retroactive queue-wait/compute spans (see obs/trace.py)
    trace_id: Optional[str] = None
    root_span_id: Optional[str] = None

    @property
    def remaining(self) -> int:
        return len(self.classes) - self.offset


@dataclasses.dataclass
class StreamSession:
    sid: int
    parser: StreamingParser
    pending: Deque[_PendingAppend] = dataclasses.field(default_factory=deque)
    arrival_seq: int = 0                 # tie-break key while active
    last_touch: int = 0                  # LRU key for eviction
    weight: float = 1.0                  # weighted-fair share
    vtime: float = 0.0                   # absorbed chars / weight

    @property
    def pending_chars(self) -> int:
        return sum(p.remaining for p in self.pending)


class StreamService:
    """Bucket-batched scheduler over many ``StreamingParser`` sessions."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro: constructing StreamService directly is deprecated — use "
            "repro.Parser.open_stream() (repro/api.py); the facade owns "
            "service construction and admission policy",
            DeprecationWarning,
            stacklevel=2,
        )
        self._init(*args, **kwargs)

    @classmethod
    def _internal(cls, *args, **kwargs) -> "StreamService":
        """Facade-owned construction path (no deprecation warning)."""
        self = object.__new__(cls)
        self._init(*args, **kwargs)
        return self

    def _init(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        max_batch: int = 8,
        first_seal_len: int = 8,
        max_seal_len: Optional[int] = None,
        cache_budget_bytes: Optional[int] = None,
        max_pending_chars: Optional[int] = None,
        mesh=None,
        mesh_rules=None,
    ):
        self.engine = _resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
        self.max_batch = max(1, max_batch)
        self.first_seal_len = first_seal_len
        self.max_seal_len = max_seal_len
        self.cache_budget_bytes = cache_budget_bytes
        self.max_pending_chars = max_pending_chars

        self._sessions: Dict[int, StreamSession] = {}
        self._next_sid = 0
        self._seq = 0                    # global arrival / touch clock
        self._vclock = 0.0               # vtime of the last scheduled session
        self.batches_run = 0
        self.evictions = 0
        self._peak_queue_depth = 0
        self._buckets: Dict[int, BucketStats] = {}

    def set_pattern_guard(self, verdict: str, mode: str) -> None:
        """Install the static analyzer's verdict on this service's admission
        path (``repro.analyze``): under ``mode="strict"`` a ``pathological``
        verdict rejects every append with ``PathologicalPatternError``
        before anything is queued.  The facade wires this from the
        construction-time analysis; directly-assembled services default to
        no guard."""
        self._pattern_guard = (verdict, mode)

    def _check_pattern_guard(self) -> None:
        verdict, mode = getattr(self, "_pattern_guard", ("ok", "off"))
        if mode == "strict" and verdict == "pathological":
            from ..errors import PathologicalPatternError

            self.engine.obs.metrics.counter(
                "admission_rejects_total", service="stream", cause="pathological"
            ).inc()
            raise PathologicalPatternError(
                "this service's pattern was diagnosed pathologically "
                'ambiguous; analyze="strict" refuses to serve it',
                ambiguity="pathological",
            )

    # ------------------------------------------------------------- sessions

    def open(self, *, weight: float = 1.0) -> int:
        """Open a streaming session; returns its session id.

        ``weight`` is the session's weighted-fair share: its virtual time
        advances by absorbed-chars/weight, so at equal backlog a weight-2
        session is scheduled twice as often as a weight-1 one.
        """
        if weight <= 0:
            raise ValueError(f"session weight must be > 0, got {weight}")
        sid = self._next_sid
        self._next_sid += 1
        self._sessions[sid] = StreamSession(
            sid=sid,
            parser=StreamingParser(
                self.engine,
                first_seal_len=self.first_seal_len,
                max_seal_len=self.max_seal_len,
            ),
            last_touch=self._tick(),
            weight=weight,
            vtime=self._vclock,          # no credit for pre-open idle time
        )
        self.engine.obs.metrics.gauge("stream_sessions").set(len(self._sessions))
        return sid

    def close(self, sid: int) -> None:
        if sid not in self._sessions:
            raise SessionNotFound(sid)
        del self._sessions[sid]
        self.engine.obs.metrics.gauge("stream_sessions").set(len(self._sessions))

    def _tick(self) -> int:
        self._seq += 1
        return self._seq

    def _session(self, sid: int) -> StreamSession:
        try:
            return self._sessions[sid]
        except KeyError:
            raise SessionNotFound(sid) from None

    # --------------------------------------------------------------- append

    def admission_p99_s(self, bucket: int) -> float:
        """Observed p99 append latency of one piece bucket (0.0 when cold —
        same defined cold-start contract as ``ParseService.admission_p99_s``)."""
        stats = self._buckets.get(bucket)
        return stats.latency_quantile_s(99.0) if stats is not None else 0.0

    def append(self, sid: int, text, *, deadline_s: Optional[float] = None) -> int:
        """Queue text onto a session; returns chars queued.  Work happens in
        ``step``/``drain`` so concurrent sessions batch on the device.

        ``deadline_s`` (remaining latency budget) runs deadline-aware
        admission against the next piece's bucket: observed p99 over budget
        (or a blown budget) raises ``AdmissionError`` before anything is
        queued.  ``max_pending_chars`` bounds the cross-session backlog with
        ``BudgetExceeded``.
        """
        s = self._session(sid)
        self._check_pattern_guard()
        classes = self.engine.classes_of_text(text)
        obs = self.engine.obs
        m = obs.metrics
        if len(classes):
            if (
                self.max_pending_chars is not None
                and self.pending_chars + len(classes) > self.max_pending_chars
            ):
                m.counter(
                    "admission_rejects_total", service="stream", cause="budget"
                ).inc()
                raise BudgetExceeded(
                    f"append of {len(classes)} chars would exceed the "
                    f"max_pending_chars budget ({self.max_pending_chars}; "
                    f"{self.pending_chars} queued)",
                    budget=self.max_pending_chars,
                    requested=self.pending_chars + len(classes),
                )
            # the admission-relevant device work is the session's NEXT
            # piece — bucket it exactly like the scheduler will
            piece_len = min(s.parser.tail_room(), len(classes))
            bucket = s.parser._bucket_len(piece_len)
            if deadline_s is not None:
                predicted = self.admission_p99_s(bucket)
                if deadline_s <= 0.0 or predicted > deadline_s:
                    m.counter(
                        "admission_rejects_total", service="stream",
                        cause="deadline",
                    ).inc()
                    raise AdmissionError(
                        f"stream bucket {bucket} p99 {predicted * 1e3:.1f}ms "
                        f"exceeds the remaining deadline {deadline_s * 1e3:.1f}ms",
                        bucket=bucket,
                        deadline_s=deadline_s,
                        predicted_s=predicted,
                    )
            # the bucket is observable (served=0, queue_depth>0) from this
            # moment — deadline or not (same cold-start contract as
            # ParseService.submit_request)
            self._buckets.setdefault(bucket, BucketStats())
            if not s.pending:
                s.arrival_seq = self._tick()
                # WFQ activation floor: a session waking from idle resumes
                # at the scheduler's clock — idle time banks no credit
                s.vtime = max(s.vtime, self._vclock)
            p = _PendingAppend(
                classes=classes,
                enqueued_at=time.perf_counter(),
                trace_id=obs.new_trace_id(),
            )
            if p.trace_id is not None:
                p.root_span_id = obs.tracer._new_span_id()
            s.pending.append(p)
            s.last_touch = self._tick()
            m.counter("appends_total", service="stream").inc()
            m.counter("chars_total", service="stream").inc(len(classes))
            m.gauge("queue_depth", service="stream").set(self.pending_appends)
        self._peak_queue_depth = max(self._peak_queue_depth, self.pending_appends)
        m.gauge("peak_queue_depth", service="stream").set(self._peak_queue_depth)
        return len(classes)

    def _next_piece_len(self, s: StreamSession) -> int:
        return min(s.parser.tail_room(), s.pending[0].remaining)

    def _piece_bucket(self, s: StreamSession) -> int:
        # the parser's own bucketing, so the batched reach grid hits exactly
        # the shapes a solo append would compile
        return s.parser._bucket_len(self._next_piece_len(s))

    def _take_piece(
        self, s: StreamSession, m: int
    ) -> Tuple[np.ndarray, Optional[_PendingAppend]]:
        """Consume m chars from the head pending append; returns (classes,
        the append record if this piece completed it)."""
        head = s.pending[0]
        piece = head.classes[head.offset : head.offset + m]
        head.offset += m
        completed = None
        if head.remaining == 0:
            completed = head
            s.pending.popleft()
        return piece, completed

    def _finish_append(
        self,
        p: _PendingAppend,
        bucket: int,
        picked_at: float,
        now: float,
        *,
        batch_size: int,
    ) -> None:
        """Latency bookkeeping + retroactive spans for one completed append."""
        stats = self._buckets.setdefault(bucket, BucketStats())
        stats.record(
            now - p.enqueued_at,
            queue_s=picked_at - p.enqueued_at,
            compute_s=now - picked_at,
        )
        obs = self.engine.obs
        obs.metrics.counter("served_total", service="stream").inc()
        if p.trace_id is None:
            return
        obs.emit(
            "stream.append",
            t_start_s=p.enqueued_at,
            duration_s=now - p.enqueued_at,
            trace_id=p.trace_id,
            span_id=p.root_span_id,
            n_chars=len(p.classes),
        )
        obs.emit(
            "stream.append_queue_wait",
            t_start_s=p.enqueued_at,
            duration_s=picked_at - p.enqueued_at,
            trace_id=p.trace_id,
            parent_id=p.root_span_id,
            bucket=bucket,
        )
        obs.emit(
            "stream.append_compute",
            t_start_s=picked_at,
            duration_s=now - picked_at,
            trace_id=p.trace_id,
            parent_id=p.root_span_id,
            bucket=bucket,
            batch_size=batch_size,
        )

    # ---------------------------------------------------------------- serving

    def step(self) -> bool:
        """Absorb one piece-batch; False when idle.

        The batch head is the least-virtual-time active session (weighted
        fair; arrival order breaks ties, so equal weights are plain FIFO);
        the rest of the batch fills with same-bucket sessions in arrival
        order — riders share the head's reach program and each charges its
        own vtime.  One batched reach serves every selected session's next
        piece; the per-session compose/seal bookkeeping is O(1) device work
        each.
        """
        active = sorted(
            (s for s in self._sessions.values() if s.pending),
            key=lambda s: s.arrival_seq,
        )
        if not active:
            return False
        head = min(active, key=lambda s: (s.vtime, s.arrival_seq))
        self._vclock = head.vtime
        bucket = self._piece_bucket(head)
        batch: List[StreamSession] = [head]
        for s in active:
            if len(batch) == self.max_batch:
                break
            if s is not head and self._piece_bucket(s) == bucket:
                batch.append(s)

        # One (B_pad, k) reach across sessions: chunk axis = session axis.
        pieces: List[np.ndarray] = []
        finished: List[Optional[_PendingAppend]] = []
        picked_at = time.perf_counter()
        for s in batch:
            piece, done = self._take_piece(s, self._next_piece_len(s))
            pieces.append(piece)
            finished.append(done)
        B_pad = _next_pow2(len(batch))
        grid = np.full((B_pad, bucket), self.engine.tables.pad_class, dtype=np.int32)
        for row, piece in enumerate(pieces):
            grid[row, : len(piece)] = piece
        products = self.engine.phases.reach(self.engine.tables.N, jnp.asarray(grid))

        stats = self._buckets.setdefault(bucket, BucketStats())
        for row, s in enumerate(batch):
            s.parser.absorb_product(pieces[row], products[row])
            s.last_touch = self._tick()
            s.vtime += len(pieces[row]) / s.weight
            if s.pending:
                s.arrival_seq = self._tick()   # requeue behind current arrivals
        now = time.perf_counter()
        for done in finished:
            if done is not None:
                self._finish_append(
                    done, bucket, picked_at, now, batch_size=len(batch)
                )
        stats.batches += 1
        self.batches_run += 1
        m = self.engine.obs.metrics
        m.counter("batches_total", service="stream").inc()
        m.gauge("queue_depth", service="stream").set(self.pending_appends)
        self._maybe_evict()
        return True

    def drain(self) -> None:
        """Absorb every queued append across all sessions."""
        while self.step():
            pass

    def _drain_session(self, s: StreamSession) -> None:
        """Absorb ONE session's pending appends (unbatched reach per piece) —
        a query's latency must not scale with other sessions' backlogs."""
        while s.pending:
            picked_at = time.perf_counter()
            piece, done = self._take_piece(s, self._next_piece_len(s))
            bucket = s.parser._bucket_len(len(piece))
            s.parser.absorb_product(piece, s.parser._reach_piece(piece))
            s.vtime += len(piece) / s.weight   # out-of-band work still charges
            if done is not None:
                self._finish_append(
                    done, bucket, picked_at, time.perf_counter(), batch_size=1
                )
        self.engine.obs.metrics.gauge("queue_depth", service="stream").set(
            self.pending_appends
        )

    # ----------------------------------------------------------------- query

    def slpf(self, sid: int) -> SLPF:
        """Current SLPF of one session's full prefix (drains ITS pending)."""
        s = self._session(sid)
        self._drain_session(s)
        s.last_touch = self._tick()
        out = s.parser.current_slpf()
        self._maybe_evict()
        return out

    def accepted(self, sid: int) -> bool:
        s = self._session(sid)
        self._drain_session(s)
        s.last_touch = self._tick()
        return s.parser.accepted

    def edit(self, sid: int, lo: int, hi: int, replacement) -> int:
        """Splice one session's prefix: replace chars [lo, hi) with
        ``replacement``; returns the new prefix length.

        Pending appends drain first (the edit addresses the post-append
        prefix), then the parser's segment tree re-composes one leaf-to-root
        path — O(log n) device work, unbatched like the other queries.
        """
        s = self._session(sid)
        self._drain_session(s)
        s.last_touch = self._tick()
        n = s.parser.edit(lo, hi, replacement)
        self._maybe_evict()
        return n

    # -------------------------------------------------------------- eviction

    @property
    def bytes_cached(self) -> int:
        return sum(s.parser.cache_nbytes for s in self._sessions.values())

    def _maybe_evict(self) -> None:
        """Cost-aware eviction until under the bytes budget.

        Every node product costs the same device bytes (the engine
        backend's product size — f32 matrix or packed words), so ranking
        is purely by recompute economics: drop the products covering the
        MOST characters first (internal tree nodes rank ahead of leaves —
        they span whole subtrees and rebuild with ONE compose; among leaves
        the largest chunk is the cheapest per covered byte to re-reach),
        with least-recently-touched session as the tie-break.  The loop
        decrements the running total by what each drop REPORTS freed —
        ``drop_sealed_product`` releases the session's join entries with
        the first drop, so every byte ``cache_nbytes`` counts is actually
        reclaimable and the loop converges instead of spinning over budget.
        When per-node drops alone cannot reach the budget, fall back to
        whole-cache LRU drops (frees tail products too).  The most recently
        touched session is never evicted.
        """
        m = self.engine.obs.metrics
        if self.cache_budget_bytes is None:
            return
        total = self.bytes_cached       # summed once; decremented per evict
        m.gauge("stream_bytes_cached").set(total)
        if total <= self.cache_budget_bytes:
            return
        by_lru = sorted(self._sessions.values(), key=lambda s: s.last_touch)
        victims = by_lru[:-1]            # never evict the most recent session
        candidates = [                   # (-covered_chars, lru_rank, key, ...)
            (-chars, rank, key, s)
            for rank, s in enumerate(victims)
            for key, chars, _ in s.parser.sealed_cache_entries()
        ]
        candidates.sort(key=lambda cand: cand[:3])
        for _, _, key, s in candidates:
            if total <= self.cache_budget_bytes:
                m.gauge("stream_bytes_cached").set(total)
                return
            freed = s.parser.drop_sealed_product(key)
            if freed:
                total -= freed
                self._count_eviction(freed)
        for s in victims:                # fallback: whole-cache LRU drops
            if total <= self.cache_budget_bytes:
                break
            freed = s.parser.cache_nbytes
            if freed == 0:
                continue
            s.parser.drop_cache()
            total -= freed
            self._count_eviction(freed)
        m.gauge("stream_bytes_cached").set(total)

    def _count_eviction(self, freed_bytes: int) -> None:
        self.evictions += 1
        m = self.engine.obs.metrics
        m.counter("stream_evictions_total").inc()
        m.counter("stream_bytes_reclaimed_total").inc(freed_bytes)

    # ------------------------------------------------------------------ stats

    @property
    def pending_chars(self) -> int:
        return sum(s.pending_chars for s in self._sessions.values())

    @property
    def pending_appends(self) -> int:
        """Queued append requests not yet fully absorbed (request units —
        comparable with ``ParseService``'s queue depth)."""
        return sum(len(s.pending) for s in self._sessions.values())

    @property
    def compile_count(self) -> int:
        return self.engine.compile_count

    @property
    def stats(self) -> Dict:
        """Same shape and units as ``ParseService.stats`` — ``pending`` and
        ``peak_queue_depth`` count append *requests* (bucket key = piece
        length k) — plus cache/eviction observables for the bytes budget
        (``pending_chars`` carries the char-level backlog)."""
        depth: Dict[int, int] = {}
        for s in self._sessions.values():
            if s.pending:
                b = self._piece_bucket(s)
                depth[b] = depth.get(b, 0) + len(s.pending)
        return {
            "backend": self.engine.backend.name,
            "sessions": len(self._sessions),
            "pending": self.pending_appends,
            "pending_chars": self.pending_chars,
            "peak_queue_depth": self._peak_queue_depth,
            "batches_run": self.batches_run,
            "compile_count": self.compile_count,
            "bytes_cached": self.bytes_cached,
            "evictions": self.evictions,
            "rebuilds": sum(s.parser.rebuilds for s in self._sessions.values()),
            "edits": sum(s.parser.edits for s in self._sessions.values()),
            "buckets": bucket_stats_dict(self._buckets, depth),
        }
