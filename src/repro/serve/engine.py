"""Batched serving engine with RE-constrained decoding.

The paper's parser automaton becomes a first-class *serving* feature:
structured-output decoding.  ``TokenDFA`` lifts the byte/char-class parser DFA
to the token vocabulary (token = byte string → composed transition), giving a
per-state allowed-token mask; ``ServeEngine.generate`` applies the mask before
sampling, so every emitted sequence is a prefix of ``L(e)`` and termination is
only allowed in accepting states — grammar-guaranteed output, driven by the
same artifacts (segments → NFA → DFA) the parallel parser uses.

The engine itself is the standard loop: step-wise prefill populating the KV /
SSM caches, then greedy or temperature decode, batched, jit-compiled once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.automata import DFA, build_dfa, build_nfa
from ..core.matrices import ParserMatrices
from ..models.config import ModelConfig
from ..models.model import decode_step, make_cache


# ------------------------------------------------------------- token DFA


@dataclasses.dataclass
class TokenDFA:
    """Parser DFA lifted to a token vocabulary.

    ``delta``: (n_states, vocab) int32 — next state or -1 (dead).
    ``final``: (n_states,) bool — states where EOS is allowed.
    """

    delta: np.ndarray
    final: np.ndarray
    initial: int

    @classmethod
    def from_matrices(
        cls,
        matrices: ParserMatrices,
        vocab: Sequence[bytes],
        dfa: Optional[DFA] = None,
    ) -> "TokenDFA":
        nfa = build_nfa(matrices.table)
        if dfa is None:
            dfa = build_dfa(nfa)
        # complete the (state, class) table lazily over reachable states
        n0 = dfa.n_states
        byte_cls = matrices.byte_to_class
        vocab_classes = [
            byte_cls[np.frombuffer(t, dtype=np.uint8)] if len(t) else np.zeros(0, np.int64)
            for t in vocab
        ]
        delta_rows: List[np.ndarray] = []
        state_ids: Dict[int, int] = {}

        def token_step(sid: int, classes) -> int:
            cur: Optional[int] = sid
            for c in classes:
                if cur is None:
                    return -1
                cur = dfa.step(cur, int(c))
            return -1 if cur is None else cur

        # BFS over token transitions (the byte-DFA is already closed; token
        # transitions only visit existing byte-DFA states)
        work = [dfa.initial[0]]
        seen = {dfa.initial[0]}
        rows: Dict[int, np.ndarray] = {}
        while work:
            sid = work.pop()
            row = np.full(len(vocab), -1, dtype=np.int32)
            for tid, classes in enumerate(vocab_classes):
                nxt = token_step(sid, classes)
                row[tid] = nxt
                if nxt >= 0 and nxt not in seen:
                    seen.add(nxt)
                    work.append(nxt)
            rows[sid] = row
        n = max(seen) + 1
        delta = np.full((n, len(vocab)), -1, dtype=np.int32)
        for sid, row in rows.items():
            delta[sid] = row
        final = np.zeros(n, dtype=bool)
        for sid in seen:
            final[sid] = dfa.final[sid]
        return cls(delta=delta, final=final, initial=dfa.initial[0])


def byte_vocab(vocab_size: int) -> List[bytes]:
    """Token id = byte id (ids ≥ 256 are non-lexical controls → dead)."""
    return [bytes([i]) if i < 256 else b"\xff\xff" for i in range(vocab_size)]


# ---------------------------------------------------------------- engine


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # (b, n_new)
    accepted: Optional[np.ndarray] = None   # constraint acceptance per row


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_seq: int = 256,
        batch: int = 1,
        tp: int = 1,
        eos_id: Optional[int] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.tp = tp
        self.eos_id = eos_id
        self._step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, tp))

    def new_caches(self):
        return make_cache(self.cfg, self.batch, self.max_seq, self.tp)

    def generate(
        self,
        prompts: np.ndarray,          # (b, Lp) int32
        max_new: int,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        constraint: Optional[TokenDFA] = None,
    ) -> GenerationResult:
        b, Lp = prompts.shape
        assert b == self.batch
        caches = self.new_caches()
        logits = None
        for t in range(Lp):  # step-wise prefill (exercises the cache path)
            logits, caches = self._step(self.params, caches, prompts[:, t : t + 1])
        key = jax.random.PRNGKey(seed)
        states = (
            np.full(b, constraint.initial, dtype=np.int32) if constraint is not None else None
        )
        out = np.zeros((b, max_new), dtype=np.int32)
        done = np.zeros(b, dtype=bool)
        for i in range(max_new):
            lg = np.asarray(logits[:, -1], np.float32)       # (b, V)
            stuck = None
            if constraint is not None:
                mask = constraint.delta[states] >= 0          # (b, V)
                if self.eos_id is not None:
                    mask[:, self.eos_id] = constraint.final[states]
                lg = np.where(mask, lg, -np.inf)
                # dead-end guard: if nothing is allowed, force EOS/stop
                stuck = ~mask.any(axis=1)
                done |= stuck
            if temperature <= 0.0:
                nxt = lg.argmax(axis=-1).astype(np.int32)
            else:
                key, sub = jax.random.split(key)
                g = np.asarray(
                    jax.random.gumbel(sub, lg.shape), np.float32
                )
                nxt = (lg / temperature + g).argmax(axis=-1).astype(np.int32)
            if stuck is not None:
                # an all--inf row argmaxes to token 0 (an arbitrary, possibly
                # grammar-breaking id); emit EOS — or the -1 sentinel when no
                # EOS is configured — for stuck rows instead
                fill = self.eos_id if self.eos_id is not None else -1
                nxt = np.where(stuck, np.int32(fill), nxt)
            if self.eos_id is not None:
                done |= nxt == self.eos_id
            out[:, i] = nxt
            if constraint is not None:
                alive = ~done
                states[alive] = constraint.delta[states[alive], nxt[alive]]
            if done.all():
                out = out[:, : i + 1]
                break
            logits, caches = self._step(self.params, caches, nxt[:, None])
        accepted = None
        if constraint is not None:
            accepted = np.where(states >= 0, constraint.final[np.maximum(states, 0)], False)
        return GenerationResult(tokens=out, accepted=accepted)
