"""Continuous batching for the serving engine (large-scale runnability).

A slot-based scheduler over the fixed-batch jitted decode step: requests
arrive with different prompts/lengths/constraints; the scheduler packs them
into ``batch`` decode slots, admits new requests the moment a slot frees
(continuous batching — no head-of-line blocking on the longest sequence),
and never recompiles (the device program is shape-static).

Per-slot state lives host-side (positions, constraint DFA states, emitted
tokens); the device caches are shared across slots — each slot owns a batch
row.  Freed rows are re-primed by step-wise prefill of the next request's
prompt while other rows keep decoding (prefill steps feed dummy tokens to
finished/waiting rows; their cache rows are masked by per-row positions).

This is the slot/iteration-level scheduling of production inference servers
(Orca-style), expressed over the same ``decode_step`` the dry-run lowers.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import numpy as np

from ..models.config import ModelConfig
from ..models.model import decode_step, make_cache
from .engine import TokenDFA


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (Lp,) int32
    max_new: int
    temperature: float = 0.0
    constraint: Optional[TokenDFA] = None
    # filled by the scheduler:
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos_in_prompt: int = 0
    emitted: int = 0
    dfa_state: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    """Slot scheduler over a fixed-batch decode program."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch: int = 4,
        max_seq: int = 256,
        eos_id: int = 0,
        seed: int = 0,
        tp: int = 1,
    ):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.tp = tp
        self._step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, tp))
        self._caches = make_cache(cfg, batch, max_seq, tp)
        self._slots = [_Slot() for _ in range(batch)]
        self._queue: Deque[Request] = deque()
        self._done: List[Request] = []
        self._rng = np.random.default_rng(seed)
        self._logits = None

    # ------------------------------------------------------------- admission

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self._slots):
            if slot.free and self._queue:
                req = self._queue.popleft()
                slot.req = req
                slot.pos_in_prompt = 0
                slot.emitted = 0
                slot.tokens = []
                slot.dfa_state = (
                    req.constraint.initial if req.constraint is not None else 0
                )
                # slot reuse isolation: mask this row's stale attention cache
                # behind the current position, and zero SSM state rows.
                pos = int(self._caches["pos"])
                if "row_start" in self._caches:
                    self._caches["row_start"] = (
                        self._caches["row_start"].at[i].set(pos)
                    )
                if "ssm" in self._caches:
                    self._caches["ssm"] = dict(self._caches["ssm"])
                    self._caches["ssm"]["state"] = (
                        self._caches["ssm"]["state"].at[:, i].set(0.0)
                    )
                    self._caches["ssm"]["conv"] = (
                        self._caches["ssm"]["conv"].at[:, i].set(0.0)
                    )

    # ---------------------------------------------------------------- stepping

    def _next_feed(self) -> np.ndarray:
        """Token each row feeds THIS step (prompt token, sampled token, or pad)."""
        feed = np.zeros((self.batch, 1), np.int32)
        for i, slot in enumerate(self._slots):
            if slot.free:
                continue
            req = slot.req
            if slot.pos_in_prompt < len(req.prompt):
                feed[i, 0] = req.prompt[slot.pos_in_prompt]
            elif slot.tokens:
                feed[i, 0] = slot.tokens[-1]
            else:
                feed[i, 0] = req.prompt[-1]
        return feed

    def _sample_row(self, i: int, logits_row: np.ndarray) -> int:
        slot = self._slots[i]
        req = slot.req
        lg = logits_row.astype(np.float32)
        if req.constraint is not None:
            mask = req.constraint.delta[slot.dfa_state] >= 0
            mask[self.eos_id] = bool(req.constraint.final[slot.dfa_state])
            if not mask.any():
                return self.eos_id
            lg = np.where(mask, lg, -np.inf)
        if req.temperature <= 0:
            return int(lg.argmax())
        g = self._rng.gumbel(size=lg.shape).astype(np.float32)
        return int((lg / req.temperature + g).argmax())

    def step(self) -> bool:
        """One engine iteration; returns False when nothing is in flight."""
        self._admit()
        if all(s.free for s in self._slots) and not self._queue:
            return False
        feed = self._next_feed()
        logits, self._caches = self._step(self.params, self._caches, feed)
        logits = np.asarray(logits[:, -1], np.float32)
        for i, slot in enumerate(self._slots):
            if slot.free:
                continue
            req = slot.req
            if slot.pos_in_prompt < len(req.prompt) - 1:
                slot.pos_in_prompt += 1        # still prefilling this row
                continue
            slot.pos_in_prompt += 1
            tok = self._sample_row(i, logits[i])
            finished = tok == self.eos_id
            if not finished:
                slot.tokens.append(tok)
                slot.emitted += 1
                if req.constraint is not None:
                    slot.dfa_state = int(req.constraint.delta[slot.dfa_state, tok])
                    if slot.dfa_state < 0:
                        finished = True
            total_pos = len(req.prompt) + slot.emitted
            if finished or slot.emitted >= req.max_new or total_pos >= self.max_seq - 1:
                req.output = np.asarray(slot.tokens, np.int32)
                self._done.append(req)
                slot.req = None               # slot frees; next admit() reuses it
        return True

    def run(self) -> List[Request]:
        """Drive to completion; returns finished requests in completion order.

        Slot reuse is exact: on admission the row's ``row_start`` is set to
        the current global position (stale K/V masked in decode_attention)
        and SSM state rows are zeroed — no leakage between requests, no
        recompilation, no head-of-line blocking.
        """
        while self._queue or any(not s.free for s in self._slots):
            if not self.step():
                break
        out, self._done = self._done, []
        return out
