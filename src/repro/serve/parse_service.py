"""Request-level batched parsing service over the shape-bucketed engine.

The LM side of this repo serves generation through ``serve/scheduler.py``'s
slot pattern: a fixed set of device-program shapes, host-side request state,
admission the moment capacity frees.  This module is the same pattern for the
*parser*: callers submit texts of arbitrary length; the service groups queued
requests by their static (c, k) chunk bucket, packs up to ``max_batch`` of
them into one batched device program (extra batch slots ride along as all-PAD
rows), and drains bucket by bucket.  Because every program shape comes from
the engine's small bucket set, steady-state serving never recompiles —
``compile_count`` makes that observable.

Scheduling policy: each ``step`` serves the bucket holding the *oldest*
queued request (FIFO fairness), batching every same-bucket request behind it
up to ``max_batch`` — mixed-length traffic aggregates into full batches
without head-of-line blocking on rare shapes.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple, Union

import numpy as np

from ..core.backend import ParserBackend
from ..core.engine import ParserEngine
from ..core.slpf import SLPF


@dataclasses.dataclass
class ParseRequest:
    rid: int
    text: Union[bytes, str]
    # cached at submit so scheduling never re-tokenizes queued texts:
    classes: Optional[np.ndarray] = None
    # filled by the service:
    slpf: Optional[SLPF] = None

    @property
    def done(self) -> bool:
        return self.slpf is not None


class ParseService:
    """Bucket-batched request scheduler over ``ParserEngine.parse_batch``."""

    def __init__(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        max_batch: int = 8,
        n_chunks: int = 8,
    ):
        if isinstance(matrices_or_engine, ParserEngine):
            if backend is not None:
                raise ValueError(
                    "pass backend= only when the service builds the engine; "
                    "a prebuilt ParserEngine already owns its backend"
                )
            self.engine = matrices_or_engine
        else:
            self.engine = ParserEngine(
                matrices_or_engine, backend=backend if backend is not None else "jnp"
            )
        self.max_batch = max(1, max_batch)
        self.n_chunks = n_chunks
        self._queue: Deque[ParseRequest] = deque()
        self._done: List[ParseRequest] = []
        self._next_rid = 0
        self.batches_run = 0

    # ------------------------------------------------------------- admission

    def submit(self, text: Union[bytes, str]) -> int:
        """Enqueue a text; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            ParseRequest(rid=rid, text=text, classes=self.engine.classes_of_text(text))
        )
        return rid

    def _bucket_of(self, req: ParseRequest) -> Tuple[int, int]:
        return self.engine.bucket_shape(len(req.classes), self.n_chunks)

    # ---------------------------------------------------------------- serving

    def step(self) -> bool:
        """Serve one batch (the oldest request's bucket); False when idle."""
        if not self._queue:
            return False
        head_bucket = self._bucket_of(self._queue[0])
        batch: List[ParseRequest] = []
        keep: Deque[ParseRequest] = deque()
        while self._queue and len(batch) < self.max_batch:
            req = self._queue.popleft()
            if self._bucket_of(req) == head_bucket:
                batch.append(req)
            else:
                keep.append(req)
        keep.extend(self._queue)  # untouched tail keeps its order
        self._queue = keep

        slpfs = self.engine.parse_batch(
            [req.classes for req in batch], n_chunks=self.n_chunks
        )
        for req, slpf in zip(batch, slpfs):
            req.slpf = slpf
            self._done.append(req)
        self.batches_run += 1
        return True

    def run(self) -> List[ParseRequest]:
        """Drain the queue; returns finished requests in completion order."""
        while self.step():
            pass
        out, self._done = self._done, []
        return out

    # ------------------------------------------------------------------ stats

    @property
    def compile_count(self) -> int:
        """Distinct device programs compiled by the underlying engine."""
        return self.engine.compile_count

    @property
    def pending(self) -> int:
        return len(self._queue)
