"""Request-level batched parsing service over the shape-bucketed engine.

The LM side of this repo serves generation through ``serve/scheduler.py``'s
slot pattern: a fixed set of device-program shapes, host-side request state,
admission the moment capacity frees.  This module is the same pattern for the
*parser*: callers submit texts of arbitrary length; the service groups queued
requests by their static (c, k) chunk bucket, packs up to ``max_batch`` of
them into one batched device program (extra batch slots ride along as all-PAD
rows), and drains bucket by bucket.  Because every program shape comes from
the engine's small bucket set, steady-state serving never recompiles —
``compile_count`` makes that observable.

Scheduling policy: each ``step`` serves the bucket holding the *oldest*
queued request (FIFO fairness), batching every same-bucket request behind it
up to ``max_batch`` — mixed-length traffic aggregates into full batches
without head-of-line blocking on rare shapes.

Instrumentation (``ParseService.stats``): queue depth (current and peak) and
per-bucket served-count / queue-depth / latency aggregates including p50/p99
over a sliding sample window.  A bucket appears in ``stats`` from the moment
a request maps to it at submit — before the first serve — with ``served=0``
and its live ``queue_depth``, so the deadline-admission policy below has a
defined cold-start observable.  ``serve/stream_service.py`` exposes the same
stats shape for streaming sessions.

Admission (the ROADMAP SLO item): ``submit(text, deadline=...)`` rejects a
request with ``repro.errors.AdmissionError`` when its bucket's observed p99
latency already exceeds the remaining deadline (a cold bucket predicts 0.0
and admits); ``max_pending`` bounds the queue with
``repro.errors.BudgetExceeded``.  Policy knobs (per-bucket latency targets,
default deadlines) live in ``repro/api.py``'s ``ParserConfig`` — the facade
is the supported construction path; building ``ParseService`` directly is
deprecated.

Distribution: ``ParseService(..., mesh=...)`` builds a mesh-aware engine, so
every served bucket runs sharded-batched (batch slots over 'data', chunks
over 'pod' — ``core/distributed.py``); the scheduling layer is unchanged.

Backends: ``ParseService(..., backend=...)`` plumbs straight to the engine —
"jnp", "pallas", or the bit-packed "packed" backend (uint32 OR-AND word ops,
32× less product bandwidth for large automata) serve through the identical
scheduling layer; ``stats["backend"]`` reports which one is live.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Deque, Dict, Hashable, List, Optional, Tuple, Union

import numpy as np

from ..core.backend import ParserBackend
from ..core.engine import _resolve_engine
from ..core.slpf import SLPF
from ..errors import AdmissionError, BudgetExceeded

# Per-bucket latency sample window for the p50/p99 estimates: percentiles are
# exact over the most recent LATENCY_WINDOW served requests (a sorted-window
# estimator — O(window) memory per bucket, robust to traffic drift, unlike a
# lossy fixed-size reservoir over all time).
LATENCY_WINDOW = 512


def _window_quantile(window: Deque[float], q: float) -> float:
    if not window:
        return 0.0
    # nearest-rank (no interpolation): an SLO predictor must report a latency
    # that was actually observed — interpolating between the two top samples
    # under-reports p99 on small windows (a 2-sample window's p99 would fall
    # just below its own slowest sample)
    return float(
        np.percentile(np.fromiter(window, dtype=float), q, method="higher")
    )


@dataclasses.dataclass
class BucketStats:
    """Served-count / latency aggregates for one device-program bucket.

    Three separate sliding windows: end-to-end latency (the admission
    predictor), queue wait (submit → batch pickup) and batch compute (the
    device program) — previously one window conflated wait with compute, so
    a deep queue read as a slow device.  Each window wraps independently at
    ``LATENCY_WINDOW`` samples and reports its own p50/p99.
    """

    served: int = 0
    batches: int = 0
    total_latency_s: float = 0.0
    max_latency_s: float = 0.0
    window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    queue_window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    compute_window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )

    def record(
        self,
        latency_s: float,
        queue_s: Optional[float] = None,
        compute_s: Optional[float] = None,
    ) -> None:
        self.served += 1
        self.total_latency_s += latency_s
        self.max_latency_s = max(self.max_latency_s, latency_s)
        self.window.append(latency_s)
        if queue_s is not None:
            self.queue_window.append(queue_s)
        if compute_s is not None:
            self.compute_window.append(compute_s)

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / self.served if self.served else 0.0

    def latency_quantile_s(self, q: float) -> float:
        """Latency quantile (q in [0,100]) over the recent sample window."""
        return _window_quantile(self.window, q)

    def as_dict(self) -> Dict[str, float]:
        return {
            "served": self.served,
            "batches": self.batches,
            "mean_latency_s": self.mean_latency_s,
            "p50_latency_s": self.latency_quantile_s(50.0),
            "p99_latency_s": self.latency_quantile_s(99.0),
            "max_latency_s": self.max_latency_s,
            "p50_queue_s": _window_quantile(self.queue_window, 50.0),
            "p99_queue_s": _window_quantile(self.queue_window, 99.0),
            "p50_compute_s": _window_quantile(self.compute_window, 50.0),
            "p99_compute_s": _window_quantile(self.compute_window, 99.0),
        }


def bucket_stats_dict(
    buckets: Dict[Hashable, BucketStats],
    queue_depth: Optional[Dict[Hashable, int]] = None,
) -> Dict[Hashable, Dict[str, float]]:
    """Per-bucket stat dicts, each carrying its live ``queue_depth``.

    Buckets with no queued work report ``queue_depth`` 0 (they are NOT
    omitted): a bucket enters the map at submit time, so admission and SLO
    policy always see a defined entry — including before the first serve.
    """
    depth = queue_depth or {}
    out = {}
    for b, s in sorted(buckets.items()):
        d = s.as_dict()
        d["queue_depth"] = depth.get(b, 0)
        out[b] = d
    return out


@dataclasses.dataclass
class ParseRequest:
    rid: int
    text: Union[bytes, str]
    # cached at submit so scheduling never re-tokenizes or re-buckets queued
    # texts (bucket_shape is pure in (len, n_chunks) — computing it per step
    # was O(queue) redundant work per batch):
    classes: Optional[np.ndarray] = None
    bucket: Optional[Tuple[int, int]] = None
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    # tracing: minted at submit when the engine's tracer is enabled; the
    # root span id lets retroactive queue-wait/compute spans parent to the
    # ``parse.request`` root the ticket emits at collection
    trace_id: Optional[str] = None
    root_span_id: Optional[str] = None
    # filled by the service:
    slpf: Optional[SLPF] = None
    latency_s: Optional[float] = None
    queue_s: Optional[float] = None
    compute_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.slpf is not None


class ParseService:
    """Bucket-batched request scheduler over ``ParserEngine.parse_batch``."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro: constructing ParseService directly is deprecated — use "
            "repro.Parser (repro/api.py): parser.submit()/parse_batch() own "
            "service construction and admission policy",
            DeprecationWarning,
            stacklevel=2,
        )
        self._init(*args, **kwargs)

    @classmethod
    def _internal(cls, *args, **kwargs) -> "ParseService":
        """Facade-owned construction path (no deprecation warning)."""
        self = object.__new__(cls)
        self._init(*args, **kwargs)
        return self

    def _init(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        max_batch: int = 8,
        n_chunks: int = 8,
        max_pending: Optional[int] = None,
        mesh=None,
        mesh_rules=None,
    ):
        self.engine = _resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
        self.max_batch = max(1, max_batch)
        self.n_chunks = n_chunks
        self.max_pending = max_pending
        self._queue: Deque[ParseRequest] = deque()
        self._done: List[ParseRequest] = []
        self._next_rid = 0
        self.batches_run = 0
        self._peak_queue_depth = 0
        self._buckets: Dict[Tuple[int, int], BucketStats] = {}

    # ------------------------------------------------------------- admission

    def admission_p99_s(self, bucket: Tuple[int, int]) -> float:
        """Observed p99 latency of one bucket — the admission predictor.

        Defined for EVERY bucket, including one no request has mapped to
        yet: a cold bucket has an empty sample window and predicts 0.0
        (optimistic — the first request is always admitted and its latency
        seeds the window).
        """
        stats = self._buckets.get(bucket)
        return stats.latency_quantile_s(99.0) if stats is not None else 0.0

    def _admit(self, bucket: Tuple[int, int], deadline_s: Optional[float]) -> None:
        """Deadline-aware admission: reject work predicted to miss its deadline.

        ``deadline_s`` is the request's REMAINING latency budget in seconds.
        The predictor is the bucket's observed p99 over the sliding window —
        if p99 already exceeds the budget (or the budget is already blown),
        serving the request would almost surely miss, so it is rejected
        up-front with ``AdmissionError`` instead of wasting a batch slot.
        """
        m = self.engine.obs.metrics
        if self.max_pending is not None and len(self._queue) >= self.max_pending:
            m.counter(
                "admission_rejects_total", service="parse", cause="budget"
            ).inc()
            raise BudgetExceeded(
                f"parse queue is at its max_pending budget ({self.max_pending})",
                budget=self.max_pending,
                requested=len(self._queue) + 1,
            )
        if deadline_s is None:
            return
        predicted = self.admission_p99_s(bucket)
        if deadline_s <= 0.0 or predicted > deadline_s:
            m.counter(
                "admission_rejects_total", service="parse", cause="deadline"
            ).inc()
            raise AdmissionError(
                f"bucket {bucket} p99 {predicted * 1e3:.1f}ms exceeds the "
                f"remaining deadline {deadline_s * 1e3:.1f}ms",
                bucket=bucket,
                deadline_s=deadline_s,
                predicted_s=predicted,
            )

    def submit_request(
        self, text: Union[bytes, str], *, deadline_s: Optional[float] = None
    ) -> ParseRequest:
        """Enqueue a text; returns its (live) request record.

        With ``deadline_s`` the request passes deadline-aware admission
        first and may raise ``AdmissionError``/``BudgetExceeded``; the
        returned object's ``slpf``/``latency_s`` fields fill in place when a
        ``step`` serves its bucket.
        """
        classes = self.engine.classes_of_text(text)
        bucket = self.engine.bucket_shape(len(classes), self.n_chunks)
        self._admit(bucket, deadline_s)
        # the bucket is observable (served=0, queue_depth>0) from this moment
        self._buckets.setdefault(bucket, BucketStats())
        obs = self.engine.obs
        req = ParseRequest(
            rid=self._next_rid,
            text=text,
            classes=classes,
            bucket=bucket,
            submitted_at=time.perf_counter(),
            trace_id=obs.new_trace_id(),
        )
        if req.trace_id is not None:
            # pre-mint the root span id so queue-wait/compute spans emitted
            # mid-flight can parent to the request root before it is written
            req.root_span_id = obs.tracer._new_span_id()
        self._next_rid += 1
        self._queue.append(req)
        self._peak_queue_depth = max(self._peak_queue_depth, len(self._queue))
        m = obs.metrics
        m.counter("requests_total", service="parse").inc()
        m.counter("chars_total", service="parse").inc(len(classes))
        m.gauge("queue_depth", service="parse").set(len(self._queue))
        m.gauge("peak_queue_depth", service="parse").set(self._peak_queue_depth)
        return req

    def submit(
        self, text: Union[bytes, str], *, deadline_s: Optional[float] = None
    ) -> int:
        """Enqueue a text; returns its request id (see ``submit_request``)."""
        return self.submit_request(text, deadline_s=deadline_s).rid

    def cancel(self, rid: int) -> bool:
        """Drop a not-yet-served request from the queue; False if already
        served (or unknown — a served rid may have been reaped)."""
        for req in self._queue:
            if req.rid == rid:
                self._queue.remove(req)
                m = self.engine.obs.metrics
                m.counter("cancelled_total", service="parse").inc()
                m.gauge("queue_depth", service="parse").set(len(self._queue))
                return True
        return False

    def _bucket_of(self, req: ParseRequest) -> Tuple[int, int]:
        if req.bucket is None:  # externally-constructed request
            req.bucket = self.engine.bucket_shape(len(req.classes), self.n_chunks)
        return req.bucket

    # ---------------------------------------------------------------- serving

    def step(self) -> bool:
        """Serve one batch (the oldest request's bucket); False when idle."""
        if not self._queue:
            return False
        head_bucket = self._bucket_of(self._queue[0])
        batch: List[ParseRequest] = []
        keep: Deque[ParseRequest] = deque()
        while self._queue and len(batch) < self.max_batch:
            req = self._queue.popleft()
            if self._bucket_of(req) == head_bucket:
                batch.append(req)
            else:
                keep.append(req)
        keep.extend(self._queue)  # untouched tail keeps its order
        self._queue = keep

        picked_at = time.perf_counter()
        slpfs = self.engine.parse_batch(
            [req.classes for req in batch], n_chunks=self.n_chunks
        )
        now = time.perf_counter()
        compute_s = now - picked_at
        obs = self.engine.obs
        stats = self._buckets.setdefault(head_bucket, BucketStats())
        for req, slpf in zip(batch, slpfs):
            req.slpf = slpf
            req.latency_s = now - req.submitted_at
            req.queue_s = picked_at - req.submitted_at
            req.compute_s = compute_s
            stats.record(req.latency_s, queue_s=req.queue_s, compute_s=compute_s)
            if req.trace_id is not None:
                # queue residency is only known at pickup: retroactive spans
                obs.emit(
                    "parse.queue_wait",
                    t_start_s=req.submitted_at,
                    duration_s=req.queue_s,
                    trace_id=req.trace_id,
                    parent_id=req.root_span_id,
                    bucket=list(head_bucket),
                )
                obs.emit(
                    "parse.batch_compute",
                    t_start_s=picked_at,
                    duration_s=compute_s,
                    trace_id=req.trace_id,
                    parent_id=req.root_span_id,
                    bucket=list(head_bucket),
                    batch_size=len(batch),
                )
            self._done.append(req)
        stats.batches += 1
        self.batches_run += 1
        m = obs.metrics
        m.counter("served_total", service="parse").inc(len(batch))
        m.counter("batches_total", service="parse").inc()
        m.gauge("queue_depth", service="parse").set(len(self._queue))
        return True

    def run(self) -> List[ParseRequest]:
        """Drain the queue; returns finished requests in completion order."""
        while self.step():
            pass
        out, self._done = self._done, []
        return out

    def reap(self, req: ParseRequest) -> None:
        """Drop one finished request from the completion buffer (the ticket
        path collects results one by one; without this, a long-lived facade
        would accumulate every served request until the next ``run``)."""
        try:
            self._done.remove(req)
        except ValueError:
            pass

    # ------------------------------------------------------------------ stats

    @property
    def compile_count(self) -> int:
        """Distinct device programs compiled by the underlying engine."""
        return self.engine.compile_count

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def stats(self) -> Dict:
        """Queue-depth + per-bucket served/latency aggregates (SLO inputs).

        Every bucket any request has ever mapped to is present — a bucket
        queued but not yet served reports ``served=0`` with its live
        ``queue_depth``, and an idle bucket reports ``queue_depth=0`` —
        so admission always reads a defined entry (no cold-start KeyError).
        """
        depth: Dict[Tuple[int, int], int] = {}
        for req in self._queue:
            b = self._bucket_of(req)
            depth[b] = depth.get(b, 0) + 1
        return {
            "backend": self.engine.backend.name,
            "pending": len(self._queue),
            "peak_queue_depth": self._peak_queue_depth,
            "batches_run": self.batches_run,
            "compile_count": self.compile_count,
            "buckets": bucket_stats_dict(self._buckets, depth),
        }
