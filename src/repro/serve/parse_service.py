"""Request-level batched parsing service over the shape-bucketed engine.

The LM side of this repo serves generation through ``serve/scheduler.py``'s
slot pattern: a fixed set of device-program shapes, host-side request state,
admission the moment capacity frees.  This module is the same pattern for the
*parser*: callers submit texts of arbitrary length; the service groups queued
requests by their static (c, k) chunk bucket, packs up to ``max_batch`` of
them into one batched device program (extra batch slots ride along as all-PAD
rows), and drains bucket by bucket.  Because every program shape comes from
the engine's small bucket set, steady-state serving never recompiles —
``compile_count`` makes that observable.

Scheduling policy: each ``step`` serves the bucket holding the *oldest*
queued request (FIFO fairness), batching every same-bucket request behind it
up to ``max_batch`` — mixed-length traffic aggregates into full batches
without head-of-line blocking on rare shapes.

Instrumentation (``ParseService.stats``): queue depth (current and peak) and
per-bucket served-count / batch-count / latency aggregates including p50/p99
over a sliding sample window — the observables the ROADMAP's SLO item
(latency targets, deadline-aware admission) builds on.
``serve/stream_service.py`` exposes the same stats shape for streaming
sessions.

Distribution: ``ParseService(..., mesh=...)`` builds a mesh-aware engine, so
every served bucket runs sharded-batched (batch slots over 'data', chunks
over 'pod' — ``core/distributed.py``); the scheduling layer is unchanged.

Backends: ``ParseService(..., backend=...)`` plumbs straight to the engine —
"jnp", "pallas", or the bit-packed "packed" backend (uint32 OR-AND word ops,
32× less product bandwidth for large automata) serve through the identical
scheduling layer; ``stats["backend"]`` reports which one is live.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, Hashable, List, Optional, Tuple, Union

import numpy as np

from ..core.backend import ParserBackend
from ..core.engine import resolve_engine
from ..core.slpf import SLPF

# Per-bucket latency sample window for the p50/p99 estimates: percentiles are
# exact over the most recent LATENCY_WINDOW served requests (a sorted-window
# estimator — O(window) memory per bucket, robust to traffic drift, unlike a
# lossy fixed-size reservoir over all time).
LATENCY_WINDOW = 512


@dataclasses.dataclass
class BucketStats:
    """Served-count / latency aggregates for one device-program bucket."""

    served: int = 0
    batches: int = 0
    total_latency_s: float = 0.0
    max_latency_s: float = 0.0
    window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )

    def record(self, latency_s: float) -> None:
        self.served += 1
        self.total_latency_s += latency_s
        self.max_latency_s = max(self.max_latency_s, latency_s)
        self.window.append(latency_s)

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / self.served if self.served else 0.0

    def latency_quantile_s(self, q: float) -> float:
        """Latency quantile (q in [0,100]) over the recent sample window."""
        if not self.window:
            return 0.0
        return float(np.percentile(np.fromiter(self.window, dtype=float), q))

    def as_dict(self) -> Dict[str, float]:
        return {
            "served": self.served,
            "batches": self.batches,
            "mean_latency_s": self.mean_latency_s,
            "p50_latency_s": self.latency_quantile_s(50.0),
            "p99_latency_s": self.latency_quantile_s(99.0),
            "max_latency_s": self.max_latency_s,
        }


def bucket_stats_dict(
    buckets: Dict[Hashable, BucketStats]
) -> Dict[Hashable, Dict[str, float]]:
    return {b: s.as_dict() for b, s in sorted(buckets.items())}


@dataclasses.dataclass
class ParseRequest:
    rid: int
    text: Union[bytes, str]
    # cached at submit so scheduling never re-tokenizes or re-buckets queued
    # texts (bucket_shape is pure in (len, n_chunks) — computing it per step
    # was O(queue) redundant work per batch):
    classes: Optional[np.ndarray] = None
    bucket: Optional[Tuple[int, int]] = None
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    # filled by the service:
    slpf: Optional[SLPF] = None
    latency_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.slpf is not None


class ParseService:
    """Bucket-batched request scheduler over ``ParserEngine.parse_batch``."""

    def __init__(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        max_batch: int = 8,
        n_chunks: int = 8,
        mesh=None,
        mesh_rules=None,
    ):
        self.engine = resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
        self.max_batch = max(1, max_batch)
        self.n_chunks = n_chunks
        self._queue: Deque[ParseRequest] = deque()
        self._done: List[ParseRequest] = []
        self._next_rid = 0
        self.batches_run = 0
        self._peak_queue_depth = 0
        self._buckets: Dict[Tuple[int, int], BucketStats] = {}

    # ------------------------------------------------------------- admission

    def submit(self, text: Union[bytes, str]) -> int:
        """Enqueue a text; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        classes = self.engine.classes_of_text(text)
        self._queue.append(
            ParseRequest(
                rid=rid,
                text=text,
                classes=classes,
                bucket=self.engine.bucket_shape(len(classes), self.n_chunks),
                submitted_at=time.perf_counter(),
            )
        )
        self._peak_queue_depth = max(self._peak_queue_depth, len(self._queue))
        return rid

    def _bucket_of(self, req: ParseRequest) -> Tuple[int, int]:
        if req.bucket is None:  # externally-constructed request
            req.bucket = self.engine.bucket_shape(len(req.classes), self.n_chunks)
        return req.bucket

    # ---------------------------------------------------------------- serving

    def step(self) -> bool:
        """Serve one batch (the oldest request's bucket); False when idle."""
        if not self._queue:
            return False
        head_bucket = self._bucket_of(self._queue[0])
        batch: List[ParseRequest] = []
        keep: Deque[ParseRequest] = deque()
        while self._queue and len(batch) < self.max_batch:
            req = self._queue.popleft()
            if self._bucket_of(req) == head_bucket:
                batch.append(req)
            else:
                keep.append(req)
        keep.extend(self._queue)  # untouched tail keeps its order
        self._queue = keep

        slpfs = self.engine.parse_batch(
            [req.classes for req in batch], n_chunks=self.n_chunks
        )
        now = time.perf_counter()
        stats = self._buckets.setdefault(head_bucket, BucketStats())
        for req, slpf in zip(batch, slpfs):
            req.slpf = slpf
            req.latency_s = now - req.submitted_at
            stats.record(req.latency_s)
            self._done.append(req)
        stats.batches += 1
        self.batches_run += 1
        return True

    def run(self) -> List[ParseRequest]:
        """Drain the queue; returns finished requests in completion order."""
        while self.step():
            pass
        out, self._done = self._done, []
        return out

    # ------------------------------------------------------------------ stats

    @property
    def compile_count(self) -> int:
        """Distinct device programs compiled by the underlying engine."""
        return self.engine.compile_count

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def stats(self) -> Dict:
        """Queue-depth + per-bucket served/latency aggregates (SLO inputs)."""
        return {
            "backend": self.engine.backend.name,
            "pending": len(self._queue),
            "peak_queue_depth": self._peak_queue_depth,
            "batches_run": self.batches_run,
            "compile_count": self.compile_count,
            "buckets": bucket_stats_dict(self._buckets),
        }
