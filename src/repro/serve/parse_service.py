"""Request-level batched parsing service over the shape-bucketed engine.

The LM side of this repo serves generation through ``serve/scheduler.py``'s
slot pattern: a fixed set of device-program shapes, host-side request state,
admission the moment capacity frees.  This module is the same pattern for the
*parser*: callers submit texts of arbitrary length; the service groups queued
requests by their static (c, k) chunk bucket, packs up to ``max_batch`` of
them into one batched device program (extra batch slots ride along as all-PAD
rows), and drains bucket by bucket.  Because every program shape comes from
the engine's small bucket set, steady-state serving never recompiles —
``compile_count`` makes that observable.

Scheduling policy — weighted-fair across tenants, FIFO within one: every
request belongs to a *tenant* (a traffic class with a ``weight``; the
implicit ``"default"`` tenant makes the single-tenant service exactly the
old FIFO).  Each ``step`` picks the active tenant with the least virtual
time (``vtime``, advanced by served-chars/weight — classic WFQ), takes that
tenant's oldest request as the batch head, and fills the rest of the batch
with same-bucket requests in global FIFO order from ANY tenant (riders are
free: they share the head's device program, and each charges its own
tenant).  A hot tenant's vtime races ahead, so a light tenant's next request
is picked as soon as it arrives — no starvation — while newly-active tenants
are floored to the scheduler's clock so idle time banks no credit.

Instrumentation (``ParseService.stats``): queue depth (current and peak),
per-bucket served-count / queue-depth / latency aggregates including p50/p99
over a sliding sample window, and per-tenant aggregates (weight, vtime,
pending, served, latency percentiles, cancels, rejects) under ``"tenants"``.
A bucket appears in ``stats`` from the moment a request maps to it at submit
— before the first serve — with ``served=0`` and its live ``queue_depth``,
so the deadline-admission policy below has a defined cold-start observable.
``serve/stream_service.py`` exposes the same stats shape for streaming
sessions.

Admission (the ROADMAP SLO item): ``submit(text, deadline=...)`` rejects a
request with ``repro.errors.AdmissionError`` when its bucket's observed p99
latency already exceeds the remaining deadline (a cold bucket predicts 0.0
and admits); ``max_pending`` bounds the whole queue and a tenant's own
``max_pending`` bounds its share, both with ``repro.errors.BudgetExceeded``.
Policy knobs (per-bucket latency targets, default deadlines, tenant weights)
live in ``repro/api.py``'s ``ParserConfig`` — the facade is the supported
construction path; building ``ParseService`` directly is deprecated.

Cancellation: ``cancel(rid)`` marks the request (O(1)) and the scheduler
purges marked rows *before packing a batch* — a cancelled request never
occupies a batch slot and never records a latency sample, even when the
cancel lands after the scheduler has already chosen its bucket.

Distribution: ``ParseService(..., mesh=...)`` builds a mesh-aware engine, so
every served bucket runs sharded-batched (batch slots over 'data', chunks
over 'pod' — ``core/distributed.py``); the scheduling layer is unchanged.

Backends: ``ParseService(..., backend=...)`` plumbs straight to the engine;
``stats["backend"]`` reports which one is live.  ``FleetParseService``
(below) runs the same scheduler over a ``core/fleet.py`` ``FleetEngine`` —
many automata, tenant-batched device programs — by overriding only the
classes/bucket and execute seams.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Deque, Dict, Hashable, List, Optional, Tuple, Union

import numpy as np

from ..core.backend import ParserBackend
from ..core.engine import _resolve_engine
from ..core.slpf import SLPF
from ..errors import AdmissionError, BudgetExceeded

# Per-bucket latency sample window for the p50/p99 estimates: percentiles are
# exact over the most recent LATENCY_WINDOW served requests (a sorted-window
# estimator — O(window) memory per bucket, robust to traffic drift, unlike a
# lossy fixed-size reservoir over all time).
LATENCY_WINDOW = 512


def _window_quantile(window: Deque[float], q: float) -> float:
    if not window:
        return 0.0
    # nearest-rank (no interpolation): an SLO predictor must report a latency
    # that was actually observed — interpolating between the two top samples
    # under-reports p99 on small windows (a 2-sample window's p99 would fall
    # just below its own slowest sample)
    return float(
        np.percentile(np.fromiter(window, dtype=float), q, method="higher")
    )


@dataclasses.dataclass
class BucketStats:
    """Served-count / latency aggregates for one device-program bucket.

    Three separate sliding windows: end-to-end latency (the admission
    predictor), queue wait (submit → batch pickup) and batch compute (the
    device program) — previously one window conflated wait with compute, so
    a deep queue read as a slow device.  Each window wraps independently at
    ``LATENCY_WINDOW`` samples and reports its own p50/p99.
    """

    served: int = 0
    batches: int = 0
    total_latency_s: float = 0.0
    max_latency_s: float = 0.0
    window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    queue_window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    compute_window: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )

    def record(
        self,
        latency_s: float,
        queue_s: Optional[float] = None,
        compute_s: Optional[float] = None,
    ) -> None:
        self.served += 1
        self.total_latency_s += latency_s
        self.max_latency_s = max(self.max_latency_s, latency_s)
        self.window.append(latency_s)
        if queue_s is not None:
            self.queue_window.append(queue_s)
        if compute_s is not None:
            self.compute_window.append(compute_s)

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / self.served if self.served else 0.0

    def latency_quantile_s(self, q: float) -> float:
        """Latency quantile (q in [0,100]) over the recent sample window."""
        return _window_quantile(self.window, q)

    def as_dict(self) -> Dict[str, float]:
        return {
            "served": self.served,
            "batches": self.batches,
            "mean_latency_s": self.mean_latency_s,
            "p50_latency_s": self.latency_quantile_s(50.0),
            "p99_latency_s": self.latency_quantile_s(99.0),
            "max_latency_s": self.max_latency_s,
            "p50_queue_s": _window_quantile(self.queue_window, 50.0),
            "p99_queue_s": _window_quantile(self.queue_window, 99.0),
            "p50_compute_s": _window_quantile(self.compute_window, 50.0),
            "p99_compute_s": _window_quantile(self.compute_window, 99.0),
        }


def bucket_stats_dict(
    buckets: Dict[Hashable, BucketStats],
    queue_depth: Optional[Dict[Hashable, int]] = None,
) -> Dict[Hashable, Dict[str, float]]:
    """Per-bucket stat dicts, each carrying its live ``queue_depth``.

    Buckets with no queued work report ``queue_depth`` 0 (they are NOT
    omitted): a bucket enters the map at submit time, so admission and SLO
    policy always see a defined entry — including before the first serve.
    """
    depth = queue_depth or {}
    out = {}
    for b, s in sorted(buckets.items()):
        d = s.as_dict()
        d["queue_depth"] = depth.get(b, 0)
        out[b] = d
    return out


@dataclasses.dataclass
class TenantState:
    """Host-side scheduling + SLO state of one traffic class.

    ``vtime`` is the tenant's weighted-fair virtual time: it advances by
    served-characters / ``weight`` whenever one of the tenant's requests is
    served, so at equal demand a weight-2 tenant is scheduled twice as often
    as a weight-1 one.  ``stats`` reuses ``BucketStats`` — the same latency
    windows that drive per-bucket admission give per-tenant SLO grades.
    """

    name: str
    weight: float = 1.0
    max_pending: Optional[int] = None
    vtime: float = 0.0
    pending: int = 0
    cancelled: int = 0
    rejects: int = 0
    stats: BucketStats = dataclasses.field(default_factory=BucketStats)

    def as_dict(self) -> Dict[str, float]:
        d = self.stats.as_dict()
        d.update(
            weight=self.weight,
            vtime=self.vtime,
            pending=self.pending,
            cancelled=self.cancelled,
            rejects=self.rejects,
        )
        return d


@dataclasses.dataclass
class ParseRequest:
    rid: int
    text: Union[bytes, str]
    tenant: str = "default"
    # cached at submit so scheduling never re-tokenizes or re-buckets queued
    # texts (bucket_shape is pure in (len, n_chunks) — computing it per step
    # was O(queue) redundant work per batch):
    classes: Optional[np.ndarray] = None
    bucket: Optional[Hashable] = None
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    # cancellation is a flag, not a queue removal: the scheduler purges
    # flagged rows before packing, so a cancel landing after batch selection
    # still never burns a batch slot nor records a latency sample
    cancelled: bool = False
    # tracing: minted at submit when the engine's tracer is enabled; the
    # root span id lets retroactive queue-wait/compute spans parent to the
    # ``parse.request`` root the ticket emits at collection
    trace_id: Optional[str] = None
    root_span_id: Optional[str] = None
    # filled by the service:
    slpf: Optional[SLPF] = None
    latency_s: Optional[float] = None
    queue_s: Optional[float] = None
    compute_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.slpf is not None


class ParseService:
    """Bucket-batched, weighted-fair request scheduler over
    ``ParserEngine.parse_batch``."""

    # the single-engine service auto-registers a tenant on first use so
    # plain ``submit(text)`` keeps working; the fleet service turns this
    # off — an unknown tenant has no automaton to parse with
    _auto_tenants = True

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro: constructing ParseService directly is deprecated — use "
            "repro.Parser (repro/api.py): parser.submit()/parse_batch() own "
            "service construction and admission policy",
            DeprecationWarning,
            stacklevel=2,
        )
        self._init(*args, **kwargs)

    @classmethod
    def _internal(cls, *args, **kwargs) -> "ParseService":
        """Facade-owned construction path (no deprecation warning)."""
        self = object.__new__(cls)
        self._init(*args, **kwargs)
        return self

    def _init(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        max_batch: int = 8,
        n_chunks: int = 8,
        max_pending: Optional[int] = None,
        mesh=None,
        mesh_rules=None,
    ):
        self.engine = _resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
        self.max_batch = max(1, max_batch)
        self.n_chunks = n_chunks
        self.max_pending = max_pending
        self._init_queue_state()

    def set_pattern_guard(self, verdict: str, mode: str) -> None:
        """Install the static analyzer's verdict on this service's admission
        path (``repro.analyze``): under ``mode="strict"`` a ``pathological``
        verdict rejects every request with ``PathologicalPatternError``
        before any queueing.  The facade wires this from the construction-
        time analysis; directly-assembled services default to no guard."""
        self._pattern_guard = (verdict, mode)

    def _check_pattern_guard(self) -> None:
        verdict, mode = getattr(self, "_pattern_guard", ("ok", "off"))
        if mode == "strict" and verdict == "pathological":
            from ..errors import PathologicalPatternError

            self.engine.obs.metrics.counter(
                "admission_rejects_total", service="parse", cause="pathological"
            ).inc()
            raise PathologicalPatternError(
                "this service's pattern was diagnosed pathologically "
                'ambiguous; analyze="strict" refuses to serve it',
                ambiguity="pathological",
            )

    def _init_queue_state(self) -> None:
        self._queue: Deque[ParseRequest] = deque()
        self._by_rid: Dict[int, ParseRequest] = {}
        self._n_pending = 0
        self._done: List[ParseRequest] = []
        self._next_rid = 0
        self.batches_run = 0
        self._peak_queue_depth = 0
        self._buckets: Dict[Hashable, BucketStats] = {}
        self._tenants: Dict[str, TenantState] = {}
        self._vclock = 0.0  # vtime of the most recently scheduled tenant
        # hot-path metric handles: registry get-or-create hashes the label
        # set on every call, which shows up at fleet request rates
        m = self.engine.obs.metrics
        self._m_requests_total = m.counter("requests_total", service="parse")
        self._m_chars_total = m.counter("chars_total", service="parse")
        self._m_served_total = m.counter("served_total", service="parse")
        self._m_batches_total = m.counter("batches_total", service="parse")
        self._m_queue_depth = m.gauge("queue_depth", service="parse")
        self._m_peak_queue_depth = m.gauge(
            "peak_queue_depth", service="parse"
        )

    # -------------------------------------------------------------- tenants

    def register_tenant(
        self,
        name: str,
        *,
        weight: float = 1.0,
        max_pending: Optional[int] = None,
    ) -> TenantState:
        """Declare a traffic class.  ``weight`` sets its fair share of
        scheduling (chars served per unit of virtual time); ``max_pending``
        caps ITS queue residency independently of the service-wide cap."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        ts = self._tenants.get(name)
        if ts is None:
            ts = TenantState(name=name, weight=weight, max_pending=max_pending)
            # late arrivals start at the scheduler's clock, not at 0: an
            # idle past must not bank scheduling credit
            ts.vtime = self._vclock
            self._tenants[name] = ts
        else:
            ts.weight = weight
            ts.max_pending = max_pending
        return ts

    def _tenant(self, name: str) -> TenantState:
        ts = self._tenants.get(name)
        if ts is None:
            if not self._auto_tenants:
                raise KeyError(f"unknown tenant {name!r}")
            ts = self.register_tenant(name)
        return ts

    # ------------------------------------------------------------- admission

    def admission_p99_s(self, bucket: Hashable) -> float:
        """Observed p99 latency of one bucket — the admission predictor.

        Defined for EVERY bucket, including one no request has mapped to
        yet: a cold bucket has an empty sample window and predicts 0.0
        (optimistic — the first request is always admitted and its latency
        seeds the window).
        """
        stats = self._buckets.get(bucket)
        return stats.latency_quantile_s(99.0) if stats is not None else 0.0

    def _admit(
        self,
        bucket: Hashable,
        deadline_s: Optional[float],
        tenant: Optional[TenantState] = None,
    ) -> None:
        """Deadline-aware admission: reject work predicted to miss its deadline.

        ``deadline_s`` is the request's REMAINING latency budget in seconds.
        The predictor is the bucket's observed p99 over the sliding window —
        if p99 already exceeds the budget (or the budget is already blown),
        serving the request would almost surely miss, so it is rejected
        up-front with ``AdmissionError`` instead of wasting a batch slot.
        A tenant's own ``max_pending`` budget is enforced first: one tenant
        flooding the queue bounces off its own cap, not the shared one.
        """
        self._check_pattern_guard()
        m = self.engine.obs.metrics
        if self.max_pending is not None and self._n_pending >= self.max_pending:
            m.counter(
                "admission_rejects_total", service="parse", cause="budget"
            ).inc()
            if tenant is not None:
                tenant.rejects += 1
            raise BudgetExceeded(
                f"parse queue is at its max_pending budget ({self.max_pending})",
                budget=self.max_pending,
                requested=self._n_pending + 1,
            )
        if (
            tenant is not None
            and tenant.max_pending is not None
            and tenant.pending >= tenant.max_pending
        ):
            m.counter(
                "admission_rejects_total", service="parse", cause="tenant_budget"
            ).inc()
            tenant.rejects += 1
            raise BudgetExceeded(
                f"tenant {tenant.name!r} is at its max_pending budget "
                f"({tenant.max_pending})",
                budget=tenant.max_pending,
                requested=tenant.pending + 1,
            )
        if deadline_s is None:
            return
        predicted = self.admission_p99_s(bucket)
        if deadline_s <= 0.0 or predicted > deadline_s:
            m.counter(
                "admission_rejects_total", service="parse", cause="deadline"
            ).inc()
            if tenant is not None:
                tenant.rejects += 1
            raise AdmissionError(
                f"bucket {bucket} p99 {predicted * 1e3:.1f}ms exceeds the "
                f"remaining deadline {deadline_s * 1e3:.1f}ms",
                bucket=bucket,
                deadline_s=deadline_s,
                predicted_s=predicted,
            )

    # -------------------------------------------------------------- planning

    def _classes_and_bucket(
        self, text: Union[bytes, str], tenant: str
    ) -> Tuple[np.ndarray, Hashable]:
        """Submit-time planning seam: (class array, batching bucket).

        The base service has one automaton, so the tenant only matters for
        scheduling; ``FleetParseService`` overrides this to route through
        the tenant's own tables and automaton bucket.
        """
        classes = self.engine.classes_of_text(text)
        return classes, self.engine.bucket_shape(len(classes), self.n_chunks)

    def submit_request(
        self,
        text: Union[bytes, str],
        *,
        deadline_s: Optional[float] = None,
        tenant: str = "default",
    ) -> ParseRequest:
        """Enqueue a text; returns its (live) request record.

        With ``deadline_s`` the request passes deadline-aware admission
        first and may raise ``AdmissionError``/``BudgetExceeded``; the
        returned object's ``slpf``/``latency_s`` fields fill in place when a
        ``step`` serves its bucket.
        """
        ts = self._tenant(tenant)
        classes, bucket = self._classes_and_bucket(text, tenant)
        self._admit(bucket, deadline_s, tenant=ts)
        # the bucket is observable (served=0, queue_depth>0) from this moment
        self._buckets.setdefault(bucket, BucketStats())
        obs = self.engine.obs
        req = ParseRequest(
            rid=self._next_rid,
            text=text,
            tenant=tenant,
            classes=classes,
            bucket=bucket,
            submitted_at=time.perf_counter(),
            trace_id=obs.new_trace_id(),
        )
        if req.trace_id is not None:
            # pre-mint the root span id so queue-wait/compute spans emitted
            # mid-flight can parent to the request root before it is written
            req.root_span_id = obs.tracer._new_span_id()
        self._next_rid += 1
        if ts.pending == 0:
            # WFQ activation floor: a tenant waking from idle resumes at the
            # scheduler's clock (idle time banks no credit), but keeps its
            # own vtime if it is already ahead
            ts.vtime = max(ts.vtime, self._vclock)
        ts.pending += 1
        self._queue.append(req)
        self._by_rid[req.rid] = req
        self._n_pending += 1
        self._peak_queue_depth = max(self._peak_queue_depth, self._n_pending)
        self._m_requests_total.inc()
        self._m_chars_total.inc(len(classes))
        self._m_queue_depth.set(self._n_pending)
        self._m_peak_queue_depth.set(self._peak_queue_depth)
        return req

    def submit(
        self,
        text: Union[bytes, str],
        *,
        deadline_s: Optional[float] = None,
        tenant: str = "default",
    ) -> int:
        """Enqueue a text; returns its request id (see ``submit_request``)."""
        return self.submit_request(text, deadline_s=deadline_s, tenant=tenant).rid

    def cancel(self, rid: int) -> bool:
        """Cancel a not-yet-served request; False if already served (or
        unknown — a served rid may have been reaped).

        O(1): the request is flagged, not searched out of the queue; the
        scheduler skips flagged rows before packing any batch, so the
        request is guaranteed to never occupy a batch slot nor record a
        latency sample — even when this call lands after the scheduler has
        already selected the request's bucket for the next batch.
        """
        req = self._by_rid.pop(rid, None)
        if req is None or req.done:
            return False
        req.cancelled = True
        ts = self._tenants.get(req.tenant)
        if ts is not None:
            ts.pending -= 1
            ts.cancelled += 1
        self._n_pending -= 1
        m = self.engine.obs.metrics
        m.counter("cancelled_total", service="parse").inc()
        m.gauge("queue_depth", service="parse").set(self._n_pending)
        return True

    def _bucket_of(self, req: ParseRequest) -> Hashable:
        if req.bucket is None:  # externally-constructed request
            req.bucket = self.engine.bucket_shape(len(req.classes), self.n_chunks)
        return req.bucket

    # ---------------------------------------------------------------- serving

    def _execute(self, bucket: Hashable, batch: List[ParseRequest]) -> List[SLPF]:
        """Device-dispatch seam: parse one same-bucket batch.

        ``FleetParseService`` overrides this to run the bucket's
        tenant-batched fleet program.
        """
        return self.engine.parse_batch(
            [req.classes for req in batch], n_chunks=self.n_chunks
        )

    def _pick_tenant(self) -> TenantState:
        """Weighted-fair pick: the active tenant with the least virtual time
        (name-ordered tie-break keeps the choice deterministic)."""
        return min(
            (ts for ts in self._tenants.values() if ts.pending > 0),
            key=lambda ts: (ts.vtime, ts.name),
        )

    def step(self) -> bool:
        """Serve one batch; False when idle.

        The batch head is the oldest request of the least-vtime active
        tenant (weighted-fair); the rest of the batch fills with same-bucket
        requests in global FIFO order from any tenant — riders share the
        head's device program and each charges its own tenant's vtime.
        Cancelled rows are purged here, before packing: they never reach a
        batch slot.
        """
        if self._n_pending == 0:
            # any residue is cancelled rows awaiting lazy purge
            self._queue.clear()
            return False
        picked = self._pick_tenant()
        self._vclock = picked.vtime
        # the picked tenant's oldest live request anchors the batch: its
        # bucket decides which device program runs
        head = next(
            req
            for req in self._queue
            if not req.cancelled and req.tenant == picked.name
        )
        head_bucket = self._bucket_of(head)
        batch: List[ParseRequest] = []
        keep: Deque[ParseRequest] = deque()
        head_seen = False
        # one FIFO pass: drop cancelled rows, pack the head plus same-bucket
        # riders from ANY queue position — riders queued ahead of the head
        # ride too (one slot stays reserved so they cannot crowd it out)
        for req in self._queue:
            if req.cancelled:
                continue
            if req is head:
                batch.append(req)
                head_seen = True
            elif (
                len(batch) + (0 if head_seen else 1) < self.max_batch
                and self._bucket_of(req) == head_bucket
            ):
                batch.append(req)
            else:
                keep.append(req)
        self._queue = keep

        picked_at = time.perf_counter()
        slpfs = self._execute(head_bucket, batch)
        now = time.perf_counter()
        compute_s = now - picked_at
        obs = self.engine.obs
        stats = self._buckets.setdefault(head_bucket, BucketStats())
        for req, slpf in zip(batch, slpfs):
            req.slpf = slpf
            req.latency_s = now - req.submitted_at
            req.queue_s = picked_at - req.submitted_at
            req.compute_s = compute_s
            stats.record(req.latency_s, queue_s=req.queue_s, compute_s=compute_s)
            ts = self._tenants.get(req.tenant)
            if ts is not None:
                ts.pending -= 1
                ts.vtime += len(req.classes) / ts.weight
                ts.stats.record(
                    req.latency_s, queue_s=req.queue_s, compute_s=compute_s
                )
            self._by_rid.pop(req.rid, None)
            self._n_pending -= 1
            if req.trace_id is not None:
                # queue residency is only known at pickup: retroactive spans
                obs.emit(
                    "parse.queue_wait",
                    t_start_s=req.submitted_at,
                    duration_s=req.queue_s,
                    trace_id=req.trace_id,
                    parent_id=req.root_span_id,
                    bucket=list(head_bucket),
                    tenant=req.tenant,
                )
                obs.emit(
                    "parse.batch_compute",
                    t_start_s=picked_at,
                    duration_s=compute_s,
                    trace_id=req.trace_id,
                    parent_id=req.root_span_id,
                    bucket=list(head_bucket),
                    batch_size=len(batch),
                    tenant=req.tenant,
                )
            self._done.append(req)
        stats.batches += 1
        self.batches_run += 1
        self._m_served_total.inc(len(batch))
        self._m_batches_total.inc()
        self._m_queue_depth.set(self._n_pending)
        return True

    def run(self) -> List[ParseRequest]:
        """Drain the queue; returns finished requests in completion order."""
        while self.step():
            pass
        out, self._done = self._done, []
        return out

    def reap(self, req: ParseRequest) -> None:
        """Drop one finished request from the completion buffer (the ticket
        path collects results one by one; without this, a long-lived facade
        would accumulate every served request until the next ``run``)."""
        try:
            self._done.remove(req)
        except ValueError:
            pass

    # ------------------------------------------------------------------ stats

    @property
    def compile_count(self) -> int:
        """Distinct device programs compiled by the underlying engine."""
        return self.engine.compile_count

    @property
    def pending(self) -> int:
        return self._n_pending

    @property
    def stats(self) -> Dict:
        """Queue-depth + per-bucket and per-tenant aggregates (SLO inputs).

        Every bucket any request has ever mapped to is present — a bucket
        queued but not yet served reports ``served=0`` with its live
        ``queue_depth``, and an idle bucket reports ``queue_depth=0`` —
        so admission always reads a defined entry (no cold-start KeyError).
        """
        depth: Dict[Hashable, int] = {}
        for req in self._queue:
            if req.cancelled:
                continue
            b = self._bucket_of(req)
            depth[b] = depth.get(b, 0) + 1
        return {
            "backend": self.engine.backend.name,
            "pending": self._n_pending,
            "peak_queue_depth": self._peak_queue_depth,
            "batches_run": self.batches_run,
            "compile_count": self.compile_count,
            "buckets": bucket_stats_dict(self._buckets, depth),
            "tenants": {
                name: ts.as_dict() for name, ts in sorted(self._tenants.items())
            },
        }


class FleetParseService(ParseService):
    """The weighted-fair scheduler over a multi-automaton ``FleetEngine``.

    Identical queueing/admission/cancellation/stats machinery; only the two
    seams differ: planning routes a text through its tenant's own tables and
    automaton bucket (``FleetEngine.request_plan``), and execution runs the
    bucket's single tenant-batched device program
    (``FleetEngine.run_bucket``).  Tenants must be registered (they carry
    the automata), so auto-registration is off and ``submit`` requires a
    known tenant name.
    """

    _auto_tenants = False

    def _init(self, fleet_engine, *, max_batch: int = 8, max_pending: Optional[int] = None):
        from ..core.fleet import FleetEngine

        if not isinstance(fleet_engine, FleetEngine):
            raise TypeError(
                "FleetParseService requires a core.fleet.FleetEngine; "
                f"got {type(fleet_engine).__name__}"
            )
        self.engine = fleet_engine
        self.max_batch = max(1, max_batch)
        self.n_chunks = None  # per-tenant: each spec carries its own
        self.max_pending = max_pending
        self._init_queue_state()

    def add_tenant(self, tid: str, spec, matrices=None) -> TenantState:
        """Register one tenant end to end: automaton into its fleet bucket,
        traffic class into the weighted-fair scheduler."""
        self.engine.add_tenant(tid, spec, matrices=matrices)
        return self.register_tenant(
            tid, weight=spec.weight, max_pending=spec.max_pending
        )

    def _classes_and_bucket(self, text, tenant):
        return self.engine.request_plan(tenant, text)

    def _execute(self, bucket, batch):
        return self.engine.run_bucket(
            bucket, [(req.tenant, req.classes) for req in batch]
        )

    def _bucket_of(self, req: ParseRequest):
        if req.bucket is None:  # externally-constructed request
            _, req.bucket = self.engine.request_plan(req.tenant, req.classes)
        return req.bucket
