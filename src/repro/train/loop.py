"""Fault-tolerant training loop (the end-to-end driver of deliverable (b)).

Properties demonstrated (and tested in tests/test_train_e2e.py):
  * resume-from-checkpoint: the loop is a pure function of (checkpoint, step);
    batches come from the seekable pipeline (``batch_at(step)``), so a killed
    job restarted on the same or a DIFFERENT mesh reproduces the exact same
    parameter trajectory (elastic re-meshing via CheckpointManager.restore);
  * crash injection: ``fail_at_step`` raises mid-run for the restart tests;
  * straggler mitigation at the framework level is SPMD-static (equal shards
    by construction); at the cluster level, restart-from-checkpoint plus the
    stateless pipeline is the recovery path (DESIGN §6);
  * metrics stream to JSONL for offline inspection.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeSpec
from ..models.model import init_params
from ..optim.adamw import AdamWConfig, init_opt_state
from ..parallel.sharding import MeshRules, adapt_rules_for
from .checkpoint import CheckpointManager
from .step import (
    TrainPlan,
    abstract_train_inputs,
    make_train_step,
    param_shardings,
    plan_for,
    shape_aware_spec,
)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
    fail_at_step: Optional[int] = None   # crash injection for restart tests


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeSpec,
        mesh: Mesh,
        workdir,
        tcfg: Optional[TrainerConfig] = None,
        opt: Optional[AdamWConfig] = None,
        pipeline=None,
    ):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg or TrainerConfig()
        self.rules = adapt_rules_for(cfg, mesh, MeshRules())
        self.plan = plan_for(cfg, shape, mesh, opt or AdamWConfig())
        self.workdir = Path(workdir)
        self.ckpt = CheckpointManager(self.workdir / "ckpt", keep=self.tcfg.keep_checkpoints)
        self.metrics_path = self.workdir / "metrics.jsonl"
        if pipeline is None:
            from ..data.pipeline import SyntheticLM

            pipeline = SyntheticLM(
                vocab_size=cfg.vocab_size,
                seq_len=shape.seq_len,
                global_batch=shape.global_batch,
                seed=self.tcfg.seed,
            )
        self.pipeline = pipeline

        self._shardings = param_shardings(cfg, mesh, self.rules, self.plan.tp)
        step_fn = make_train_step(self.plan, mesh, self.rules)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------- state

    def init_state(self):
        with jax.default_device(jax.devices()[0]):
            params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed), self.plan.tp)
        params = jax.tree.map(
            lambda p, s: jax.device_put(p, s), params, self._shardings
        )
        opt_state = init_opt_state(params)
        return params, opt_state

    def restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, *self.init_state()
        params_like, opt_like = self.init_state()
        step, (params, opt_state), _ = self.ckpt.restore(
            (params_like, opt_like),
            shardings=(self._shardings, _opt_shardings(opt_like, self._shardings, self.mesh)),
        )
        return step, params, opt_state

    # -------------------------------------------------------------- data

    def device_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        raw = self.pipeline.batch_at(step)
        accum, micro = self.plan.accum_steps, self.plan.microbatch
        toks = raw["tokens"].reshape(accum, micro, self.plan.seq_len)
        spec = shape_aware_spec(toks.shape, (None, "batch", None), self.mesh, self.rules)
        batch = {"tokens": jax.device_put(toks, NamedSharding(self.mesh, spec))}
        if self.cfg.frontend is not None:
            fe = self.cfg.frontend
            extra = np.zeros(
                (accum, micro, fe.n_extra_tokens, fe.feature_dim), np.float32
            )
            espec = shape_aware_spec(extra.shape, (None, "batch", None, None), self.mesh, self.rules)
            batch["extra"] = jax.device_put(
                extra.astype(jnp.dtype(self.cfg.dtype)), NamedSharding(self.mesh, espec)
            )
        return batch

    # -------------------------------------------------------------- run

    def run(self) -> Dict[str, Any]:
        start, params, opt_state = self.restore_or_init()
        history = []
        with self.metrics_path.open("a") as mf:
            for step in range(start, self.tcfg.total_steps):
                if self.tcfg.fail_at_step is not None and step == self.tcfg.fail_at_step:
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.time()
                batch = self.device_batch(step)
                params, opt_state, metrics = self._step(params, opt_state, batch)
                if (step + 1) % self.tcfg.checkpoint_every == 0 or step + 1 == self.tcfg.total_steps:
                    self.ckpt.save(step + 1, (params, opt_state), extra={"loss": float(metrics["loss"])})
                rec = {
                    "step": step + 1,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]),
                    "dt": time.time() - t0,
                }
                history.append(rec)
                if (step + 1) % self.tcfg.log_every == 0 or step == start:
                    mf.write(json.dumps(rec) + "\n")
                    mf.flush()
        self.ckpt.wait()
        return {"history": history, "final_loss": history[-1]["loss"] if history else None}


def _opt_shardings(opt_like, param_shardings, mesh):
    from ..optim.adamw import OptState

    return OptState(
        step=NamedSharding(mesh, P()),
        master=param_shardings,
        m=param_shardings,
        v=param_shardings,
    )
