"""Fault-tolerant checkpointing: atomic, keep-k, mesh-elastic.

Design (DESIGN §6):
  * a checkpoint is a directory ``step_<n>/`` holding one ``.npz`` of flat
    leaves plus a JSON manifest (treedef, shapes, dtypes, step);
  * writes go to ``step_<n>.tmp/`` and are atomically renamed — a crash mid-
    write never corrupts the latest checkpoint (restore picks the newest
    *complete* directory);
  * arrays are saved as full (unsharded) host arrays and re-sharded at load
    onto whatever mesh the restarted job has — **elastic re-meshing**: the
    checkpoint is valid for any device count / topology;
  * ``keep`` newest checkpoints are retained, older ones GC'd after a
    successful write (never before);
  * saving can run on a background thread (``async_save``) so the train loop
    overlaps checkpoint I/O with compute.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


# bfloat16 (ml_dtypes) does not survive npz round-trips: store as uint16 views
# and restore from the manifest dtype.
def _to_storable(arr: np.ndarray) -> np.ndarray:
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16)
    return arr


def _from_storable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        import ml_dtypes

        return arr.view(ml_dtypes.bfloat16)
    return arr


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- paths

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and (p / "manifest.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -------------------------------------------------------------- save

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        self.wait()  # serialize with any in-flight async save
        return self._save_impl(step, tree, extra)

    def _save_impl(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        leaves, treedef = _flatten(tree)
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(
            tmp / "arrays.npz",
            **{f"leaf_{i}": _to_storable(l) for i, l in enumerate(leaves)},
        )
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    def async_save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        # snapshot to host BEFORE returning so the donated buffers may be reused
        leaves, treedef = _flatten(tree)
        host_tree = jax.tree.unflatten(treedef, leaves)
        self.wait()
        self._thread = threading.Thread(
            target=self._save_impl, args=(step, host_tree, extra), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------ restore

    def restore(
        self, like: Any, step: Optional[int] = None, shardings: Optional[Any] = None
    ) -> Tuple[int, Any, Dict]:
        """Load into the structure of ``like``; re-shard onto ``shardings``
        (elastic: the stored arrays are full — any mesh works)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        leaves = [
            _from_storable(data[f"leaf_{i}"], manifest["dtypes"][i])
            for i in range(manifest["n_leaves"])
        ]
        _, treedef = jax.tree.flatten(like)
        tree = jax.tree.unflatten(treedef, leaves)
        like_leaves = jax.tree.leaves(like)
        for stored, want in zip(leaves, like_leaves):
            if tuple(stored.shape) != tuple(want.shape):
                raise ValueError(
                    f"checkpoint leaf shape {stored.shape} != expected {want.shape}"
                )
        if shardings is not None:
            sh_leaves, _ = jax.tree.flatten(shardings)
            tree = jax.tree.unflatten(
                treedef,
                [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)],
            )
        return step, tree, manifest.get("extra", {})
