"""Distributed train / serve steps: sharding, microbatch accumulation, mixed
precision — the device programs lowered by the multi-pod dry-run and driven by
the training loop.

Distribution recipe (DESIGN §6):
  * params: logical axes from the model decls → ('data' fsdp, 'model' tp);
  * batch: leading dim over ('pod', 'data');
  * gradient accumulation via ``lax.scan`` over microbatches — each microbatch
    computes bf16 grads ("compressed" reduction dtype), accumulated in fp32;
    XLA overlaps the per-microbatch reduce-scatter/all-reduce with the next
    microbatch's compute (async collectives);
  * optimizer update in fp32 masters, params re-cast to bf16.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeSpec
from ..models.model import (
    abstract_params,
    decode_step,
    forward_train,
    init_params,
    make_cache,
    param_logical_axes,
    prefill,
)
from ..optim.adamw import AdamWConfig, OptState, abstract_opt_state, apply_updates, init_opt_state
from ..parallel.sharding import MeshRules, adapt_rules_for, divisible

Params = Any


def shape_aware_spec(
    shape: Tuple[int, ...], logical, mesh: Mesh, rules: MeshRules
) -> P:
    """Resolve logical axes to a PartitionSpec, dropping axes whose mesh extent
    does not divide the corresponding dimension (replication is exact)."""
    base = rules.resolve(logical, mesh)
    out = []
    for i, entry in enumerate(base):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % size == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def map_with_logical(abstract, logical, fn):
    """tree.map over (abstract, logical-axes) trees where logical leaves are
    tuples (which are themselves pytrees — use flatten_up_to)."""
    treedef = jax.tree.structure(abstract)
    la = treedef.flatten_up_to(logical)
    ab = jax.tree.leaves(abstract)
    return jax.tree.unflatten(treedef, [fn(a, lg) for a, lg in zip(ab, la)])


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: MeshRules, tp: int):
    return map_with_logical(
        abstract_params(cfg, tp),
        param_logical_axes(cfg, tp),
        lambda a, lg: NamedSharding(mesh, shape_aware_spec(a.shape, lg, mesh, rules)),
    )


def make_shard_fn(mesh: Mesh, rules: MeshRules):
    def shard(t, logical):
        spec = shape_aware_spec(t.shape, logical, mesh, rules)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))
    return shard


# ------------------------------------------------------------------ train


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    cfg: ModelConfig
    opt: AdamWConfig
    accum_steps: int
    microbatch: int          # global sequences per microbatch
    seq_len: int
    tp: int


def plan_for(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh: Mesh,
    opt: Optional[AdamWConfig] = None,
    seqs_per_device: int = 1,
) -> TrainPlan:
    """Pick grad-accumulation: each device sees ``seqs_per_device`` sequences
    per microstep.  Larger microbatches amortize the per-microbatch FSDP
    weight gathers (§Perf mixtral iteration 2) at the cost of activation
    memory — remat keeps one residual per layer per sequence."""
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    tp = mesh.shape.get("model", 1)
    micro = dp * seqs_per_device
    if shape.global_batch % micro != 0:
        micro = dp if shape.global_batch % dp == 0 else shape.global_batch
    micro = min(micro, shape.global_batch)
    accum = max(1, shape.global_batch // micro)
    return TrainPlan(
        cfg=cfg,
        opt=opt or AdamWConfig(),
        accum_steps=accum,
        microbatch=micro,
        seq_len=shape.seq_len,
        tp=tp,
    )


def make_train_step(plan: TrainPlan, mesh: Mesh, rules: MeshRules) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch['tokens']``: (accum, microbatch, seq) int32, microbatch dim sharded
    over ('pod','data').  Donation of params/opt_state enabled by the caller's
    jit (argnums 0, 1).
    """
    cfg, opt = plan.cfg, plan.opt
    shard = make_shard_fn(mesh, rules)
    shardings = param_shardings(cfg, mesh, rules, plan.tp)

    def loss_fn(params, micro):
        total, metrics = forward_train(params, micro, cfg, plan.tp, shard)
        return total, metrics

    def train_step(params: Params, opt_state: OptState, batch: Dict[str, jnp.ndarray]):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def accum_body(carry, micro):
            gacc, lacc = carry
            (loss, metrics), grads = grad_fn(params, micro)
            # constrain per-microbatch grads to the parameter shardings so the
            # DP reduction lowers to reduce-scatter, not all-reduce (§Perf)
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, shardings
            )
            # bf16 gradient "compression" for the DP reduction, fp32 accumulation
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.bfloat16).astype(jnp.float32), gacc, grads
            )
            return (gacc, lacc + metrics["loss"]), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(
            accum_body, (zeros, jnp.zeros((), jnp.float32)), batch
        )
        grads = jax.tree.map(lambda g: g / plan.accum_steps, grads)
        new_params, new_opt, om = apply_updates(
            opt, params, grads, opt_state, jnp.dtype(cfg.param_dtype)
        )
        metrics = {"loss": loss_sum / plan.accum_steps, **om}
        return new_params, new_opt, metrics

    return train_step


# ------------------------------------------------------------------ serve


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, rules: MeshRules, tp: int) -> Callable:
    shard = make_shard_fn(mesh, rules)

    def prefill_step(params, tokens, extra=None):
        return prefill(params, tokens, cfg, tp, shard, extra)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh, rules: MeshRules, tp: int) -> Callable:
    shard = make_shard_fn(mesh, rules)

    def serve_step(params, caches, token):
        return decode_step(params, caches, token, cfg, tp, shard)

    return serve_step


# -------------------------------------------------- abstract inputs (dry-run)


def abstract_train_inputs(cfg: ModelConfig, plan: TrainPlan, mesh: Mesh, rules: MeshRules):
    """(params, opt_state, batch) as sharded ShapeDtypeStructs — no allocation."""
    shardings = param_shardings(cfg, mesh, rules, plan.tp)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_params(cfg, plan.tp),
        shardings,
    )
    opt_abs = abstract_opt_state(params)
    opt_sh = OptState(
        step=NamedSharding(mesh, P()),
        master=shardings,
        m=shardings,
        v=shardings,
    )
    opt_state = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), opt_abs, opt_sh
    )
    bspec = shape_aware_spec(
        (plan.accum_steps, plan.microbatch, plan.seq_len),
        (None, "batch", None),
        mesh,
        rules,
    )
    batch = {
        "tokens": jax.ShapeDtypeStruct(
            (plan.accum_steps, plan.microbatch, plan.seq_len),
            jnp.int32,
            sharding=NamedSharding(mesh, bspec),
        )
    }
    if cfg.frontend is not None:
        fe = cfg.frontend
        fspec = shape_aware_spec(
            (plan.accum_steps, plan.microbatch, fe.n_extra_tokens, fe.feature_dim),
            (None, "batch", None, None),
            mesh,
            rules,
        )
        batch["extra"] = jax.ShapeDtypeStruct(
            (plan.accum_steps, plan.microbatch, fe.n_extra_tokens, fe.feature_dim),
            jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, fspec),
        )
    return params, opt_state, batch


def cache_logical_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Logical axes for decode caches: full-attention caches shard the sequence
    slots over 'model' (flash-decoding by the SPMD partitioner, DESIGN §2);
    ring-buffered SWA caches are small and shard kv heads when divisible."""
    axes: Dict[str, Any] = {"pos": ()}
    seq_axis = "cache_seq" if cfg.sliding_window is None else None
    kinds = cfg.layer_kinds
    if any(k in ("attn", "moe") for k in kinds) or cfg.shared_attn_every:
        axes["row_start"] = ("batch",)
    if any(k in ("attn", "moe") for k in kinds):
        axes["attn"] = {
            "k": ("stack", "batch", seq_axis, "kv_heads", None),
            "v": ("stack", "batch", seq_axis, "kv_heads", None),
            "slot_pos": (None,),
        }
    if any(k == "ssm" for k in kinds):
        axes["ssm"] = {
            "state": ("stack", "batch", "heads", None, None),
            "conv": ("stack", "batch", None, "mlp"),
        }
    if cfg.shared_attn_every:
        axes["shared_attn"] = {
            "k": ("stack", "batch", seq_axis, "kv_heads", None),
            "v": ("stack", "batch", seq_axis, "kv_heads", None),
        }
    return axes


def abstract_decode_inputs(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: MeshRules, tp: int
):
    shardings = param_shardings(cfg, mesh, rules, tp)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_params(cfg, tp),
        shardings,
    )
    caches_concrete = jax.eval_shape(
        lambda: make_cache(cfg, shape.global_batch, shape.seq_len, tp)
    )
    cax = cache_logical_axes(cfg)
    caches = map_with_logical(
        caches_concrete,
        cax,
        lambda a, lg: jax.ShapeDtypeStruct(
            a.shape, a.dtype,
            sharding=NamedSharding(mesh, shape_aware_spec(a.shape, lg, mesh, rules)),
        ),
    )
    tspec = shape_aware_spec((shape.global_batch, 1), ("batch", None), mesh, rules)
    token = jax.ShapeDtypeStruct(
        (shape.global_batch, 1), jnp.int32, sharding=NamedSharding(mesh, tspec)
    )
    return params, caches, token


def abstract_prefill_inputs(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: MeshRules, tp: int
):
    shardings = param_shardings(cfg, mesh, rules, tp)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_params(cfg, tp),
        shardings,
    )
    tspec = shape_aware_spec(
        (shape.global_batch, shape.seq_len), ("batch", None), mesh, rules
    )
    tokens = jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32,
        sharding=NamedSharding(mesh, tspec),
    )
    extra = None
    if cfg.frontend is not None:
        fe = cfg.frontend
        espec = shape_aware_spec(
            (shape.global_batch, fe.n_extra_tokens, fe.feature_dim),
            ("batch", None, None), mesh, rules,
        )
        extra = jax.ShapeDtypeStruct(
            (shape.global_batch, fe.n_extra_tokens, fe.feature_dim),
            jnp.dtype(cfg.dtype), sharding=NamedSharding(mesh, espec),
        )
    return params, tokens, extra
