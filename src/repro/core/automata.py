"""Explicit finite automata of the parser (paper Sect. 2.3.4 and 3.1).

These are the paper-faithful machine constructions used by the reference (CPU)
parsers, the Tab. 5 validation benchmarks and the tests:

* ``ParserNFA``    — states = segments; arcs labeled by the char class read by the
                     *source* segment's end-letter.
* ``ParserDFA``    — classic powerset determinization from the initial-segment set
                     (Fig. 11).  *Not minimized* — minimization would merge states and
                     destroy the segment-set ↔ SLPF-column correspondence (Sect. 3.1).
* ``MultiEntryDFA``— powerset from *every singleton* segment (Fig. 12): one entry
                     state per segment, merged on equal segment sets (Gill's ME-DFA).

All are built over the char-class alphabet (App. A) so wildcards / sets stay compact.
The reverse machines are obtained from the reversed NFA (Eq. 5: transposed matrices,
I and F switched).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Tuple

from .segments import SegmentTable


@dataclass
class ParserNFA:
    table: SegmentTable
    n_states: int
    n_classes: int                      # real classes (incl. DEAD), no PAD here
    initial: FrozenSet[int]
    final: FrozenSet[int]
    # delta[state] = {class: (targets...)}
    delta: List[Dict[int, Tuple[int, ...]]]

    def step(self, states: FrozenSet[int], cls: int) -> FrozenSet[int]:
        out: set[int] = set()
        for s in states:
            out.update(self.delta[s].get(cls, ()))
        return frozenset(out)

    def run(self, classes) -> FrozenSet[int]:
        cur = self.initial
        for c in classes:
            cur = self.step(cur, int(c))
        return cur

    def accepts(self, classes) -> bool:
        return bool(self.run(classes) & self.final)

    def reverse(self) -> "ParserNFA":
        rdelta: List[Dict[int, List[int]]] = [dict() for _ in range(self.n_states)]
        for src, by_cls in enumerate(self.delta):
            for cls, targets in by_cls.items():
                for t in targets:
                    rdelta[t].setdefault(cls, []).append(src)
        return ParserNFA(
            table=self.table,
            n_states=self.n_states,
            n_classes=self.n_classes,
            initial=self.final,
            final=self.initial,
            delta=[{c: tuple(sorted(v)) for c, v in d.items()} for d in rdelta],
        )


def build_nfa(table: SegmentTable) -> ParserNFA:
    n = table.n
    delta: List[Dict[int, Tuple[int, ...]]] = []
    for src in range(n):
        d: Dict[int, Tuple[int, ...]] = {}
        succs = table.folseg[src]
        if succs:
            for cls in table.seg_classes[src]:
                d[cls] = succs
        delta.append(d)
    return ParserNFA(
        table=table,
        n_states=n,
        n_classes=table.numbered.n_classes,
        initial=frozenset(i for i in range(n) if table.initial[i]),
        final=frozenset(i for i in range(n) if table.final[i]),
        delta=delta,
    )


@dataclass
class DFA:
    """A deterministic automaton over segment sets (used for both DFA and ME-DFA)."""

    states: List[FrozenSet[int]]                  # state id → segment set
    index: Dict[FrozenSet[int], int]
    initial: List[int]                            # entry state ids (1 for DFA, ℓ for ME-DFA)
    final: List[bool]
    delta: List[Dict[int, int]]                   # state id → {class: state id}

    @property
    def n_states(self) -> int:
        return len(self.states)

    def step(self, state: int, cls: int) -> int | None:
        return self.delta[state].get(cls)

    def run(self, state: int, classes) -> int | None:
        for c in classes:
            state = self.delta[state].get(int(c))
            if state is None:  # dead
                return None
        return state


def _powerset(nfa: ParserNFA, seeds: List[FrozenSet[int]]) -> DFA:
    states: List[FrozenSet[int]] = []
    index: Dict[FrozenSet[int], int] = {}
    delta: List[Dict[int, int]] = []

    def intern(s: FrozenSet[int]) -> int:
        if s not in index:
            index[s] = len(states)
            states.append(s)
            delta.append({})
        return index[s]

    initial = [intern(s) for s in seeds]
    work = list(dict.fromkeys(initial))
    seen = set(work)
    while work:
        sid = work.pop()
        sset = states[sid]
        by_cls: Dict[int, set] = {}
        for q in sset:
            for cls, targets in nfa.delta[q].items():
                by_cls.setdefault(cls, set()).update(targets)
        for cls, targets in by_cls.items():
            tid = intern(frozenset(targets))
            delta[sid][cls] = tid
            if tid not in seen:
                seen.add(tid)
                work.append(tid)
    final = [bool(s & nfa.final) for s in states]
    return DFA(states=states, index=index, initial=initial, final=final, delta=delta)


def build_dfa(nfa: ParserNFA) -> DFA:
    """Classic powerset DFA from the initial-segment set (Fig. 11)."""
    return _powerset(nfa, [nfa.initial])


def build_medfa(nfa: ParserNFA) -> DFA:
    """Multi-entry DFA: one entry per segment singleton (Fig. 12).

    ``initial[j]`` is the entry state for segment ``j``; distinct DFA states reached
    from different entries are merged when they carry the same segment set.
    """
    seeds = [frozenset({j}) for j in range(nfa.n_states)]
    return _powerset(nfa, seeds)
