"""Paper-faithful parallel parser (Sect. 3.2, Tab. 6, Ex. 6) — the reference oracle.

This module reproduces the published algorithm *exactly* as specified, phase by
phase, over explicit ME-DFA / DFA look-up tables:

  split  — text → c chunks (equal length; the last may be shorter, per Sect. 3.2
           we also support the paper's simplifying equal-length assumption);
  reach  — Eq. (6): per chunk, per ME-DFA entry (one per segment), run the
           ME-DFA to the chunk end → edge-segment sets R[i][j];
  join   — Eq. (7): J_0 = I;  J_i = ∪_{q_j ∈ J_{i-1}} R[i][j];
  build  — Eq. (8): per chunk, DFA run from J_{i-1} emitting every column B;
  merge  — Eq. (9): M = B ∩ B̂ per position;
  compose— C_0 = J_0 ∩ Ĵ_1, then concatenate the M columns.

The backward phases use the reverse ME-DFA / DFA built from the reversed NFA
(Eq. 5).  A fused ``builder&merger`` (Fig. 14) variant is provided too: one pass
forward storing M, one backward pass with a TMP column ANDing in place.

Everything is pure Python over frozensets/numpy — slow, obviously correct, used
as the oracle for the JAX engine and the Pallas kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .automata import DFA, ParserNFA, build_dfa, build_medfa, build_nfa
from .matrices import ParserMatrices, build_matrices
from .segments import SegmentTable, compute_segments
from .slpf import SLPF


@dataclass
class ParallelArtifacts:
    """All machines the parallel parser needs, generated once per RE (Sect. 4.1)."""

    table: SegmentTable
    matrices: ParserMatrices
    nfa: ParserNFA
    dfa: DFA
    medfa: DFA
    rnfa: ParserNFA
    rdfa: DFA
    rmedfa: DFA

    @classmethod
    def generate(cls, pattern_or_table, *, inf_limit: int = 2) -> "ParallelArtifacts":
        if isinstance(pattern_or_table, SegmentTable):
            table = pattern_or_table
        else:
            table = compute_segments(pattern_or_table, inf_limit=inf_limit)
        nfa = build_nfa(table)
        rnfa = nfa.reverse()
        return cls(
            table=table,
            matrices=build_matrices(table),
            nfa=nfa,
            dfa=build_dfa(nfa),
            medfa=build_medfa(nfa),
            rnfa=rnfa,
            rdfa=build_dfa(rnfa),
            rmedfa=build_medfa(rnfa),
        )


def split_chunks(classes: np.ndarray, c: int) -> List[np.ndarray]:
    """Split phase: ``c`` chunks, sizes as equal as possible (within ±1)."""
    n = len(classes)
    c = max(1, min(c, n)) if n else 1
    bounds = [round(i * n / c) for i in range(c + 1)]
    return [classes[bounds[i]: bounds[i + 1]] for i in range(c)]


def _medfa_state_of(medfa: DFA, j: int) -> int:
    """Entry state of the ME-DFA for segment j (singleton {j})."""
    return medfa.initial[j]


def reach_phase(medfa: DFA, chunk: Sequence[int], ell: int) -> List[frozenset]:
    """Eq. (6) for one chunk: R[j] = δ*_ME-DFA({j}, chunk) for every segment j."""
    out: List[frozenset] = []
    for j in range(ell):
        state: Optional[int] = _medfa_state_of(medfa, j)
        for ch in chunk:
            state = medfa.step(state, int(ch))
            if state is None:
                break
        out.append(medfa.states[state] if state is not None else frozenset())
    return out


def join_phase(R: List[List[frozenset]], start: frozenset) -> List[frozenset]:
    """Eq. (7): J_0 = start; J_i = ∪_{j ∈ J_{i-1}} R_i[j].  Returns J_0..J_c."""
    J = [frozenset(start)]
    for Ri in R:
        s: set = set()
        for j in J[-1]:
            s |= Ri[j]
        J.append(frozenset(s))
    return J


def _dfa_state_for(dfa: DFA, segset: frozenset, nfa: ParserNFA) -> Optional[int]:
    """The DFA state whose segment set equals ``segset``.

    By construction (Sect. 3.2, join discussion) every join column *is* a DFA
    state; sets never seen during powerset (e.g. ∅ on invalid texts) intern here.
    """
    if segset in dfa.index:
        return dfa.index[segset]
    if not segset:
        return None
    # Intern on demand: extend the DFA lazily (equivalent to powerset from this set).
    dfa.index[segset] = len(dfa.states)
    dfa.states.append(segset)
    dfa.delta.append({})
    dfa.final.append(bool(segset & nfa.final))
    return dfa.index[segset]


def _dfa_step_lazy(dfa: DFA, nfa: ParserNFA, sid: Optional[int], cls: int) -> Optional[int]:
    if sid is None:
        return None
    nxt = dfa.delta[sid].get(cls)
    if nxt is not None:
        return nxt
    targets: set = set()
    for q in dfa.states[sid]:
        targets.update(nfa.delta[q].get(cls, ()))
    if not targets:
        return None
    tid = _dfa_state_for(dfa, frozenset(targets), nfa)
    dfa.delta[sid][cls] = tid
    return tid


def build_phase(
    dfa: DFA, nfa: ParserNFA, entry: frozenset, chunk: Sequence[int], ell: int
) -> np.ndarray:
    """Eq. (8) for one chunk: DFA columns B[t] (t = 1..k) from entry set."""
    k = len(chunk)
    B = np.zeros((k, ell), dtype=bool)
    sid = _dfa_state_for(dfa, entry, nfa)
    for t, ch in enumerate(chunk):
        sid = _dfa_step_lazy(dfa, nfa, sid, int(ch))
        if sid is None:
            break  # remaining columns stay empty
        for q in dfa.states[sid]:
            B[t, q] = True
    return B


def parse_parallel_reference(
    art: ParallelArtifacts, text, c: int = 4, *, fused: bool = False
) -> SLPF:
    """The complete parallel algorithm (Fig. 13) with c chunks."""
    m = art.matrices
    classes = (
        m.classes_of_text(text) if isinstance(text, (bytes, str))
        else np.asarray(text, dtype=np.int32)
    )
    ell = art.table.n
    n = len(classes)
    if n == 0:
        col = (m.I & m.F)[None, :]
        return SLPF(table=art.table, columns=col, classes=classes)

    chunks = split_chunks(classes, c)
    c = len(chunks)

    # ---- reach (FW and BW; Eq. 6) -------------------------------------------
    R = [reach_phase(art.medfa, ch, ell) for ch in chunks]
    Rb = [reach_phase(art.rmedfa, ch[::-1], ell) for ch in chunks]

    # ---- join (FW and BW; Eq. 7) --------------------------------------------
    I_set = frozenset(np.flatnonzero(m.I).tolist())
    F_set = frozenset(np.flatnonzero(m.F).tolist())
    J = join_phase(R, I_set)                      # J[0..c]
    Jb_rev = join_phase(Rb[::-1], F_set)          # Ĵ[c+1], Ĵ[c], .., Ĵ[1]
    Jb = Jb_rev[::-1]                             # Ĵ[i] at index i-1 → reindex below
    # Jb list: index i (0..c) holds Ĵ_{i+1}; Ĵ_{c+1} = F_set at index c.

    if fused:
        M = _fused_build_merge(art, chunks, J, Jb, ell)
    else:
        # ---- build (FW and BW; Eq. 8) ---------------------------------------
        # 0-based chunk i ↔ paper chunk i+1: FW entry J_i = J[i]; BW entry
        # Ĵ_{(i+1)+1} = Ĵ_{i+2} = Jb[i+1]  (Jb[m] holds Ĵ_{m+1}).
        B = [build_phase(art.dfa, art.nfa, J[i], chunks[i], ell) for i in range(c)]
        Bb = [
            build_phase(art.rdfa, art.rnfa, Jb[i + 1], chunks[i][::-1], ell)[::-1]
            for i in range(c)
        ]
        # Bb[i][t] (0-based t) = paper B̂_{i+1,t}; the chunk-end backward column
        # is the entry itself: B̂_{i+1,k} = Ĵ_{i+2} = Jb[i+1].
        M = []
        for i in range(c):
            k = len(chunks[i])
            Mi = np.zeros((k, ell), dtype=bool)
            for t in range(k):
                fwd = B[i][t]
                if t == k - 1:
                    bwd = np.zeros(ell, dtype=bool)
                    for q in Jb[i + 1]:
                        bwd[q] = True
                else:
                    bwd = Bb[i][t + 1]
                Mi[t] = fwd & bwd
            M.append(Mi)

    # ---- compose (C_0 = J_0 ∩ Ĵ_1, then M columns) --------------------------
    C = np.zeros((n + 1, ell), dtype=bool)
    J0 = np.zeros(ell, dtype=bool)
    for q in J[0]:
        J0[q] = True
    Jb1 = np.zeros(ell, dtype=bool)
    for q in (Jb[0] if c >= 1 else F_set):
        Jb1[q] = True
    C[0] = J0 & Jb1
    r = 1
    for Mi in M:
        C[r : r + len(Mi)] = Mi
        r += len(Mi)
    return SLPF(table=art.table, columns=C, classes=classes)


def _fused_build_merge(art, chunks, J, Jb, ell) -> List[np.ndarray]:
    """Fig. 14: fused FW build + BW build&merge with a single M array per chunk."""
    M = []
    for i, chunk in enumerate(chunks):
        k = len(chunk)
        Mi = np.zeros((k, ell), dtype=bool)
        sid = _dfa_state_for(art.dfa, J[i], art.nfa)
        for t, ch in enumerate(chunk):
            sid = _dfa_step_lazy(art.dfa, art.nfa, sid, int(ch))
            if sid is None:
                break
            for q in art.dfa.states[sid]:
                Mi[t, q] = True
        # Backward: TMP = Ĵ_{i+2} (paper Ĵ_{i+1} for its 1-based chunk);
        # M[k] &= TMP; then walk down ANDing.
        tmp = np.zeros(ell, dtype=bool)
        for q in Jb[i + 1]:
            tmp[q] = True
        Mi[k - 1] &= tmp
        rsid = _dfa_state_for(art.rdfa, Jb[i + 1], art.rnfa)
        for t in range(k - 2, -1, -1):
            rsid = _dfa_step_lazy(art.rdfa, art.rnfa, rsid, int(chunk[t + 1]))
            if rsid is None:
                Mi[: t + 1] = False
                break
            tmp[:] = False
            for q in art.rdfa.states[rsid]:
                tmp[q] = True
            Mi[t] &= tmp
        M.append(Mi)
    return M


def recognize_parallel(art: ParallelArtifacts, text, c: int = 4) -> bool:
    """Mere parallel recognizer (Sect. 4.2): FW reach + join only."""
    m = art.matrices
    classes = (
        m.classes_of_text(text) if isinstance(text, (bytes, str))
        else np.asarray(text, dtype=np.int32)
    )
    if len(classes) == 0:
        return bool((m.I & m.F).any())
    chunks = split_chunks(classes, c)
    R = [reach_phase(art.medfa, ch, art.table.n) for ch in chunks]
    I_set = frozenset(np.flatnonzero(m.I).tolist())
    J = join_phase(R, I_set)
    F_set = frozenset(np.flatnonzero(m.F).tolist())
    return bool(J[-1] & F_set)
