"""Segment computation (paper Sect. 2.3.2–2.3.3, Fig. 5) and FolSeg (Eq. 3).

A *segment* is a maximal substring ``μ a`` of an LST where ``μ`` (the meta-prefix) is
made of numbered parentheses / numbered epsilons and ``a`` (the end-letter) is a
numbered terminal or the end-mark ⊣.

The paper's Fig. 5 algorithm extends meta-prefixes right-to-left from each end-letter.
We enumerate equivalently *left-to-right*: a segment occurrence always starts right
after an end-letter (or at the very start of the LST), so walking the ``Fol`` relation
forward from every anchor (START ∪ terminals) through metasymbols until the next
end-letter enumerates exactly the maximal factors.  Since the LST language is local
(Sect. 2.3.4), every such walk is realizable in some LST, and every segment is found.

Termination: for non-infinitely-ambiguous REs a meta-prefix cannot repeat a numbered
metasymbol (Prop. 2) — we bound each symbol to one occurrence per meta-prefix.  For
infinitely ambiguous REs we follow App. A: symbols may repeat up to ``inf_limit``
times, yielding a finite representative sample of the LSTs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .numbering import END, EPS, NumberedRE, TERM, number_regex


class SegmentExplosion(RuntimeError):
    pass


@dataclass
class SegmentTable:
    numbered: NumberedRE
    segs: List[Tuple[int, ...]]          # segment id → tuple of sids (meta* + end-letter)
    index: Dict[Tuple[int, ...], int]
    initial: np.ndarray                  # (ℓ,) bool — set I
    final: np.ndarray                    # (ℓ,) bool — set F
    folseg: List[Tuple[int, ...]]        # segment id → follower segment ids (Eq. 3)
    end_letter: List[int]                # segment id → sid of its end-letter
    seg_classes: List[Tuple[int, ...]]   # segment id → char classes its end-letter reads

    @property
    def n(self) -> int:
        return len(self.segs)

    def display(self, i: int) -> str:
        return "".join(self.numbered.display_sym(s) for s in self.segs[i])

    def all_displays(self) -> List[str]:
        return [self.display(i) for i in range(self.n)]

    def delta(self, seg: int, cls: int) -> Tuple[int, ...]:
        """NFA transition: from ``seg`` reading char-class ``cls`` (Sect. 2.3.4)."""
        if cls in self.seg_classes[seg]:
            return self.folseg[seg]
        return ()


def compute_segments(
    numbered: NumberedRE | str,
    *,
    inf_limit: int = 2,
    max_segments: int = 200_000,
) -> SegmentTable:
    if isinstance(numbered, str):
        numbered = number_regex(numbered)
    syms = numbered.symbols
    follow = numbered.follow
    end_sid = numbered.end_sid

    limit = inf_limit if numbered.infinitely_ambiguous else 1

    is_end_letter = [s.kind in (TERM, END) for s in syms]

    segs: Dict[Tuple[int, ...], int] = {}
    seg_list: List[Tuple[int, ...]] = []
    initial_flags: List[bool] = []

    def add(seg: Tuple[int, ...], is_initial: bool) -> None:
        if seg in segs:
            if is_initial:
                initial_flags[segs[seg]] = True
            return
        if len(seg_list) >= max_segments:
            raise SegmentExplosion(
                f"more than {max_segments} segments; RE too ambiguous for this limit"
            )
        segs[seg] = len(seg_list)
        seg_list.append(seg)
        initial_flags.append(is_initial)

    # Walk forward through metasymbols from every anchor successor.
    def walk(start_sym: int, is_initial: bool) -> None:
        # iterative DFS over (path, counts)
        stack: List[Tuple[Tuple[int, ...], Dict[int, int]]] = [((start_sym,), {start_sym: 1})]
        while stack:
            path, counts = stack.pop()
            last = path[-1]
            if is_end_letter[last]:
                add(path, is_initial)
                continue
            for nxt in follow.get(last, ()):  # extend through the metasymbol
                c = counts.get(nxt, 0)
                if c >= limit:
                    continue
                nc = dict(counts)
                nc[nxt] = c + 1
                stack.append((path + (nxt,), nc))

    for s in sorted(numbered.first):
        walk(s, True)
    for sym in syms:
        if sym.kind == TERM:
            for s in sorted(follow.get(sym.sid, ())):
                walk(s, False)

    n = len(seg_list)
    end_letter = [seg[-1] for seg in seg_list]
    final = np.array([el == end_sid for el in end_letter], dtype=bool)
    initial = np.array(initial_flags, dtype=bool)

    # FolSeg (Eq. 3): σ follows ρ iff first-symbol(σ) ∈ Fol(end-letter(ρ)).
    by_first: Dict[int, List[int]] = {}
    for i, seg in enumerate(seg_list):
        by_first.setdefault(seg[0], []).append(i)
    folseg: List[Tuple[int, ...]] = []
    for i in range(n):
        succs: List[int] = []
        for s in follow.get(end_letter[i], ()):
            succs.extend(by_first.get(s, ()))
        folseg.append(tuple(sorted(set(succs))))

    seg_classes = [
        numbered.term_classes.get(end_letter[i], ()) if end_letter[i] != end_sid else ()
        for i in range(n)
    ]

    return SegmentTable(
        numbered=numbered,
        segs=seg_list,
        index=segs,
        initial=initial,
        final=final,
        folseg=folseg,
        end_letter=end_letter,
        seg_classes=seg_classes,
    )
