"""Multi-tenant fleet engine: one device program serves many automata.

Everything below ``FleetEngine`` batches over *text*: chunks within a text
(the paper's decomposition), batch slots across texts (``parse_batch``).
Production RE traffic is thousands of *distinct patterns* — and nothing in
reach/compose/join/build&merge depends on *which* automaton's tables are
bound: every phase body takes (N, I, F) as operands (``core/backend.py``'s
contract), so the tenant axis vmaps exactly like the batch-slot axis.

Three pieces make that serve-able:

  automaton bucketing   ``pad_matrices_bundle`` (core/matrices.py) pads each
                        tenant's tables to a shared pow2 bucket shape —
                        ℓp to the next power of two (floor: the backend's
                        ``min_lane_pad``) and the class axis likewise, with
                        PAD relocated to the bucket's uniform last index.
                        Tenants bucket by (backend variant, class bucket,
                        ℓp bucket); padding is semantics-free (unreachable
                        states, identity classes), so each tenant's SLPF is
                        bit-identical to its solo ``Parser``'s.

  tenant-batched phases ``_BucketRunner`` stacks member tables on a leading
                        tenant axis and jits ONE program per bucket:
                        ``backend.lift_batch(backend.batch_core(core))`` —
                        the same two seams the mesh route uses — running
                        (tenant, batch-slot, chunk) in a single dispatch.
                        Compiled-program count scales with #buckets × the
                        pow2 (T, B, c, k) shape set, NOT with #tenants.
                        Sparse buckets bind the backend at the member-max
                        feasible width (``SparseBackend.bind_shape``): a
                        width ≥ any member's own bound stays exact, so a
                        dense-fallback tenant can share a bucket with a
                        reduced one.

  table compile cache   building an automaton (segment table → matrices →
                        padded bundle) is the per-tenant compile cost; the
                        process-wide ``_TABLE_CACHE`` memoizes it keyed on
                        (normalized regex, backend variant, ℓp bucket) —
                        ``normalize_regex`` is the parsed AST's canonical
                        form, so syntactic variants of one pattern share an
                        entry.  ``table_cache_hits_total`` /
                        ``table_cache_misses_total`` make the cache
                        observable per fleet.

``repro.ParserFleet`` (repro/api.py) is the supported facade over this
engine; ``serve/parse_service.py``'s ``FleetParseService`` adds the
weighted-fair queue.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import ObsHandle
from .backend import ParserBackend, SparseBackend, get_backend
from .engine import make_parse_core
from .matrices import (
    ParserMatrices,
    build_matrices,
    feasible_width_bound,
    pad_matrices_bundle,
    unpack_bits,
)
from .slpf import SLPF


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


# ---------------------------------------------------------------- tenant spec


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Core-level description of one fleet tenant (the jax-free subset of
    ``repro.ParserConfig`` the engine needs; the facade converts)."""

    regex: str
    backend: str = "jnp"
    kernel: bool = False
    feasible_depth: int = 1
    n_chunks: int = 8
    min_chunk_len: int = 8
    weight: float = 1.0
    max_pending: Optional[int] = None

    def backend_key(self) -> str:
        """Bucket-key component: backends with different static behavior
        (kernel toggle, feasible depth) must not share a compiled program."""
        key = self.backend
        if self.kernel:
            key += "+kernel"
        if self.backend == "sparse" and self.feasible_depth != 1:
            key += f"+d{self.feasible_depth}"
        return key

    def make_backend(self) -> ParserBackend:
        if self.backend == "sparse":
            return SparseBackend(kernel=self.kernel, depth=self.feasible_depth)
        if self.backend == "packed" and self.kernel:
            from .backend import PackedBackend

            return PackedBackend(kernel=True)
        return get_backend(self.backend)


# ----------------------------------------------------------- compile cache


def normalize_regex(pattern: str) -> str:
    """Canonical structural form of a pattern — the cache-key normalizer.

    Parses to the AST and renders its (deterministic, frozen-dataclass)
    repr, so syntactic variants that parse identically — whitespace-free
    reformattings, redundant alternation nesting the parser flattens —
    share one cache entry, while semantically distinct patterns (including
    explicit groups, which own paren numbers) never collide.
    """
    from .regex import parse_regex

    return repr(parse_regex(pattern))


@dataclasses.dataclass
class CompiledTenantTables:
    """One automaton compiled + padded to its fleet bucket shape (host side)."""

    matrices: ParserMatrices
    N: np.ndarray            # (Ab, Lb, Lb) f32 — PAD = index Ab-1 = identity
    I: np.ndarray            # (Lb,) f32
    F: np.ndarray            # (Lb,) f32
    ell: int                 # true segment count
    ell_pad: int             # Lb: pow2 ℓp bucket
    n_classes: int           # Ab: pow2 class bucket (incl. PAD)
    pad_class: int           # Ab - 1
    width_bound: int         # depth-1 feasible width (sparse bucket input)


def _compile_tables(matrices: ParserMatrices, min_lane_pad: int) -> CompiledTenantTables:
    ell = matrices.n_segments
    lb = _next_pow2(max(min_lane_pad, ell))
    ab = _next_pow2(matrices.N.shape[0])
    N, I, F = pad_matrices_bundle(matrices, ell_pad=lb, n_classes=ab)
    return CompiledTenantTables(
        matrices=matrices,
        N=N,
        I=I,
        F=F,
        ell=ell,
        ell_pad=lb,
        n_classes=ab,
        pad_class=ab - 1,
        width_bound=feasible_width_bound(matrices),
    )


# (normalized regex, backend variant, ℓp bucket) → CompiledTenantTables.
# Process-wide: every fleet in the process shares it, so two fleets serving
# the same pattern set compile its tables once.
_TABLE_CACHE: Dict[Tuple[str, str, int], CompiledTenantTables] = {}
# (normalized regex, backend variant) → ℓp bucket: the bucket is a function
# of the pattern + backend (derived while building), so lookups that have
# not built yet resolve their full key through this index.
_TABLE_CACHE_LP: Dict[Tuple[str, str], int] = {}
_TABLE_CACHE_LOCK = threading.Lock()


def compiled_tenant_tables(
    regex: str,
    backend_key: str,
    min_lane_pad: int,
    metrics=None,
) -> CompiledTenantTables:
    """Cache front: padded tenant tables, built at most once per key.

    Hit/miss counters land on the calling fleet's registry (the cache is
    process-wide; attribution is per fleet).
    """
    norm = normalize_regex(regex)
    with _TABLE_CACHE_LOCK:
        lp = _TABLE_CACHE_LP.get((norm, backend_key))
        entry = _TABLE_CACHE.get((norm, backend_key, lp)) if lp is not None else None
    if entry is not None:
        if metrics is not None:
            metrics.counter("table_cache_hits_total").inc()
        return entry
    if metrics is not None:
        metrics.counter("table_cache_misses_total").inc()
    from .segments import compute_segments

    ct = _compile_tables(build_matrices(compute_segments(regex)), min_lane_pad)
    with _TABLE_CACHE_LOCK:
        _TABLE_CACHE_LP[(norm, backend_key)] = ct.ell_pad
        _TABLE_CACHE[(norm, backend_key, ct.ell_pad)] = ct
    return ct


def table_cache_stats() -> Dict[str, Any]:
    with _TABLE_CACHE_LOCK:
        return {
            "entries": len(_TABLE_CACHE),
            "keys": sorted((k[1], k[2]) for k in _TABLE_CACHE),
        }


def clear_table_cache() -> None:
    """Test hook: forget every compiled table (counters are per-registry)."""
    with _TABLE_CACHE_LOCK:
        _TABLE_CACHE.clear()
        _TABLE_CACHE_LP.clear()


# ---------------------------------------------------------------- tenants


@dataclasses.dataclass
class TenantState:
    tid: str
    spec: TenantSpec
    tables: CompiledTenantTables
    bucket_key: Tuple[str, int, int]   # (backend variant, Ab, Lb)
    row: int                           # row in the bucket's table stack

    def classes_of_text(self, text) -> np.ndarray:
        if isinstance(text, (bytes, str)):
            return self.tables.matrices.classes_of_text(text)
        return np.asarray(text, dtype=np.int32)

    def text_bucket(self, n: int) -> Tuple[int, int]:
        c = max(1, self.spec.n_chunks)
        k = _next_pow2(max(self.spec.min_chunk_len, -(-n // c)))
        return c, k


class _BucketRunner:
    """One automaton bucket: stacked member tables + ONE jitted program.

    The program is ``jit(lift_batch(batch_core(core)))`` — the fused
    three-phase core lifted over batch slots, then over the tenant axis with
    tables mapped as per-row operands.  Each distinct pow2 (T, B, c, k)
    shape traces once; ``jnp.take`` gathers the active tenants' rows from
    the resident device stack per call, so adding a tenant never retraces
    (the stack pads to pow2 rows) except when a sparse bucket's shared
    width S grows.
    """

    def __init__(self, key: Tuple[str, int, int], backend: ParserBackend, obs, on_trace):
        self.key = key
        self.backend = backend
        self.obs = obs
        self._on_trace = on_trace
        _, self.n_classes, self.ell_pad = key
        self.pad_class = self.n_classes - 1
        self.tenant_rows: Dict[str, int] = {}
        self._host: List[CompiledTenantTables] = []
        self._stack: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None
        self._jit = None
        self._seen_shapes: set = set()
        # steady-state serving re-gathers the same tenant rows every call;
        # keyed on the row tuple, the gathered device operands are reused
        # so a warm dispatch is ONE program launch (reset with the stack)
        self._gather_cache: Dict[Tuple[int, ...], Tuple] = {}

    # --------------------------------------------------------- membership

    def add(self, tid: str, ct: CompiledTenantTables) -> int:
        row = len(self._host)
        self.tenant_rows[tid] = row
        self._host.append(ct)
        self._stack = None                       # restack lazily (pow2 rows)
        self._gather_cache.clear()
        if isinstance(self.backend, SparseBackend):
            # the bucket runs every member at the shared width S = pow2 of
            # the member maximum (dense fallback S = Lb when it reaches Lb);
            # a width ≥ a member's own bound keeps its gathers exact.  A
            # grown S changes product shapes → drop the compiled set.
            old = self.backend._width
            raw = max(t.width_bound for t in self._host)
            self.backend.bind_shape(self.ell_pad, raw)
            if self.backend._width != old:
                self._jit = None
                self._seen_shapes.clear()
        return row

    @property
    def n_tenants(self) -> int:
        return len(self._host)

    # ------------------------------------------------------------ program

    def _ensure_program(self):
        if self._jit is None:
            core = make_parse_core(self.backend)

            def counted(N, I, F, chunks):
                self._on_trace()                 # trace-time compile counter
                return core(N, I, F, chunks)

            self._jit = jax.jit(
                self.backend.lift_batch(self.backend.batch_core(counted))
            )
        if self._stack is None:
            T = len(self._host)
            Tp = _next_pow2(T)
            ab, lb = self.n_classes, self.ell_pad
            N = np.empty((Tp, ab, lb, lb), dtype=np.float32)
            I = np.empty((Tp, lb), dtype=np.float32)
            F = np.empty((Tp, lb), dtype=np.float32)
            for r, ct in enumerate(self._host):
                N[r], I[r], F[r] = ct.N, ct.I, ct.F
            # pad rows replicate row 0: always a valid automaton for every
            # backend (their chunks are all-PAD and their outputs discarded)
            N[T:], I[T:], F[T:] = N[0], I[0], F[0]
            self._stack = (jnp.asarray(N), jnp.asarray(I), jnp.asarray(F))

    def run(
        self,
        c: int,
        k: int,
        per_tenant: Dict[str, List[np.ndarray]],
    ) -> Dict[str, List[Tuple[np.ndarray, np.ndarray]]]:
        """One device dispatch for every (tenant, text) of one (c, k) grid.

        ``per_tenant`` maps tid → class arrays; returns tid → [(col0, cols)]
        aligned with the input lists (packed uint32, bucket-width words).
        """
        self._ensure_program()
        tids = list(per_tenant)
        Ta = len(tids)
        Tp = _next_pow2(Ta)
        B = _next_pow2(max(len(v) for v in per_tenant.values()))
        m = self.obs.metrics
        shape = (Tp, B, c, k)
        if shape in self._seen_shapes:
            m.counter("bucket_cache_hits_total").inc()
        else:
            self._seen_shapes.add(shape)
            m.counter("bucket_cache_misses_total").inc()
        rows = np.zeros(Tp, dtype=np.int32)      # pad rows gather row 0
        chunks = np.full((Tp, B, c, k), self.pad_class, dtype=np.int32)
        flat = chunks.reshape(Tp, B, c * k)      # fill texts in place
        for t, tid in enumerate(tids):
            rows[t] = self.tenant_rows[tid]
            for b, classes in enumerate(per_tenant[tid]):
                flat[t, b, : len(classes)] = classes
        row_key = tuple(rows.tolist())
        operands = self._gather_cache.get(row_key)
        if operands is None:
            Ns, Is, Fs = self._stack
            idx = jnp.asarray(rows)
            operands = (
                jnp.take(Ns, idx, axis=0),
                jnp.take(Is, idx, axis=0),
                jnp.take(Fs, idx, axis=0),
            )
            self._gather_cache[row_key] = operands
        col0s, colss = self._jit(*operands, jnp.asarray(chunks))
        col0s = np.asarray(col0s)
        colss = np.asarray(colss)
        return {
            tid: [
                (col0s[t, b], colss[t, b])
                for b in range(len(per_tenant[tid]))
            ]
            for t, tid in enumerate(tids)
        }


# ------------------------------------------------------------------ engine


class _FleetBackendInfo:
    """Engine-duck-typing shim: services report ``engine.backend.name``."""

    name = "fleet"


class FleetEngine:
    """Many automata, one engine pool: per-bucket tenant-batched programs.

    Quacks like ``ParserEngine`` where the service layer needs it
    (``obs``, ``compile_count``, ``backend.name``); parsing goes through
    ``parse_batch([(tenant_id, text), ...])`` or the per-bucket
    ``run_bucket`` the fleet service drives.
    """

    def __init__(self, obs: Optional[ObsHandle] = None):
        self.obs = obs if obs is not None else ObsHandle()
        self.backend = _FleetBackendInfo()
        self._tenants: Dict[str, TenantState] = {}
        self._buckets: Dict[Tuple[str, int, int], _BucketRunner] = {}
        self._compile_count = 0

    def _bump_compiles(self) -> None:
        self._compile_count += 1
        self.obs.metrics.counter("compiled_programs_total").inc()

    @property
    def compile_count(self) -> int:
        """Device programs traced across every bucket — grows with the
        number of (backend, ℓp-bucket) pairs × pow2 shapes, not tenants."""
        return self._compile_count

    @property
    def tenants(self) -> Dict[str, TenantState]:
        return dict(self._tenants)

    @property
    def n_buckets(self) -> int:
        return len(self._buckets)

    def bucket_sizes(self) -> Dict[Tuple[str, int, int], int]:
        return {k: r.n_tenants for k, r in self._buckets.items()}

    # ---------------------------------------------------------- membership

    def add_tenant(
        self,
        tid: str,
        spec: TenantSpec,
        matrices: Optional[ParserMatrices] = None,
    ) -> TenantState:
        """Register one tenant: compile-or-cache its tables, place it in its
        automaton bucket (creating the bucket's backend + program slot on
        first membership)."""
        if tid in self._tenants:
            raise ValueError(f"fleet tenant {tid!r} already registered")
        if spec.backend == "auto":
            # static backend selection (repro.analyze): resolve before the
            # bucket key is derived, so auto tenants land in the bucket of
            # the backend they actually run on
            from ..analyze.pattern import analyze_matrices, resolve_auto_backend

            if matrices is not None:
                chosen = analyze_matrices(matrices).recommended_backend
            else:
                chosen = resolve_auto_backend(spec.regex, spec.feasible_depth)
            spec = dataclasses.replace(spec, backend=chosen)
            self.obs.metrics.counter(
                "auto_backend_selected_total", backend=chosen
            ).inc()
        backend_key = spec.backend_key()
        min_lane = spec.make_backend().min_lane_pad
        if matrices is not None:
            ct = _compile_tables(matrices, min_lane)   # prebuilt: bypass cache
        else:
            ct = compiled_tenant_tables(
                spec.regex, backend_key, min_lane, metrics=self.obs.metrics
            )
        key = (backend_key, ct.n_classes, ct.ell_pad)
        runner = self._buckets.get(key)
        if runner is None:
            backend = spec.make_backend()
            if isinstance(backend, SparseBackend):
                backend.bind_shape(ct.ell_pad, ct.width_bound)
            runner = _BucketRunner(key, backend, self.obs, self._bump_compiles)
            self._buckets[key] = runner
        row = runner.add(tid, ct)
        ts = TenantState(tid=tid, spec=spec, tables=ct, bucket_key=key, row=row)
        self._tenants[tid] = ts
        m = self.obs.metrics
        m.gauge("fleet_tenants").set(len(self._tenants))
        m.gauge("fleet_buckets").set(len(self._buckets))
        return ts

    def tenant(self, tid: str) -> TenantState:
        ts = self._tenants.get(tid)
        if ts is None:
            raise KeyError(f"unknown fleet tenant {tid!r}")
        return ts

    # ------------------------------------------------------------- parsing

    def request_plan(self, tid: str, text) -> Tuple[np.ndarray, Tuple]:
        """(classes, bucket) of one request — the service's submit-time hook.

        The bucket is (automaton bucket, (c, k) text bucket): requests batch
        together exactly when they share a compiled program's operand shape.
        """
        ts = self.tenant(tid)
        classes = ts.classes_of_text(text)
        return classes, (ts.bucket_key, ts.text_bucket(len(classes)))

    def run_bucket(
        self, bucket: Tuple, items: Sequence[Tuple[str, np.ndarray]]
    ) -> List[SLPF]:
        """Serve one same-bucket group in a single tenant-batched dispatch."""
        bkey, (c, k) = bucket
        runner = self._buckets[bkey]
        per_tenant: Dict[str, List[np.ndarray]] = {}
        slots: List[Tuple[str, int]] = []
        for tid, classes in items:
            lst = per_tenant.setdefault(tid, [])
            slots.append((tid, len(lst)))
            lst.append(classes)
        out = runner.run(c, k, per_tenant)
        results = []
        for (tid, b), (_, classes) in zip(slots, items):
            col0, cols = out[tid][b]
            results.append(self._assemble(self.tenant(tid), col0, cols, classes))
        return results

    def parse_batch(self, items: Sequence[Tuple[str, Any]]) -> List[SLPF]:
        """Parse [(tenant_id, text), ...]: group by (automaton bucket,
        (c, k)), one tenant-batched device program per group, results in
        input order — bit-identical per tenant to a serial per-tenant loop."""
        plans = []
        groups: Dict[Tuple, List[int]] = {}
        for i, (tid, text) in enumerate(items):
            classes, bucket = self.request_plan(tid, text)
            plans.append((tid, classes, bucket))
            groups.setdefault(bucket, []).append(i)
        results: List[Optional[SLPF]] = [None] * len(items)
        for bucket, idxs in sorted(groups.items()):
            group_items = [(plans[i][0], plans[i][1]) for i in idxs]
            for i, slpf in zip(idxs, self.run_bucket(bucket, group_items)):
                results[i] = slpf
        return results  # type: ignore[return-value]

    def parse(self, tid: str, text) -> SLPF:
        return self.parse_batch([(tid, text)])[0]

    def _assemble(
        self, ts: TenantState, col0: np.ndarray, cols: np.ndarray, classes
    ) -> SLPF:
        n = len(classes)
        W = cols.shape[-1]
        packed = np.concatenate(
            [np.asarray(col0)[None], np.asarray(cols).reshape(-1, W)[:n]], axis=0
        )
        columns = unpack_bits(packed, ts.tables.ell, axis=-1)
        return SLPF(
            table=ts.tables.matrices.table,
            columns=columns,
            classes=np.asarray(classes, dtype=np.int32),
        )
