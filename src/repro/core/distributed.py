"""Mesh-native distributed parse runtime: batch × chunk sharding on one engine.

``DistributedEngine`` re-expresses the multi-device parser on top of the
engine's phase contract (``ParserBackend`` phase bodies + the shared
``core/scan.py`` join) instead of carrying a separate sharded code path.
Every route below is the SAME three-phase program the single-device engine
runs — only the placement differs — so outputs are bit-identical to
``ParserEngine.parse``/``parse_batch`` (the {0,1} semiring makes every value
exactly 0 or 1; there is no reduction-order slack to hide behind).

The product-stack all-gather contract
-------------------------------------

All cross-device structure flows through ONE array: the stacked chunk
products ``P`` — axis 0 indexes chunks; the per-chunk payload is the
backend's opaque product representation ((ℓp, ℓp) f32 for jnp/pallas,
(ℓp, W = ℓp/32) uint32 words for packed, which cuts the collective's bytes
32×, and (S, 1+W) gathered feasible-start rows for sparse, which further
shrinks it to the automaton's speculation width S ≤ ℓp — the payload
reduction composes with the placement for free because the collective only
ever sees "axis 0 = chunks").  The contract, shared by all three routes and
by the streaming prefix cache:

  1. reach runs shard-local — each device folds only its own chunk rows into
     products (no communication);
  2. the product stack is all-gathered over the chunk mesh axes, in
     ``linear_index`` order, giving every device the full (c, …) stack —
     O(c · product-bytes) of collective traffic, independent of the text
     length;
  3. the join (``core/scan.py`` ``exclusive_entries``, the same scan the
     Mamba-2 SSD state passing uses) runs replicated on the gathered stack,
     yielding forward/backward entries for every chunk plus the packed text-
     start column C₀ (recovered from ``P[0]ᵀ`` — no backward reach pass);
  4. each device slices its own chunks' entries and runs build&merge
     shard-local, emitting packed SLPF columns under the input sharding.

Because step 2's payload is just "the stacked chunk products", anything that
already holds such a stack plugs in directly: ``core/stream.py``'s product
segment tree flattens to exactly this payload — the in-order leaf frontier
of the tree IS the sealed-product stack, before and after any ``edit``
splice (internal nodes are memoized re-associations the collective never
sees) — so sharded streaming, including post-edit queries, is
``join_products`` over a stack sharded on the chunk axes — no streaming-
specific collective code.

Routes
------

  parse          one text; the chunk dim takes EVERY mesh axis the logical
                 'chunk' rule names (``MeshRules``: 'chunk' → ('pod','data'))
                 — maximum chunk parallelism for one long text.
  parse_batch    many texts; the slot/bucket batch dim shards over 'data'
                 (pure DP, no collective) and the chunk dim keeps 'pod' —
                 the composition falls out of ``MeshRules``' duplicate-axis
                 dropping once batch is restricted to 'data'.  The all-gather
                 of step 2 then runs over 'pod' only, per batch shard.
  join_products  the streaming route: a (c, ℓp, ℓp) product stack sharded
                 over the chunk axes → replicated (Jf, Jb, packed C₀).

``ParserEngine(mesh=...)`` builds this layer lazily and routes its
``parse``/``parse_batch`` through it, so ``ParseService``, ``StreamService``
and ``StreamingParser`` become mesh-aware by construction, without their own
distribution code.  Texts keep the engine's shape bucketing; chunk and batch
counts additionally round up to multiples of the mesh axis sizes (identity
PAD rows/chunks are semantics-free, so divisibility padding is free).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..launch.mesh import mesh_axes_size
from ..parallel.sharding import MeshRules, spec_axes
from .engine import _next_pow2, join_with_col0, _resolve_engine
from .scan import linear_index
from .slpf import SLPF


def _shard_map():
    """jax.shard_map across jax versions (legacy: experimental, check_rep)."""
    if hasattr(jax, "shard_map"):  # jax ≥ 0.6
        return functools.partial(jax.shard_map, check_vma=False)
    from jax.experimental.shard_map import shard_map as _esm

    return functools.partial(_esm, check_rep=False)


def _entry(axes: Tuple[str, ...]):
    """PartitionSpec entry for one dim from a flat mesh-axis tuple."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _gather(x: jnp.ndarray, axes: Tuple[str, ...], axis: int) -> jnp.ndarray:
    """all_gather over possibly-several mesh axes, concatenated along ``axis``
    in ``linear_index`` order; identity when ``axes`` is empty."""
    if not axes:
        return x
    return jax.lax.all_gather(x, tuple(axes), axis=axis, tiled=True)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


class DistributedEngine:
    """Mesh-aware front-end over one ``ParserEngine``'s backend and buckets.

    Usually reached as ``ParserEngine(mesh=...).dist``; also constructible
    standalone from matrices / a segment table / a prebuilt engine.  Sharding
    specs resolve through ``parallel/sharding.py``'s ``MeshRules`` — the
    logical 'chunk' axis and 'data' batch axis, filtered to whatever axes the
    given mesh actually has (a 1-axis host mesh degrades gracefully: absent
    axes replicate).
    """

    def __init__(self, matrices_or_engine, mesh, *, backend=None, rules=None):
        self.engine = _resolve_engine(matrices_or_engine, backend)
        self.mesh = mesh
        self.rules = rules if rules is not None else MeshRules()
        # single-text route: the chunk dim takes every mesh axis the 'chunk'
        # rule names — all of ('pod','data') that exist on this mesh
        self.chunk_axes = self.rules.resolve_axes("chunk", mesh)
        # batched route: batch is pure DP over 'data'; MeshRules' duplicate-
        # axis dropping then leaves 'pod' (when present) for the chunk dim
        bspec = self.rules.with_overrides(batch="data").resolve(
            ("batch", "chunk"), mesh
        )
        self.batch_axes = spec_axes(bspec, 0)
        self.batch_chunk_axes = spec_axes(bspec, 1)
        self._chunk_prog = None
        self._batched_prog = None
        self._join_prog = None

    # ------------------------------------------------------------- geometry

    @property
    def chunk_devices(self) -> int:
        """Devices the single-text route splits the chunk dim across."""
        return mesh_axes_size(self.mesh, self.chunk_axes)

    @property
    def batch_devices(self) -> int:
        """Devices the batched route splits the batch dim across."""
        return mesh_axes_size(self.mesh, self.batch_axes)

    @property
    def batch_chunk_devices(self) -> int:
        """Devices the batched route splits the chunk dim across."""
        return mesh_axes_size(self.mesh, self.batch_chunk_axes)

    def _bump(self):
        # Python side effect at trace time, like the engine's counted_core
        # (routes through the engine so the metrics registry sees it too)
        self.engine._bump_compiles()

    def _product_nbytes(self) -> int:
        """Bytes of ONE chunk product in the backend's representation —
        the unit of the all-gather payload accounting (packed words and
        sparse rows shrink it automatically)."""
        t = self.engine.tables
        eye = self.engine.backend.identity_product(t.ell_pad, dtype=t.N.dtype)
        return int(eye.size) * eye.dtype.itemsize

    def _count_allgather(self, n_products: int, gather_axes) -> None:
        """Record the product-stack collective payload for one dispatch.

        The contract's step 2 moves the full (c, …) stack to every device;
        the counted payload is the gathered stack's bytes (text-length
        independent).  A degenerate mesh (no gather axes) moves nothing.
        """
        if not gather_axes:
            return
        self.engine.obs.metrics.counter("allgather_payload_bytes_total").inc(
            n_products * self._product_nbytes()
        )

    def _rep(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())

    # ------------------------------------------------- single-text program

    @property
    def chunk_program(self):
        """Jitted single-text route: chunks (c, k) sharded over chunk_axes."""
        if self._chunk_prog is None:
            self._chunk_prog = self._build_chunk_program()
        return self._chunk_prog

    def _build_chunk_program(self):
        backend = self.engine.backend
        axes = self.chunk_axes
        spec = PartitionSpec(_entry(axes))

        def body(N, I, F, chunks):  # chunks: (f, k) shard-local rows
            self._bump()
            P_local = backend.reach(N, chunks)            # (f, ℓp, ℓp)
            P_all = _gather(P_local, axes, axis=0)        # (c, ℓp, ℓp) repl.
            Jf, Jb, col0p = join_with_col0(backend, P_all, I, F)
            f = P_local.shape[0]
            start = linear_index(axes) * f
            Jf_loc = jax.lax.dynamic_slice_in_dim(Jf, start, f, 0)
            Jb_loc = jax.lax.dynamic_slice_in_dim(Jb, start, f, 0)
            M = backend.build_merge_packed(N, chunks, Jf_loc, Jb_loc)
            return col0p, M

        program = _shard_map()(
            body,
            mesh=self.mesh,
            in_specs=(PartitionSpec(), PartitionSpec(), PartitionSpec(), spec),
            out_specs=(PartitionSpec(), spec),
        )
        rep = self._rep()
        return jax.jit(
            program,
            in_shardings=(rep, rep, rep, NamedSharding(self.mesh, spec)),
            out_shardings=(rep, NamedSharding(self.mesh, spec)),
        )

    # ---------------------------------------------------- batched program

    @property
    def batched_program(self):
        """Jitted batched route: (B, c, k) with batch over 'data', chunks
        over 'pod'."""
        if self._batched_prog is None:
            self._batched_prog = self._build_batched_program()
        return self._batched_prog

    def _build_batched_program(self):
        backend = self.engine.backend
        b_axes, c_axes = self.batch_axes, self.batch_chunk_axes
        spec_in = PartitionSpec(_entry(b_axes), _entry(c_axes))
        spec_b = PartitionSpec(_entry(b_axes))

        def body(N, I, F, batch):  # batch: (B_loc, c_loc, k) shard-local
            self._bump()
            reach_b = backend.lift_batch(lambda ch: backend.reach(N, ch))
            P_local = reach_b(batch)                      # (B_loc, c_loc, ℓp, ℓp)
            P_all = _gather(P_local, c_axes, axis=1)      # (B_loc, c, ℓp, ℓp)
            join_b = backend.lift_batch(
                lambda Pa: join_with_col0(backend, Pa, I, F)
            )
            Jf, Jb, col0p = join_b(P_all)                 # (B_loc, c, ℓp) ×2
            f = P_local.shape[1]
            start = linear_index(c_axes) * f
            Jf_loc = jax.lax.dynamic_slice_in_dim(Jf, start, f, 1)
            Jb_loc = jax.lax.dynamic_slice_in_dim(Jb, start, f, 1)
            bm_b = backend.lift_batch(
                lambda ch, ef, eb: backend.build_merge_packed(N, ch, ef, eb)
            )
            M = bm_b(batch, Jf_loc, Jb_loc)               # (B_loc, c_loc, k, W)
            return col0p, M

        program = _shard_map()(
            body,
            mesh=self.mesh,
            in_specs=(PartitionSpec(), PartitionSpec(), PartitionSpec(), spec_in),
            out_specs=(spec_b, spec_in),
        )
        rep = self._rep()
        return jax.jit(
            program,
            in_shardings=(rep, rep, rep, NamedSharding(self.mesh, spec_in)),
            out_shardings=(
                NamedSharding(self.mesh, spec_b),
                NamedSharding(self.mesh, spec_in),
            ),
        )

    # ------------------------------------------------- streaming join route

    @property
    def join_program(self):
        if self._join_prog is None:
            self._join_prog = self._build_join_program()
        return self._join_prog

    def _build_join_program(self):
        backend = self.engine.backend
        axes = self.chunk_axes
        spec = PartitionSpec(_entry(axes))

        def body(P, I, F):  # P: (f, ℓp, ℓp) shard-local product rows
            self._bump()
            P_all = _gather(P, axes, axis=0)
            return join_with_col0(backend, P_all, I, F)

        program = _shard_map()(
            body,
            mesh=self.mesh,
            in_specs=(spec, PartitionSpec(), PartitionSpec()),
            out_specs=(PartitionSpec(), PartitionSpec(), PartitionSpec()),
        )
        rep = self._rep()
        return jax.jit(
            program,
            in_shardings=(NamedSharding(self.mesh, spec), rep, rep),
            out_shardings=(rep, rep, rep),
        )

    def join_products(self, P: jnp.ndarray):
        """Sharded-stack join — the streaming contract.

        ``P`` (c, ℓp, ℓp) is a stacked chunk-product prefix (e.g. the
        streaming cache's sealed products + tail); it lives sharded over the
        chunk axes and is all-gathered once before the replicated scan.
        Returns (Jf, Jb, packed C₀), all replicated.  The stack pads with
        identity products to a multiple of the chunk device count —
        identities are no-ops for both scan directions, so entries at real
        indices are unchanged (a power-of-two input stack stays power-of-two,
        keeping the compiled-shape set bounded).
        """
        t = self.engine.tables
        c = int(P.shape[0])
        c_pad = _round_up(max(c, 1), self.chunk_devices)
        if c_pad != c:
            eye = self.engine.backend.identity_product(t.ell_pad, dtype=t.N.dtype)
            P = jnp.concatenate(
                [P, jnp.broadcast_to(eye, (c_pad - c,) + eye.shape)], axis=0
            )
        self._count_allgather(c_pad, self.chunk_axes)
        return self.join_program(P, t.I, t.F)

    # ---------------------------------------------------------------- parse

    def parse(self, text, n_chunks: Optional[int] = None) -> SLPF:
        """One text, the chunk dim sharded over EVERY chunk axis.

        The long-text route: one device program, reach/build&merge shard-
        local, one product-stack all-gather.  ``n_chunks`` rounds up to a
        multiple of the chunk device count (default: one bucket-padded chunk
        row per device, at least 8 rows total).
        """
        eng = self.engine
        csz = self.chunk_devices
        c_req = n_chunks if n_chunks is not None else max(8, csz)
        c_req = _round_up(max(1, c_req), csz)
        classes = eng.classes_of_text(text)
        c, k = eng.bucket_shape(len(classes), c_req)
        chunks = eng._pad_to(classes, c, k)
        t = eng.tables
        self._count_allgather(c, self.chunk_axes)
        col0, cols = self.chunk_program(t.N, t.I, t.F, chunks)
        return eng._assemble(np.asarray(col0), np.asarray(cols), classes)

    def parse_batch(self, texts: Sequence, n_chunks: int = 8) -> List[SLPF]:
        """Many texts: batch slots over 'data' × chunks over 'pod'.

        Identical grouping/bucketing to ``ParserEngine.parse_batch``; batch
        slots additionally round up to a multiple of the batch device count
        and chunk counts to the chunk device count (all-PAD rows/chunks are
        identity, discarded on assembly).
        """
        eng = self.engine
        csz = self.batch_chunk_devices
        dsz = self.batch_devices
        c_req = _round_up(max(1, n_chunks), csz)
        classes_list = [eng.classes_of_text(t) for t in texts]
        groups = {}
        for i, cls in enumerate(classes_list):
            groups.setdefault(eng.bucket_shape(len(cls), c_req), []).append(i)

        t = eng.tables
        results: List[Optional[SLPF]] = [None] * len(texts)
        for (c, k), idxs in sorted(groups.items()):
            B = _round_up(_next_pow2(len(idxs)), dsz)
            batch = np.full((B, c, k), t.pad_class, dtype=np.int32)
            for row, i in enumerate(idxs):
                batch[row] = eng._pad_to(classes_list[i], c, k)
            self._count_allgather(B * c, self.batch_chunk_axes)
            col0s, colss = self.batched_program(t.N, t.I, t.F, batch)
            col0s = np.asarray(col0s)
            colss = np.asarray(colss)
            for row, i in enumerate(idxs):
                results[i] = eng._assemble(col0s[row], colss[row], classes_list[i])
        return results  # type: ignore[return-value]
