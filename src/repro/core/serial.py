"""Serial parsers (paper Sect. 2.4 and Sect. 4.1 — *serial parser*).

Two paper-faithful serial algorithms, both returning the clean SLPF:

* ``parse_serial_matrix`` — the NFA matrix parser of Fig. 10 / Eq. (4):
  ``C_r = N_{x_r} × C_{r-1}`` forwards from ``I``, ``Ĉ_r = N^T_{x_{r+1}} × Ĉ_{r+1}``
  backwards from ``F``, clean column = ``C_r ∩ Ĉ_r``.  Boolean matmuls in numpy.
  This is the baseline the parallel parser is derived from — slow but transparent.

* ``parse_serial_dfa`` — the DFA look-up-table parser outlined in Sect. 4.1:
  one forward DFA run (each DFA state *is* the segment-set column) and one
  backward reverse-DFA run, intersected per column.  Same output, no matmuls.

Also: ``recognize`` — the mere recognizer (forward only, Sect. 4.2).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .automata import DFA, ParserNFA, build_dfa, build_nfa
from .matrices import ParserMatrices, boolean_matvec, build_matrices
from .numbering import number_regex
from .segments import SegmentTable, compute_segments
from .slpf import SLPF


def _as_classes(matrices: ParserMatrices, text) -> np.ndarray:
    if isinstance(text, (bytes, str)):
        return matrices.classes_of_text(text)
    return np.asarray(text, dtype=np.int32)


def parse_serial_matrix(matrices: ParserMatrices, text) -> SLPF:
    """Fig. 10: forward + backward Boolean matrix passes, then intersect."""
    classes = _as_classes(matrices, text)
    n = len(classes)
    ell = matrices.n_segments
    N = matrices.N

    C = np.zeros((n + 1, ell), dtype=bool)
    C[0] = matrices.I
    for r in range(1, n + 1):
        C[r] = boolean_matvec(N[classes[r - 1]], C[r - 1])

    # Backward pass with the reverse NFA: transposed matrices, I and F switched
    # (Eq. 5).  Overwrites C in place with the intersection — the paper's memory
    # optimization (Sect. 2.4 note / Fig. 14 applied to the serial case).
    back = matrices.F.copy()
    C[n] &= back
    for r in range(n - 1, -1, -1):
        back = boolean_matvec(N[classes[r]].T, back)
        C[r] &= back

    return SLPF(table=matrices.table, columns=C, classes=classes)


def parse_serial_dfa(
    matrices: ParserMatrices,
    text,
    dfa: Optional[DFA] = None,
    rdfa: Optional[DFA] = None,
    nfa: Optional[ParserNFA] = None,
) -> SLPF:
    """Sect. 4.1 serial DFA parser: look-up-table forward + backward runs."""
    classes = _as_classes(matrices, text)
    table = matrices.table
    if nfa is None:
        nfa = build_nfa(table)
    if dfa is None:
        dfa = build_dfa(nfa)
    if rdfa is None:
        rdfa = build_dfa(nfa.reverse())

    n = len(classes)
    ell = table.n
    pad = matrices.pad_class

    def run(d: DFA, seq) -> list:
        """Forward column series as segment-set vectors; dead state ⇒ empty."""
        cols = [np.zeros(ell, dtype=bool)]
        state: Optional[int] = d.initial[0]
        for q in d.states[state]:
            cols[0][q] = True
        for c in seq:
            c = int(c)
            if state is not None and c != pad:
                state = d.step(state, c)
            col = np.zeros(ell, dtype=bool)
            if state is not None:
                for q in d.states[state]:
                    col[q] = True
            cols.append(col)
        return cols

    fwd = run(dfa, classes)
    bwd = run(rdfa, classes[::-1])[::-1]
    C = np.stack([f & b for f, b in zip(fwd, bwd)])
    return SLPF(table=table, columns=C, classes=classes)


def recognize(matrices: ParserMatrices, text, dfa: Optional[DFA] = None) -> bool:
    """Mere recognizer (Sect. 4.2): forward DFA run, check final."""
    classes = _as_classes(matrices, text)
    if dfa is None:
        dfa = build_dfa(build_nfa(matrices.table))
    state: Optional[int] = dfa.initial[0]
    for c in classes:
        state = dfa.step(state, int(c))
        if state is None:
            return False
    return dfa.final[state]


class SerialParser:
    """Convenience wrapper bundling the generated artifacts for one RE."""

    def __init__(self, pattern: str, *, mask_ops=(), inf_limit: int = 2):
        self.table: SegmentTable = compute_segments(
            number_regex(pattern, mask_ops=mask_ops), inf_limit=inf_limit
        )
        self.matrices = build_matrices(self.table)
        self.nfa = build_nfa(self.table)
        self.dfa = build_dfa(self.nfa)
        self.rdfa = build_dfa(self.nfa.reverse())

    def parse(self, text, *, method: str = "dfa") -> SLPF:
        if method == "matrix":
            return parse_serial_matrix(self.matrices, text)
        return parse_serial_dfa(self.matrices, text, self.dfa, self.rdfa, self.nfa)

    def accepts(self, text) -> bool:
        return recognize(self.matrices, text, self.dfa)
