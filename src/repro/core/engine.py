"""Layered parse runtime: backend-pluggable three-phase engine, batched front-end.

The runtime is organised in three layers (bottom-up):

  phase backends   ``core/backend.py`` — swappable implementations of the
                   paper's reach / join / build&merge phases over the padded
                   table layout.  ``jnp`` is the pure-``jax.numpy`` reference
                   device program; ``pallas`` wires in the Mosaic kernels of
                   ``repro/kernels`` (scalar-prefetch DMA pipelining on TPU,
                   interpret mode on CPU so CI exercises the real BlockSpecs).
                   The join phase is shared by every backend: it is
                   ``core/scan.py``'s ``exclusive_entries`` over the Boolean
                   OR-AND matrix monoid — the same scan primitive the Mamba-2
                   SSD chunked state passing uses.

  engine           ``ParserEngine(backend=...)`` compiles ONE program per
                   static chunk shape (c, k) and runs texts through it.
                   Texts pad to equal static chunks with the PAD class, whose
                   matrix is the identity — a semantic no-op replacing the
                   paper's load-balancing fragments (Sect. 4.3) with
                   SPMD-exact balance.  Chunk lengths are *bucketed* to a
                   small set of power-of-two shapes so arbitrary text lengths
                   hit a handful of compiled programs instead of re-jitting
                   per length (``compile_count`` exposes the trace count).
                   Zero-length texts flow through the same bucketed path.

  batched front-end ``parse_batch(texts)`` groups mixed-length requests by
                   shape bucket, pads each group to power-of-two batch slots,
                   and executes one batched device program per bucket —
                   request-level serving on top lives in
                   ``serve/parse_service.py`` (slot pattern of the LM
                   scheduler).

  phase programs   ``ParserEngine.phases`` — the same three phases as
                   separately-jitted programs whose boundaries (the stacked
                   chunk products P_i — backend-owned representation — and
                   the join entries) are first-class, cacheable arrays
                   instead of fused intermediates.  This is the seam the
                   streaming layer caches across calls.

  stream layer     ``core/stream.py``'s ``StreamingParser`` — a persistent
                   prefix cache of sealed chunk products + a mutable tail;
                   ``append`` re-runs only the appended piece's reach and the
                   O(log c) join over cached summaries.  Session-level
                   serving lives in ``serve/stream_service.py`` (bucket-
                   batched tail execution across sessions, bytes-budget
                   eviction).

  distribution     ``core/distributed.py``'s ``DistributedEngine`` — the
                   same phase bodies placed over a device mesh: reach and
                   build&merge shard-local, ONE all-gather of the stacked
                   chunk products, replicated join.  ``ParserEngine(mesh=...)``
                   builds it lazily and routes ``parse`` (chunks over every
                   'chunk' axis) and ``parse_batch`` (batch over 'data' ×
                   chunks over 'pod') through it; specs resolve via
                   ``parallel/sharding.py``'s ``MeshRules``.

Mapping from the paper's phases (all validated against ``core/reference.py``,
the paper-faithful oracle):

  reach   Per chunk, the Boolean-semiring matrix chain product
          ``P_i = N_{y_k} ⊗ … ⊗ N_{y_1}`` (ℓ×ℓ).  Column j of ``P_i`` equals
          ``R_{i,j}`` (Eq. 6): all ℓ speculative ME-DFA entries are evaluated
          *simultaneously* as matrix columns on the MXU.

  join    Eq. (7) becomes an exclusive monoid scan over the chunk products.
          Cross-device: one all_gather of the (c, ℓ, ℓ) summaries + a
          replicated log-depth local scan — O(c·ℓ²) bytes of collective
          traffic, independent of the text length.

  build & Fig. 14's fused builder&merger.  Beyond the paper: the backward
  merge   *reach* phase is free — reverse chunk summaries are the transposes
          ``P_iᵀ`` (Eq. 5 + product reversal), so only one reach pass is ever
          computed (paper runs both).

Numeric form: {0,1} float32 matrices; ``⊗`` = matmul + min(·,1) (exact in f32
up to 2²⁴ ≫ ℓ).  SLPF columns are emitted bit-packed (uint32, 32 segments per
word, App. C encoding).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import ObsHandle
from .backend import (
    ParserBackend,
    get_backend,
    join_entries,
    pack_columns_u32,
)
from .matrices import ParserMatrices, build_matrices, unpack_bits
from .segments import SegmentTable
from .slpf import SLPF

# Back-compat alias: the join phase now lives in core/backend.py on top of
# core/scan.py's exclusive_entries (one scan implementation repo-wide).
_entries_from_products = join_entries


# ---------------------------------------------------------------- tables


@dataclass
class EngineTables:
    """Device-resident parser tables for one RE."""

    N: jnp.ndarray            # (A+1, ℓp, ℓp) f32 — PAD class (index A) = identity
    I: jnp.ndarray            # (ℓp,) f32
    F: jnp.ndarray            # (ℓp,) f32
    byte_to_class: jnp.ndarray  # (256,) int32
    ell: int                  # true segment count
    ell_pad: int              # padded to a multiple of ``lane_pad``
    pad_class: int

    @classmethod
    def from_matrices(cls, m: ParserMatrices, lane_pad: int = 32) -> "EngineTables":
        ell = m.n_segments
        lp = max(lane_pad, ((ell + lane_pad - 1) // lane_pad) * lane_pad)
        A1 = m.N.shape[0]
        N = np.zeros((A1, lp, lp), dtype=np.float32)
        N[:, :ell, :ell] = m.N.astype(np.float32)
        N[-1] = np.eye(lp, dtype=np.float32)  # PAD = identity over the padded space
        I = np.zeros(lp, dtype=np.float32)
        I[:ell] = m.I
        F = np.zeros(lp, dtype=np.float32)
        F[:ell] = m.F
        return cls(
            N=jnp.asarray(N),
            I=jnp.asarray(I),
            F=jnp.asarray(F),
            byte_to_class=jnp.asarray(m.byte_to_class),
            ell=ell,
            ell_pad=lp,
            pad_class=m.pad_class,
        )


# ------------------------------------------------------------- parse core


def join_with_col0(backend: ParserBackend, P, I, F):
    """Join phase over stacked products, plus the packed text-start column.

    C_0 = I ∧ β_0 with β_0 = P_0ᵀ Ĵ_0 — the backward state at text start,
    recovered from the reach products (no extra backward pass).  ``P`` is the
    backend's opaque product stack; the product arithmetic lives behind
    ``backend.start_column`` so representations (f32 matrices, packed words)
    never leak here.
    """
    Jf, Jb = backend.join(P, I, F)                       # (c, ℓp) f32 each
    col0 = backend.start_column(P, I, Jb[0])
    return Jf, Jb, pack_columns_u32(col0)


def make_parse_core(backend: ParserBackend):
    """Single-text three-phase program over one (c, k) chunk grid.

    Returns ``core(N, I, F, chunks) -> (packed col0 (W,), packed cols (c,k,W))``.
    This is the *fused* composition of the phase bodies; ``PhasePrograms``
    exposes the identical phases as separate programs with cacheable
    boundaries.
    """

    def parse_core(N, I, F, chunks):
        P = backend.reach(N, chunks)                     # (c, …) products
        Jf, Jb, col0p = join_with_col0(backend, P, I, F)
        return col0p, backend.build_merge_packed(N, chunks, Jf, Jb)  # (c, k, W)

    return parse_core


class PhasePrograms:
    """The three phases as separately-jitted, shape-bucketed device programs.

    Where ``make_parse_core`` fuses reach → join → build&merge into one
    program (best for cold batch parsing), these programs expose every phase
    boundary as a first-class array contract:

      reach        (N, (c, k) chunks)        → (c, …) chunk products P_i
      compose      (later P, earlier P)      → later ⊗ earlier (one product)
      join         (P (c, …), I, F)          → (Jf, Jb, packed C_0)
      build_merge  (N, chunks, Jf, Jb)       → (c, k, W) packed clean columns

    The products crossing these seams are *backend-owned opaque* device
    arrays (f32 (ℓp, ℓp) matrices for jnp/pallas, uint32 (ℓp, W) words for
    packed — see ``core/backend.py``'s contract); callers may cache, slice
    along axis 0, restack, and feed them back in, never arithmetic on them.
    Entries and packed columns are fixed f32/u32 layouts.  This is the
    contract the streaming prefix cache (``core/stream.py``) is built on,
    and the same seam sharded-batched execution plugs into.  Each program
    re-traces once per input shape, so callers that bucket their shapes
    (power-of-two chunk lengths / product counts) keep the compiled set
    bounded exactly like the fused path.
    """

    def __init__(self, backend: ParserBackend, on_trace: Optional[Callable] = None):
        notify = on_trace or (lambda: None)

        def _reach(N, chunks):
            notify()
            return backend.reach(N, chunks)

        def _compose(later, earlier):
            notify()
            return backend.compose(later, earlier)

        def _join(P, I, F):
            notify()
            return join_with_col0(backend, P, I, F)

        def _build_merge(N, chunks, Jf, Jb):
            notify()
            return backend.build_merge_packed(N, chunks, Jf, Jb)

        self.backend = backend
        self.reach = jax.jit(_reach)
        self.compose = jax.jit(_compose)
        self.join = jax.jit(_join)
        self.build_merge = jax.jit(_build_merge)


def _next_pow2(x: int) -> int:
    return 1 << max(0, x - 1).bit_length()


# ---------------------------------------------------------------- engine


class ParserEngine:
    """Single-host engine: backend-pluggable, shape-bucketed, batch-capable."""

    def __init__(
        self,
        matrices_or_table,
        *,
        lane_pad: int = 32,
        backend: Union[str, ParserBackend] = "jnp",
        min_chunk_len: int = 8,
        mesh=None,
        mesh_rules=None,
        obs: Optional[ObsHandle] = None,
    ):
        if isinstance(matrices_or_table, SegmentTable):
            matrices = build_matrices(matrices_or_table)
        else:
            matrices = matrices_or_table
        self.matrices = matrices
        self.table = matrices.table
        self.backend = get_backend(backend)
        lane_pad = max(lane_pad, self.backend.min_lane_pad)
        self.tables = EngineTables.from_matrices(matrices, lane_pad=lane_pad)
        # table-dependent backends (sparse width bucket) fix their static
        # product shapes here, before any phase program is traced
        self.backend.bind_tables(self.tables)
        self.min_chunk_len = max(1, min_chunk_len)
        self.mesh = mesh
        self.mesh_rules = mesh_rules
        # the observability seam every layer over this engine records into
        # (core/stream.py, core/distributed.py, both services, the facade);
        # a default handle is a disabled tracer + live metrics registry
        self.obs = obs if obs is not None else ObsHandle()

        self._compile_count = 0
        self._phases: Optional[PhasePrograms] = None
        self._dist = None
        self._seen_batch_shapes: set = set()
        self._hlo_memo: Dict[Tuple[int, int], Dict[str, Dict[str, float]]] = {}

        def counted_core(N, I, F, chunks, _core=make_parse_core(self.backend)):
            # Python side effect at trace time: counts compiled programs.
            self._bump_compiles()
            return _core(N, I, F, chunks)

        self._jit_batched = jax.jit(self.backend.batch_core(counted_core))

    def _bump_compiles(self) -> None:
        """One device program traced — a re-jit event (trace-time side
        effect, mirrored into the metrics registry)."""
        self._compile_count += 1
        self.obs.metrics.counter("compiled_programs_total").inc()

    # ------------------------------------------------------------- helpers

    @property
    def compile_count(self) -> int:
        """Number of distinct programs traced so far (one per shape bucket)."""
        return self._compile_count

    @property
    def phases(self) -> PhasePrograms:
        """Separately-jitted phase programs over this engine's backend.

        Built lazily (the fused batch path never pays for them); traces are
        counted into ``compile_count`` like every other engine program.
        """
        if self._phases is None:
            self._phases = PhasePrograms(self.backend, on_trace=self._bump_compiles)
        return self._phases

    @property
    def dist(self):
        """The mesh distribution layer (``core/distributed.py``) when this
        engine was built with ``mesh=``; None on a single-device engine.
        Built lazily — a mesh-less engine never imports it."""
        if self.mesh is None:
            return None
        if self._dist is None:
            from .distributed import DistributedEngine

            self._dist = DistributedEngine(self, self.mesh, rules=self.mesh_rules)
        return self._dist

    def classes_of_text(self, text) -> np.ndarray:
        if isinstance(text, (bytes, str)):
            return self.matrices.classes_of_text(text)
        return np.asarray(text, dtype=np.int32)

    def bucket_shape(self, n: int, n_chunks: int) -> Tuple[int, int]:
        """Static (c, k) chunk-grid bucket for a text of length ``n``.

        c is fixed by ``n_chunks``; k rounds up to the next power of two (with
        a floor of ``min_chunk_len``) so arbitrary lengths land in O(log n)
        distinct compiled shapes instead of one per length.  The trade: a text
        just past a bucket edge runs up to ~2x padded cells (identity-PAD
        steps are materialized), in exchange for never paying a re-jit —
        lengths 2^p·c+1 … 2^(p+1)·c share one program.
        """
        c = max(1, n_chunks)
        k = _next_pow2(max(self.min_chunk_len, -(-n // c)))
        return c, k

    def pad_chunks(self, classes: np.ndarray, n_chunks: int) -> np.ndarray:
        """Pad with the identity PAD class to equal static chunks (DESIGN §2)."""
        n = len(classes)
        c = max(1, n_chunks)
        k = max(1, -(-n // c))
        return self._pad_to(classes, c, k)

    def _pad_to(self, classes: np.ndarray, c: int, k: int) -> np.ndarray:
        padded = np.full(c * k, self.tables.pad_class, dtype=np.int32)
        padded[: len(classes)] = classes
        return padded.reshape(c, k)

    # --------------------------------------------------------------- parse

    def parse(self, text, n_chunks: int = 8) -> SLPF:
        """Parse one text through the bucketed batch program (batch slot 1).

        All lengths — including zero — route through the same padded/jitted
        path; PAD chunks are identity, so the bucket padding is semantics-free.
        Sharing the batched program means mixing ``parse`` and ``parse_batch``
        compiles one program per bucket, not two.

        On a mesh engine this is the long-text route: the chunk dim shards
        over EVERY chunk axis ('pod' × 'data').
        """
        if self.mesh is not None:
            return self.dist.parse(text, n_chunks=n_chunks)
        return self.parse_batch([text], n_chunks=n_chunks)[0]

    def parse_batch(self, texts: Sequence, n_chunks: int = 8) -> List[SLPF]:
        """Parse many texts, bucketed by static shape, one device program each.

        Texts are grouped by their (c, k) bucket; each group is padded to a
        power-of-two number of batch slots (extra rows are all-PAD and
        discarded), so the set of compiled programs stays small and static —
        at most one per (bucket, batch-slot) shape, reused across calls.

        On a mesh engine the groups run through the distributed batched
        route instead: batch slots shard over 'data', chunks over 'pod'.
        """
        if self.mesh is not None:
            return self.dist.parse_batch(texts, n_chunks=n_chunks)
        classes_list = [self.classes_of_text(t) for t in texts]
        groups: Dict[Tuple[int, int], List[int]] = {}
        for i, cls in enumerate(classes_list):
            groups.setdefault(self.bucket_shape(len(cls), n_chunks), []).append(i)

        m = self.obs.metrics
        results: List[Optional[SLPF]] = [None] * len(texts)
        for (c, k), idxs in sorted(groups.items()):
            B = _next_pow2(len(idxs))
            # bucket program-cache accounting: a (B, c, k) shape seen before
            # dispatches a compiled program; a new one is a re-jit event
            if (B, c, k) in self._seen_batch_shapes:
                m.counter("bucket_cache_hits_total").inc()
            else:
                self._seen_batch_shapes.add((B, c, k))
                m.counter("bucket_cache_misses_total").inc()
            batch = np.full((B, c, k), self.tables.pad_class, dtype=np.int32)
            for row, i in enumerate(idxs):
                batch[row] = self._pad_to(classes_list[i], c, k)
            col0s, colss = self._jit_batched(
                self.tables.N, self.tables.I, self.tables.F, jnp.asarray(batch)
            )
            col0s = np.asarray(col0s)
            colss = np.asarray(colss)
            for row, i in enumerate(idxs):
                results[i] = self._assemble(col0s[row], colss[row], classes_list[i])
        return results  # type: ignore[return-value]

    def _assemble(self, col0, cols, classes) -> SLPF:
        n = len(classes)
        W = cols.shape[-1]
        packed = np.concatenate(
            [np.asarray(col0)[None], np.asarray(cols).reshape(-1, W)[:n]], axis=0
        )
        columns = unpack_bits(packed, self.tables.ell, axis=-1)
        return SLPF(table=self.table, columns=columns, classes=classes)

    # -------------------------------------------------------- observability

    def parse_traced(self, text, n_chunks: int = 8) -> SLPF:
        """Parse one text with per-phase spans (the observability route).

        Runs the separately-jitted phase programs — the same bodies the
        fused program composes, bit-identical (the phase-split route of
        ``tests/test_conformance.py``) — so each phase boundary is a real
        host-side seam that can be timed honestly: every span blocks on its
        device result before closing.  Queue-free: this is the direct route
        ``Parser.parse`` takes when tracing is enabled (mesh engines keep
        their fused distributed program and report one ``phase.device_parse``
        span instead — the phases live inside one ``shard_map``).
        """
        obs = self.obs
        classes = self.classes_of_text(text)
        if self.mesh is not None:
            with obs.span("phase.device_parse", n_chars=len(classes)):
                slpf = self.dist.parse(classes, n_chunks=n_chunks)
            return slpf
        c, k = self.bucket_shape(len(classes), n_chunks)
        chunks = jnp.asarray(self._pad_to(classes, c, k))
        t = self.tables
        with obs.span("phase.reach", bucket=[c, k], n_chars=len(classes)):
            P = jax.block_until_ready(self.phases.reach(t.N, chunks))
        with obs.span("phase.join", n_products=c):
            Jf, Jb, col0p = jax.block_until_ready(self.phases.join(P, t.I, t.F))
        with obs.span("phase.build_merge", bucket=[c, k]):
            cols = jax.block_until_ready(
                self.phases.build_merge(t.N, chunks, Jf, Jb)
            )
        with obs.span("phase.host_build", n_chars=len(classes)):
            slpf = self._assemble(np.asarray(col0p), np.asarray(cols), classes)
        return slpf

    def phase_static_cost(self, c: int, k: int) -> Dict[str, Dict[str, float]]:
        """Static modeled cost of one bucket's compiled phase programs.

        Lowers the reach / join / build&merge phase programs at this bucket's
        shapes and runs ``launch/hlo_stats.py`` over the optimized HLO —
        trip-count-aware flops / HBM-model bytes / collective bytes, the
        modeled numbers ``Parser.stats()`` places next to the observed phase
        times.  One extra lowering+compile per bucket, memoized forever, and
        recorded once into the metrics registry as per-phase gauges.
        """
        key = (int(c), int(k))
        if key in self._hlo_memo:
            return self._hlo_memo[key]
        from ..launch.hlo_stats import analyze_hlo_text

        t = self.tables
        eye = self.backend.identity_product(t.ell_pad, dtype=t.N.dtype)
        chunks_sds = jax.ShapeDtypeStruct((c, k), jnp.int32)
        P_sds = jax.ShapeDtypeStruct((c,) + eye.shape, eye.dtype)
        J_sds = jax.ShapeDtypeStruct((c, t.ell_pad), jnp.float32)
        phases = self.phases
        lowered = {
            "reach": (phases.reach, (t.N, chunks_sds)),
            "join": (phases.join, (P_sds, t.I, t.F)),
            "build_merge": (phases.build_merge, (t.N, chunks_sds, J_sds, J_sds)),
        }
        out: Dict[str, Dict[str, float]] = {}
        total = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
        bucket_label = f"{c}x{k}"
        m = self.obs.metrics
        for phase, (prog, args) in lowered.items():
            stats = analyze_hlo_text(prog.lower(*args).compile().as_text())
            entry = {
                "flops": stats.flops,
                "bytes": stats.bytes,
                "collective_bytes": stats.coll_bytes,
            }
            out[phase] = entry
            for field_name in total:
                total[field_name] += entry[field_name]
            m.gauge("hlo_flops", bucket=bucket_label, phase=phase).set(entry["flops"])
            m.gauge("hlo_bytes", bucket=bucket_label, phase=phase).set(entry["bytes"])
            m.gauge(
                "hlo_collective_bytes", bucket=bucket_label, phase=phase
            ).set(entry["collective_bytes"])
        out["total"] = total
        self._hlo_memo[key] = out
        return out

    def count_accepting(self, text, n_chunks: int = 8) -> int:
        return self.parse(text, n_chunks).count_trees()


def _resolve_engine(
    matrices_or_engine,
    backend: Union[str, ParserBackend, None],
    mesh=None,
    mesh_rules=None,
    min_chunk_len: Optional[int] = None,
) -> ParserEngine:
    """Shared constructor contract of everything layered on the engine
    (ParseService, StreamingParser, StreamService): accept matrices / a
    segment table and build an engine, or accept a prebuilt ParserEngine —
    in which case ``backend=``/``mesh=`` must not also be passed."""
    if isinstance(matrices_or_engine, ParserEngine):
        if backend is not None or mesh is not None:
            raise ValueError(
                "pass backend=/mesh= only when building the engine here; "
                "a prebuilt ParserEngine already owns its backend and mesh"
            )
        return matrices_or_engine
    return ParserEngine(
        matrices_or_engine,
        backend=backend if backend is not None else "jnp",
        mesh=mesh,
        mesh_rules=mesh_rules,
        min_chunk_len=min_chunk_len if min_chunk_len is not None else 8,
    )


def resolve_engine(
    matrices_or_engine,
    backend: Union[str, ParserBackend, None],
    mesh=None,
    mesh_rules=None,
) -> ParserEngine:
    """Deprecated public alias of the internal engine-resolution path.

    The supported way to build the parse runtime is the ``repro.Parser``
    facade (``repro/api.py``), which owns engine and service construction
    from one declarative ``ParserConfig``.  This shim keeps pre-facade call
    sites working one release longer.
    """
    import warnings

    warnings.warn(
        "repro: resolve_engine is deprecated — construct repro.Parser "
        "(repro/api.py) instead; it owns engine/service construction",
        DeprecationWarning,
        stacklevel=2,
    )
    return _resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
