"""JAX parallel parsing engine — the paper's algorithm, TPU-native (DESIGN §2).

Mapping from the paper's phases to this engine (all validated against
``core/reference.py``, the paper-faithful oracle):

  reach   Per chunk, the Boolean-semiring matrix chain product
          ``P_i = N_{y_k} ⊗ … ⊗ N_{y_1}`` (ℓ×ℓ).  Column j of ``P_i`` equals
          ``R_{i,j}`` (Eq. 6): all ℓ speculative ME-DFA entries are evaluated
          *simultaneously* as matrix columns on the MXU.  The ME-DFA's bounded
          speculation (ℓ entries, never the 2^ℓ DFA states) holds identically.

  join    Eq. (7) becomes an exclusive monoid scan over the chunk products.
          Cross-device: one all_gather of the (c, ℓ, ℓ) summaries + a replicated
          log-depth local scan (``core/scan.py``) — O(c·ℓ²) bytes of collective
          traffic, independent of the text length.

  build & Fig. 14's fused builder&merger: forward Boolean mat-vec scan emits the
  merge   columns; the backward scan uses the *transposed* matrices and ANDs in
          place.  Beyond the paper: the backward *reach* phase is free — reverse
          chunk summaries are the transposes ``P_iᵀ`` (Eq. 5 + product reversal),
          so only one reach pass is ever computed (paper runs both).

  pad     Texts pad to equal static chunks with the PAD class, whose matrix is
          the identity — a semantic no-op replacing the paper's load-balancing
          fragments (Sect. 4.3) with SPMD-exact balance.

Numeric form: {0,1} float32 matrices; ``⊗`` = matmul + min(·,1) (exact in f32 up
to 2²⁴ ≫ ℓ).  SLPF columns are emitted bit-packed (uint32, 32 segments/word,
App. C encoding).  The Pallas kernels in ``repro/kernels`` implement the two hot
loops (reach product, fused build&merge) with explicit VMEM tiling; this module
is the pure-jnp engine the kernels are verified against, and is itself the
device program lowered in the multi-pod dry-run.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .matrices import ParserMatrices, build_matrices, unpack_bits
from .scan import associative_prefix
from .segments import SegmentTable
from .slpf import SLPF


# ----------------------------------------------------------- semiring ops


def semiring_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Boolean OR-AND product on {0,1} floats: clamp(a @ b)."""
    return jnp.minimum(jnp.matmul(a, b, precision=jax.lax.Precision.DEFAULT), 1.0)


def semiring_matvec(m: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(m @ v, 1.0)


def pack_columns_u32(cols: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp) {0,1} floats → (…, ℓp/32) uint32, little-endian bits."""
    shape = cols.shape
    lp = shape[-1]
    assert lp % 32 == 0
    bits = cols.reshape(shape[:-1] + (lp // 32, 32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


# ---------------------------------------------------------------- engine


@dataclass
class EngineTables:
    """Device-resident parser tables for one RE."""

    N: jnp.ndarray            # (A+1, ℓp, ℓp) f32 — PAD class (index A) = identity
    I: jnp.ndarray            # (ℓp,) f32
    F: jnp.ndarray            # (ℓp,) f32
    byte_to_class: jnp.ndarray  # (256,) int32
    ell: int                  # true segment count
    ell_pad: int              # padded to a multiple of ``lane_pad``
    pad_class: int

    @classmethod
    def from_matrices(cls, m: ParserMatrices, lane_pad: int = 32) -> "EngineTables":
        ell = m.n_segments
        lp = max(lane_pad, ((ell + lane_pad - 1) // lane_pad) * lane_pad)
        A1 = m.N.shape[0]
        N = np.zeros((A1, lp, lp), dtype=np.float32)
        N[:, :ell, :ell] = m.N.astype(np.float32)
        N[-1] = np.eye(lp, dtype=np.float32)  # PAD = identity over the padded space
        I = np.zeros(lp, dtype=np.float32)
        I[:ell] = m.I
        F = np.zeros(lp, dtype=np.float32)
        F[:ell] = m.F
        return cls(
            N=jnp.asarray(N),
            I=jnp.asarray(I),
            F=jnp.asarray(F),
            byte_to_class=jnp.asarray(m.byte_to_class),
            ell=ell,
            ell_pad=lp,
            pad_class=m.pad_class,
        )


def reach_chunk(N: jnp.ndarray, chunk: jnp.ndarray) -> jnp.ndarray:
    """Chunk product P = N[y_k] ⊗ … ⊗ N[y_1] — the reach phase (Eq. 6)."""
    lp = N.shape[-1]

    def step(P, cls):
        return semiring_matmul(N[cls], P), None

    P, _ = jax.lax.scan(step, jnp.eye(lp, dtype=N.dtype), chunk)
    return P


def build_merge_chunk(
    N: jnp.ndarray, chunk: jnp.ndarray, entry_f: jnp.ndarray, entry_b: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fig. 14 fused builder&merger for one chunk.

    Returns (M, beta0): M (k, ℓp) clean columns at positions 1..k of the chunk;
    beta0 (ℓp,) the backward state at the chunk start (used for global C_0).
    """

    def fstep(v, cls):
        nv = semiring_matvec(N[cls], v)
        return nv, nv

    _, fwd = jax.lax.scan(fstep, entry_f, chunk)            # fwd[t] = B_{t+1}

    def bstep(v, cls):
        nv = semiring_matvec(N[cls].T, v)
        return nv, nv

    _, bwd_rev = jax.lax.scan(bstep, entry_b, chunk[::-1])  # β_{k-1} … β_0
    bwd = bwd_rev[::-1]                                     # β_0 … β_{k-1}
    beta0 = bwd[0]
    # merge: M[t] = fwd[t] ∧ β_{t+1};  β_k = entry_b
    bwd_for_merge = jnp.concatenate([bwd[1:], entry_b[None]], axis=0)
    return fwd * bwd_for_merge, beta0


def _entries_from_products(
    P: jnp.ndarray, I: jnp.ndarray, F: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Join phase from stacked chunk products P (c, ℓp, ℓp).

    Forward entry of chunk i:  J_i  = P_{i-1} ⊗ … ⊗ P_0 applied to I.
    Backward entry of chunk i: Ĵ   = (P_{c-1} … P_{i+1})ᵀ applied to F —
    the transposed-suffix form that makes the backward reach free (DESIGN §2).
    """
    c = P.shape[0]
    prefix = associative_prefix(semiring_matmul, P)              # P_i ⊗ … ⊗ P_0
    Jf = jnp.concatenate(
        [I[None], jnp.minimum(jnp.einsum("cij,j->ci", prefix[:-1], I), 1.0)], axis=0
    )                                                            # (c, ℓp)
    # suffix products S_i = P_{c-1} ⊗ … ⊗ P_{i+1}: reverse, prefix, reverse.
    Prev = P[::-1]
    suf_prefix = associative_prefix(lambda later, earlier: semiring_matmul(earlier, later), Prev)
    # suf_prefix[j] = Prev_0 ⊗ … ⊗ Prev_j composed as P_{c-1} ⊗ … ⊗ P_{c-1-j}
    Sfull = suf_prefix[::-1]                                     # S'_i = P_{c-1}…P_i
    Jb = jnp.concatenate(
        [
            jnp.minimum(jnp.einsum("cji,j->ci", Sfull[1:], F), 1.0),  # transpose apply
            F[None],
        ],
        axis=0,
    )                                                            # (c, ℓp): Ĵ for chunk i
    return Jf, Jb


def _parse_core(
    N: jnp.ndarray, I: jnp.ndarray, F: jnp.ndarray, chunks: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full three-phase parse of (c, k) class chunks → packed columns.

    Returns (col0 packed (W,), cols packed (c, k, W)).
    """
    P = jax.vmap(lambda ch: reach_chunk(N, ch))(chunks)          # (c, ℓp, ℓp)
    Jf, Jb = _entries_from_products(P, I, F)
    M, beta0 = jax.vmap(lambda ch, ef, eb: build_merge_chunk(N, ch, ef, eb))(
        chunks, Jf, Jb
    )
    col0 = I * beta0[0]
    return pack_columns_u32(col0), pack_columns_u32(M)


_parse_jit = jax.jit(_parse_core)


class ParserEngine:
    """Single-host engine: jit-compiled chunked parallel parsing."""

    def __init__(
        self,
        matrices_or_table,
        *,
        lane_pad: int = 32,
    ):
        if isinstance(matrices_or_table, SegmentTable):
            matrices = build_matrices(matrices_or_table)
        else:
            matrices = matrices_or_table
        self.matrices = matrices
        self.table = matrices.table
        self.tables = EngineTables.from_matrices(matrices, lane_pad=lane_pad)

    # ------------------------------------------------------------- helpers

    def classes_of_text(self, text) -> np.ndarray:
        if isinstance(text, (bytes, str)):
            return self.matrices.classes_of_text(text)
        return np.asarray(text, dtype=np.int32)

    def pad_chunks(self, classes: np.ndarray, n_chunks: int) -> np.ndarray:
        """Pad with the identity PAD class to equal static chunks (DESIGN §2)."""
        n = len(classes)
        c = max(1, n_chunks)
        k = max(1, -(-n // c))
        padded = np.full(c * k, self.tables.pad_class, dtype=np.int32)
        padded[:n] = classes
        return padded.reshape(c, k)

    # --------------------------------------------------------------- parse

    def parse(self, text, n_chunks: int = 8) -> SLPF:
        classes = self.classes_of_text(text)
        n = len(classes)
        if n == 0:
            col = (self.matrices.I & self.matrices.F)[None, :]
            return SLPF(table=self.table, columns=col, classes=classes)
        chunks = self.pad_chunks(classes, n_chunks)
        col0, cols = _parse_jit(
            self.tables.N, self.tables.I, self.tables.F, jnp.asarray(chunks)
        )
        return self._assemble(col0, cols, classes)

    def _assemble(self, col0, cols, classes) -> SLPF:
        n = len(classes)
        W = cols.shape[-1]
        packed = np.concatenate(
            [np.asarray(col0)[None], np.asarray(cols).reshape(-1, W)[:n]], axis=0
        )
        columns = unpack_bits(packed, self.tables.ell, axis=-1)
        return SLPF(table=self.table, columns=columns, classes=classes)

    def count_accepting(self, text, n_chunks: int = 8) -> int:
        return self.parse(text, n_chunks).count_trees()


# ----------------------------------------------------- sharded (multi-pod)


def sharded_parse_step(
    N: jnp.ndarray,
    I: jnp.ndarray,
    F: jnp.ndarray,
    local_chunks: jnp.ndarray,
    axis_names: Sequence[str],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-device body (inside shard_map) of the multi-pod parser.

    ``local_chunks``: (f, k) — this device's f fragments.  Phases:
      reach   local (f chunk products),
      join    ONE all_gather of (c·f, ℓp, ℓp) summaries + replicated scan,
      build&merge local, emitting packed columns.
    Returns (col0 packed — valid on global chunk 0's device, cols (f, k, W)).
    """
    P_local = jax.vmap(lambda ch: reach_chunk(N, ch))(local_chunks)  # (f, ℓp, ℓp)
    gathered = jax.lax.all_gather(P_local, tuple(axis_names), axis=0, tiled=False)
    cf = P_local.shape[0]
    P_all = gathered.reshape((-1,) + P_local.shape[1:])              # (c·f, ℓp, ℓp)
    Jf_all, Jb_all = _entries_from_products(P_all, I, F)

    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    sl = idx * cf
    Jf = jax.lax.dynamic_slice_in_dim(Jf_all, sl, cf, 0)
    Jb = jax.lax.dynamic_slice_in_dim(Jb_all, sl, cf, 0)

    M, beta0 = jax.vmap(lambda ch, ef, eb: build_merge_chunk(N, ch, ef, eb))(
        local_chunks, Jf, Jb
    )
    col0 = I * beta0[0]  # meaningful on the device holding global chunk 0
    return pack_columns_u32(col0), pack_columns_u32(M)


def make_sharded_parser(tables: EngineTables, mesh, axis_names: Sequence[str], frags: int = 1):
    """Build the jitted multi-device parse program over ``mesh``.

    Input ``chunks``: (c_total·frags, k) int32, sharded over ``axis_names`` on
    dim 0.  Output columns sharded the same way (SLPF stays distributed; App. C
    packing applied on device).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec_in = P(tuple(axis_names))
    body = functools.partial(sharded_parse_step, axis_names=tuple(axis_names))

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(), spec_in),
        out_specs=(P(), spec_in),
        check_vma=False,  # scan carries start device-invariant, become varying
    )
    def program(N, I, F, chunks):
        col0, cols = body(N, I, F, chunks)
        # col0 from every device; keep chunk-0's via psum of masked values.
        idx = jnp.int32(0)
        for name in axis_names:
            idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
        col0 = jnp.where(idx == 0, col0, jnp.zeros_like(col0))
        col0 = jax.lax.psum(col0, tuple(axis_names))
        return col0, cols

    in_shardings = (
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, spec_in),
    )
    out_shardings = (NamedSharding(mesh, P()), NamedSharding(mesh, spec_in))
    return jax.jit(program, in_shardings=in_shardings, out_shardings=out_shardings)
