"""Regular-expression abstract syntax and a POSIX-ish string parser.

Supported syntax (paper Sect. 2.1 + App. A):
  - terminals: any byte; ``\\x`` escapes force terminal-hood of metacharacters
  - ``.`` wildcard (any byte except newline)
  - ``[...]`` / ``[^...]`` character sets with ranges (``a-z``)
  - concatenation (juxtaposition), union ``|``
  - iterators ``*`` (star), ``+`` (cross), ``?`` (optional)
  - bounded repetition ``{h}``, ``{h,k}``, ``{h,}``
  - grouping parentheses ``( )`` — *extra parentheses* in the paper's sense: they are
    numbered and appear in the LSTs, enabling group-match extraction (App. A).
  - ``()`` or a bare reference to the empty string via ``\\e`` produce an Eps leaf.

The AST is deliberately tiny; everything downstream (numbering, segments, automata)
consumes it.  ``Alt``/``Cat`` are n-ary, matching the paper's n-ary union/concatenation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


class RegexSyntaxError(ValueError):
    pass


# --------------------------------------------------------------------------- AST


@dataclass(frozen=True)
class Node:
    pass


@dataclass(frozen=True)
class Lit(Node):
    """A single terminal character (stored as an int byte / code point)."""

    char: int

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Lit({chr(self.char)!r})"


@dataclass(frozen=True)
class CharClass(Node):
    """A set of terminals: sorted tuple of inclusive (lo, hi) ranges.

    ``negated`` is resolved at construction time against the byte alphabet, so the
    stored ranges are always the *positive* member set.
    """

    ranges: Tuple[Tuple[int, int], ...]

    def members(self, alphabet_size: int = 256):
        for lo, hi in self.ranges:
            for c in range(lo, min(hi, alphabet_size - 1) + 1):
                yield c

    def contains(self, c: int) -> bool:
        return any(lo <= c <= hi for lo, hi in self.ranges)


@dataclass(frozen=True)
class Eps(Node):
    """The empty-string leaf (explicit epsilon in the RE, App. A)."""


@dataclass(frozen=True)
class Cat(Node):
    items: Tuple[Node, ...]


@dataclass(frozen=True)
class Alt(Node):
    items: Tuple[Node, ...]


@dataclass(frozen=True)
class Star(Node):
    item: Node


@dataclass(frozen=True)
class Plus(Node):
    item: Node


@dataclass(frozen=True)
class Opt(Node):
    item: Node


@dataclass(frozen=True)
class Repeat(Node):
    """Bounded repetition e{lo,hi}; hi=None means unbounded (e{lo,})."""

    item: Node
    lo: int
    hi: int | None


@dataclass(frozen=True)
class Group(Node):
    """An explicit user parenthesis pair — an *extra parenthesis* (App. A).

    It owns a paren number of its own so matches of the group can be extracted
    from the SLPF (``getMatches``).
    """

    item: Node


WILDCARD_RANGES: Tuple[Tuple[int, int], ...] = ((0, 9), (11, 255))  # '.' = not \n


def char_class(ranges, negated: bool = False, alphabet_size: int = 256) -> CharClass:
    """Normalize ranges (merge overlaps); resolve negation against the byte space."""
    rs = sorted((int(lo), int(hi)) for lo, hi in ranges)
    merged: list[list[int]] = []
    for lo, hi in rs:
        if merged and lo <= merged[-1][1] + 1:
            merged[-1][1] = max(merged[-1][1], hi)
        else:
            merged.append([lo, hi])
    if negated:
        out, prev = [], 0
        for lo, hi in merged:
            if lo > prev:
                out.append((prev, lo - 1))
            prev = max(prev, hi + 1)
        if prev <= alphabet_size - 1:
            out.append((prev, alphabet_size - 1))
        merged = [list(t) for t in out]
    return CharClass(tuple((lo, hi) for lo, hi in merged))


# ------------------------------------------------------------------ string parser


_SPECIAL = set("()[]{}|*+?.\\")


class _Parser:
    def __init__(self, src: str):
        self.src = src
        self.pos = 0

    def error(self, msg: str) -> RegexSyntaxError:
        return RegexSyntaxError(f"{msg} at position {self.pos} in {self.src!r}")

    def peek(self) -> str | None:
        return self.src[self.pos] if self.pos < len(self.src) else None

    def next(self) -> str:
        c = self.src[self.pos]
        self.pos += 1
        return c

    # alternation := concat ('|' concat)*
    def parse_alt(self) -> Node:
        items = [self.parse_cat()]
        while self.peek() == "|":
            self.next()
            items.append(self.parse_cat())
        if len(items) == 1:
            return items[0]
        return Alt(tuple(items))

    # concat := repeat*
    def parse_cat(self) -> Node:
        items = []
        while True:
            c = self.peek()
            if c is None or c in "|)":
                break
            items.append(self.parse_repeat())
        if not items:
            return Eps()
        if len(items) == 1:
            return items[0]
        return Cat(tuple(items))

    # repeat := atom ('*' | '+' | '?' | '{h}' | '{h,}' | '{h,k}')*
    def parse_repeat(self) -> Node:
        node = self.parse_atom()
        while True:
            c = self.peek()
            if c == "*":
                self.next()
                node = Star(node)
            elif c == "+":
                self.next()
                node = Plus(node)
            elif c == "?":
                self.next()
                node = Opt(node)
            elif c == "{":
                self.next()
                node = self._parse_bound(node)
            else:
                return node

    def _parse_bound(self, node: Node) -> Node:
        start = self.pos
        digits = ""
        while self.peek() is not None and self.peek().isdigit():
            digits += self.next()
        if not digits:
            raise self.error("expected digit in bounded repetition")
        lo = int(digits)
        hi: int | None = lo
        if self.peek() == ",":
            self.next()
            digits = ""
            while self.peek() is not None and self.peek().isdigit():
                digits += self.next()
            hi = int(digits) if digits else None
        if self.peek() != "}":
            self.pos = start
            raise self.error("unterminated bounded repetition")
        self.next()
        if hi is not None and hi < lo:
            raise self.error(f"bad repetition bounds {{{lo},{hi}}}")
        return Repeat(node, lo, hi)

    def parse_atom(self) -> Node:
        c = self.peek()
        if c is None:
            raise self.error("unexpected end of pattern")
        if c == "(":
            self.next()
            inner = self.parse_alt()
            if self.peek() != ")":
                raise self.error("unbalanced parenthesis")
            self.next()
            return Group(inner)
        if c == "[":
            return self._parse_class()
        if c == ".":
            self.next()
            return CharClass(WILDCARD_RANGES)
        if c == "\\":
            self.next()
            e = self.peek()
            if e is None:
                raise self.error("dangling escape")
            self.next()
            table = {"n": 10, "t": 9, "r": 13, "0": 0, "e": None}
            if e == "e":
                return Eps()
            if e in table:
                return Lit(table[e])
            if e == "d":
                return char_class([(48, 57)])
            if e == "w":
                return char_class([(48, 57), (65, 90), (97, 122), (95, 95)])
            if e == "s":
                return char_class([(9, 13), (32, 32)])
            return Lit(ord(e))
        if c in "|)*+?{}":
            raise self.error(f"unexpected metacharacter {c!r}")
        self.next()
        return Lit(ord(c))

    def _parse_class(self) -> Node:
        assert self.next() == "["
        negated = False
        if self.peek() == "^":
            negated = True
            self.next()
        ranges: list[tuple[int, int]] = []
        first = True
        while True:
            c = self.peek()
            if c is None:
                raise self.error("unterminated character class")
            if c == "]" and not first:
                self.next()
                break
            first = False
            lo = self._class_char()
            if self.peek() == "-" and self.pos + 1 < len(self.src) and self.src[self.pos + 1] != "]":
                self.next()
                hi = self._class_char()
                if hi < lo:
                    raise self.error("reversed range in character class")
                ranges.append((lo, hi))
            else:
                ranges.append((lo, lo))
        if not ranges:
            raise self.error("empty character class")
        return char_class(ranges, negated=negated)

    def _class_char(self) -> int:
        c = self.next()
        if c == "\\":
            e = self.next()
            table = {"n": 10, "t": 9, "r": 13, "0": 0}
            return table.get(e, ord(e))
        return ord(c)


def parse_regex(pattern: str) -> Node:
    """Parse an RE string into the AST."""
    p = _Parser(pattern)
    node = p.parse_alt()
    if p.pos != len(pattern):
        raise p.error("trailing input")
    return node


# ------------------------------------------------------------------- utilities


def nullable(node: Node) -> bool:
    """Does the RE generate the empty string?"""
    if isinstance(node, (Eps,)):
        return True
    if isinstance(node, (Lit, CharClass)):
        return False
    if isinstance(node, Cat):
        return all(nullable(i) for i in node.items)
    if isinstance(node, Alt):
        return any(nullable(i) for i in node.items)
    if isinstance(node, (Star, Opt)):
        return True
    if isinstance(node, Plus):
        return nullable(node.item)
    if isinstance(node, Repeat):
        return node.lo == 0 or nullable(node.item)
    if isinstance(node, Group):
        return nullable(node.item)
    raise TypeError(node)


def infinitely_ambiguous(node: Node) -> bool:
    """True iff some iterator (star/cross/unbounded repeat) has a nullable body.

    This is exactly the paper's characterization (footnote 3): infinite ambiguity
    stems from an iterator with a nullable argument.
    """
    if isinstance(node, (Lit, CharClass, Eps)):
        return False
    if isinstance(node, (Cat, Alt)):
        return any(infinitely_ambiguous(i) for i in node.items)
    if isinstance(node, (Star, Plus)):
        return nullable(node.item) or infinitely_ambiguous(node.item)
    if isinstance(node, Repeat):
        if node.hi is None and nullable(node.item):
            return True
        return infinitely_ambiguous(node.item)
    if isinstance(node, (Opt, Group)):
        return infinitely_ambiguous(node.item)
    raise TypeError(node)


def node_size(node: Node) -> int:
    """Paper's ||e||: count of terminals and operators (metasymbols).

    Each leaf counts 1; each operator node counts 1 (n-ary operators count once,
    matching Ex. 5 where a ternary concatenation is a single numbered operator).
    Groups (extra parens) count 1 as they are numbered.  Bounded repetition
    counts its copy-expanded body (Ex. 5: the symbols "repeated k times with
    progressive numbering" each count).
    """
    if isinstance(node, (Lit, CharClass, Eps)):
        return 1
    if isinstance(node, (Cat, Alt)):
        return 1 + sum(node_size(i) for i in node.items)
    if isinstance(node, Repeat):
        copies = node.hi if node.hi is not None else node.lo + 1
        return 1 + max(copies, 1) * node_size(node.item)
    if isinstance(node, (Star, Plus, Opt, Group)):
        return 1 + node_size(node.item)
    raise TypeError(node)
