"""Streaming incremental parse: a persistent chunk-product prefix cache.

The batch engine (``core/engine.py``) re-pays the full reach pass over the
whole text for every parse.  But the paper derives *all* cross-chunk
structure from the per-chunk summaries ``P_i`` (Eq. 6) and the log-depth
join (Eq. 7) — and those summaries form a monoid that composes
incrementally (the Simultaneous-Finite-Automata view, PAPERS.md):

    P(prefix · piece) = P(piece) ⊗ P(prefix)

so appending text only requires the *new* piece's reach product plus a
re-join over the cached summaries.  ``StreamingParser`` keeps exactly that
state between calls, built on the engine's separately-jitted phase programs
(``ParserEngine.phases``):

  sealed chunks   immutable prefix chunks with their cached products P_i —
                  the persistent prefix cache; never recomputed by append.
                  Products are the backend's opaque representation (the
                  ``core/backend.py`` contract), so cache residency follows
                  the backend: packed words cut the bytes 32× vs f32, and
                  the sparse backend's (S, 1+W) feasible-start rows shrink
                  each entry to the automaton's speculation width — the
                  ``cache_nbytes`` accounting and eviction budgets see the
                  reduction automatically (``size · itemsize``).
  mutable tail    the unsealed suffix; its running product is *extended*
                  (one ``compose`` per appended piece), never re-folded.
  join cache      forward/backward entries over [sealed…, tail] from
                  ``core/scan.py``'s ``exclusive_entries`` — O(c) product
                  compositions per refresh, c = O(log n) chunks.

Geometric chunk-sealing: the tail seals when it reaches ``next_seal_len``,
which then doubles — so a prefix of length n holds O(log n) sealed chunks,
every sealed length is first_seal_len·2^i, and every device shape (reach
chunk length, product-stack height, build chunk length) lands in a
power-of-two bucket.  The compiled program set stays bounded exactly like
``ParserEngine.bucket_shape``'s buckets: appending never re-jits.

The product stack fed to the join is padded with identity products to the
next power of two **plus at least one identity** — identities are no-ops
for both scan directions, and the guaranteed pad slot makes the forward
state *after* the last real chunk available as ``Jf[c_real]`` (the
streaming acceptance state) without an extra inclusive scan.

``current_slpf()`` materializes the full clean SLPF of the prefix: one
join over the cached products plus build&merge per chunk — no reach work
for sealed chunks.  Output is bit-identical to a cold ``ParserEngine.parse``
of the same prefix (the clean SLPF is unique), validated against
``core/reference.py`` in tests.

``snapshot()``/``restore()`` capture/reinstate the whole stream state in
O(1) device work (products are immutable jax arrays; only class buffers are
copied).  ``drop_cache()`` releases the device arrays (serving-layer
eviction) and ``drop_sealed_product(i)`` releases a single chunk's product
(the serving layer's cost-aware partial eviction); classes are retained
host-side and the missing products rebuild transparently on the next touch.

On a mesh engine (``ParserEngine(mesh=...)``) the join over the cached
summaries routes through ``core/distributed.py``: the sealed-product stack
is exactly the distributed runtime's all-gather payload, so it lives sharded
over the chunk axes and one collective feeds the replicated join — sharded
streaming with no streaming-specific distribution code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .backend import ParserBackend
from .engine import _next_pow2, _resolve_engine
from .matrices import unpack_bits
from .slpf import SLPF


@dataclass(frozen=True)
class StreamSnapshot:
    """Immutable capture of a stream's full state.

    Products are jax arrays (immutable — shared by reference); class buffers
    are copied numpy arrays.  A snapshot of an evicted (cold) parser carries
    ``sealed_products=None`` — restoring it reinstates the cold state and the
    cache rebuilds on the next touch, so ``snapshot`` is O(1) device work in
    every state.  ``restore`` accepts snapshots across ``StreamingParser``
    instances that share an engine.
    """

    sealed_classes: Tuple[np.ndarray, ...]
    sealed_products: Optional[Tuple[jnp.ndarray, ...]]
    tail_classes: np.ndarray
    tail_product: Optional[jnp.ndarray]
    next_seal_len: int


class StreamingParser:
    """Incremental parser over a persistent chunk-product prefix cache."""

    def __init__(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        first_seal_len: int = 8,
        max_seal_len: Optional[int] = None,
        mesh=None,
        mesh_rules=None,
    ):
        self.engine = _resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
        self.first_seal_len = _next_pow2(max(1, first_seal_len))
        if max_seal_len is None:
            self.max_seal_len = None
        else:
            # floor to a power of two: the cap is a promise, never exceeded
            floored = 1 << (max(1, max_seal_len).bit_length() - 1)
            self.max_seal_len = max(self.first_seal_len, floored)
        t = self.engine.tables
        # the monoid identity in the engine backend's product representation
        # (f32 eye / packed-word eye) — tail init and join-stack pad slots
        self._eye = self.engine.backend.identity_product(t.ell_pad, dtype=t.N.dtype)

        # prefix cache -----------------------------------------------------
        self._sealed_classes: List[np.ndarray] = []
        self._sealed_products: List[jnp.ndarray] = []   # dropped when cold
        self._tail_pieces: List[np.ndarray] = []
        self._tail_len = 0
        self._tail_product: jnp.ndarray = self._eye
        self._next_seal = self.first_seal_len
        self._cold = False            # True ⇔ products evicted, classes kept
        # join cache over [sealed…, tail]: (Jf, Jb, packed col0, c_real)
        self._join: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]] = None

        # counters ---------------------------------------------------------
        self.appended_bytes = 0
        self.rebuilds = 0             # cold-cache reconstructions paid

    # ------------------------------------------------------------- geometry

    @property
    def n(self) -> int:
        """Current prefix length (characters appended so far)."""
        return sum(len(s) for s in self._sealed_classes) + self._tail_len

    @property
    def n_sealed_chunks(self) -> int:
        return len(self._sealed_classes)

    def tail_room(self) -> int:
        """Characters the tail accepts before the next seal boundary."""
        return self._next_seal - self._tail_len

    @property
    def compile_count(self) -> int:
        return self.engine.compile_count

    @property
    def cache_nbytes(self) -> int:
        """Device bytes held by the prefix cache (products + join entries).

        An empty tail holds the shared identity matrix, not cache — counting
        it would report phantom bytes eviction cannot free."""
        if self._cold:
            return 0
        total = sum(
            int(p.size) * p.dtype.itemsize
            for p in self._sealed_products
            if p is not None
        )
        if self._tail_len:
            total += int(self._tail_product.size) * self._tail_product.dtype.itemsize
        if self._join is not None:
            Jf, Jb, col0p, _ = self._join
            total += sum(int(a.size) * a.dtype.itemsize for a in (Jf, Jb, col0p))
        return total

    # --------------------------------------------------------------- append

    def append(self, text) -> int:
        """Extend the stream; returns the number of characters appended.

        Incremental cost: one bucketed reach over each appended piece (a
        piece never crosses a seal boundary — large appends split into
        O(log) geometric pieces), one ``compose`` per piece to extend the
        tail product, and one exclusive join over the O(log n) cached
        summaries — eager on purpose, so ``accepted`` is O(1) after every
        append (the batched service path goes through ``absorb_product``
        instead, which defers the join to first query).  No sealed product
        is ever recomputed.
        """
        classes = self.engine.classes_of_text(text)
        if len(classes) == 0:
            return 0
        self._ensure_cache()
        i = 0
        while i < len(classes):
            piece = classes[i : i + self.tail_room()]
            i += len(piece)
            self.absorb_product(piece, self._reach_piece(piece))
        self._refresh_join()
        return len(classes)

    def _reach_piece(self, piece: np.ndarray) -> jnp.ndarray:
        """Reach product of one piece via the bucketed phase program."""
        k = self._bucket_len(len(piece))
        chunk = self.engine._pad_to(piece, 1, k)
        return self.engine.phases.reach(self.engine.tables.N, jnp.asarray(chunk))[0]

    def _bucket_len(self, m: int) -> int:
        return _next_pow2(max(self.engine.min_chunk_len, m))

    def absorb_product(self, piece: np.ndarray, product: jnp.ndarray) -> None:
        """Fold one already-reached piece into the tail (service fast path).

        ``piece`` must fit inside the current seal boundary (``tail_room``);
        ``product`` is its reach product *in the engine backend's product
        representation* (f32 matrix / packed words — opaque per the
        ``core/backend.py`` contract) — from ``_reach_piece`` or from a
        batched reach the serving layer ran across sessions.
        """
        if len(piece) > self.tail_room():
            from ..errors import BudgetExceeded

            raise BudgetExceeded(
                f"piece of {len(piece)} chars crosses the seal boundary "
                f"(tail_room={self.tail_room()}); split it first",
                budget=self.tail_room(),
                requested=len(piece),
            )
        self._ensure_cache()
        self._tail_product = self.engine.phases.compose(product, self._tail_product)
        self._tail_pieces.append(np.asarray(piece, dtype=np.int32))
        self._tail_len += len(piece)
        self.appended_bytes += len(piece)
        self._join = None
        if self._tail_len == self._next_seal:
            self._seal()

    def _seal(self) -> None:
        """Seal the full tail as an immutable chunk with its cached product."""
        self._sealed_classes.append(np.concatenate(self._tail_pieces))
        self._sealed_products.append(self._tail_product)
        self._tail_pieces = []
        self._tail_len = 0
        self._tail_product = self._eye
        grown = self._next_seal * 2
        if self.max_seal_len is not None:
            grown = min(grown, self.max_seal_len)
        self._next_seal = grown

    # ----------------------------------------------------------------- join

    def _chunk_classes(self) -> List[np.ndarray]:
        chunks = list(self._sealed_classes)
        if self._tail_len:
            chunks.append(np.concatenate(self._tail_pieces))
        return chunks

    def _stack_products(self) -> Tuple[jnp.ndarray, int]:
        """Cached products stacked (c_pad, …) in the backend's product
        representation; pad slots are identity.

        c_pad = next_pow2(c_real + 1): at least one identity pad, so the
        exclusive forward entries extend one slot past the real chunks and
        ``Jf[c_real]`` is the forward state after the whole prefix.
        """
        products = list(self._sealed_products)
        if self._tail_len:
            products.append(self._tail_product)
        c_real = len(products)
        c_pad = _next_pow2(c_real + 1)
        products.extend([self._eye] * (c_pad - c_real))
        return jnp.stack(products), c_real

    def _refresh_join(self) -> None:
        if self.n == 0:
            self._join = None
            return
        t = self.engine.tables
        P, c_real = self._stack_products()
        dist = self.engine.dist
        if dist is not None:
            # Sharded streaming: the sealed-product stack IS the distributed
            # runtime's all-gather payload — shard it over the chunk axes and
            # run the replicated join there (core/distributed.py contract).
            Jf, Jb, col0p = dist.join_products(P)
        else:
            Jf, Jb, col0p = self.engine.phases.join(P, t.I, t.F)
        self._join = (Jf, Jb, col0p, c_real)

    def _joined(self):
        self._ensure_cache()
        if self._join is None:
            self._refresh_join()
        return self._join

    @property
    def accepted(self) -> bool:
        """Is the current prefix a valid text?  O(1) from the join cache."""
        t = self.engine.tables
        if self.n == 0:
            return bool(np.any(np.asarray(t.I) * np.asarray(t.F)))
        Jf, _, _, c_real = self._joined()
        final_fwd = np.asarray(Jf[c_real])   # forward state after the prefix
        return bool(np.any(final_fwd * np.asarray(t.F)))

    # ----------------------------------------------------------------- slpf

    def current_slpf(self) -> SLPF:
        """Clean SLPF of the whole current prefix.

        Join over the cached products + one build&merge per chunk (bucketed
        shapes) — zero reach work for sealed chunks.  Bit-identical to a
        cold ``ParserEngine.parse`` of the same prefix.
        """
        with self.engine.obs.span(
            "stream.query", n_chars=self.n, n_sealed=self.n_sealed_chunks
        ):
            return self._current_slpf()

    def _current_slpf(self) -> SLPF:
        eng = self.engine
        t = eng.tables
        chunks = self._chunk_classes()
        classes = (
            np.concatenate(chunks) if chunks else np.zeros(0, dtype=np.int32)
        )
        if len(classes) == 0:
            col = (np.asarray(t.I, dtype=bool) & np.asarray(t.F, dtype=bool))
            return SLPF(table=eng.table, columns=col[None, : t.ell], classes=classes)

        Jf, Jb, col0p, c_real = self._joined()
        assert c_real == len(chunks)
        rows = [np.asarray(col0p)[None]]
        for i, ch in enumerate(chunks):
            k = self._bucket_len(len(ch))
            padded = jnp.asarray(eng._pad_to(ch, 1, k))
            Mp = eng.phases.build_merge(t.N, padded, Jf[i][None], Jb[i][None])
            rows.append(np.asarray(Mp)[0, : len(ch)])
        packed = np.concatenate(rows, axis=0)
        columns = unpack_bits(packed, t.ell, axis=-1)
        return SLPF(table=eng.table, columns=columns, classes=classes)

    def count_trees(self) -> int:
        return self.current_slpf().count_trees()

    # ----------------------------------------------------- snapshot / evict

    def snapshot(self) -> StreamSnapshot:
        """O(1)-device capture of the stream state (products shared by ref).

        A cold (evicted) parser snapshots without rebuilding: the snapshot
        records the cold state and restore defers the rebuild to next touch.
        """
        tail = (
            np.concatenate(self._tail_pieces)
            if self._tail_len
            else np.zeros(0, dtype=np.int32)
        )
        return StreamSnapshot(
            sealed_classes=tuple(s.copy() for s in self._sealed_classes),
            sealed_products=None if self._cold else tuple(self._sealed_products),
            tail_classes=tail,
            tail_product=None if self._cold else self._tail_product,
            next_seal_len=self._next_seal,
        )

    def restore(self, snap: StreamSnapshot) -> None:
        """Reinstate a snapshot taken on this engine's table set."""
        self._sealed_classes = [s.copy() for s in snap.sealed_classes]
        self._tail_pieces = (
            [snap.tail_classes.copy()] if len(snap.tail_classes) else []
        )
        self._tail_len = int(len(snap.tail_classes))
        self._next_seal = int(snap.next_seal_len)
        self._join = None
        if snap.sealed_products is None:       # cold snapshot
            self._sealed_products = []
            self._tail_product = self._eye
            self._cold = True
        else:
            self._sealed_products = list(snap.sealed_products)
            self._tail_product = snap.tail_product
            self._cold = False

    def drop_cache(self) -> None:
        """Release all device product arrays (serving-layer eviction).

        Classes stay host-side; the next ``append``/``current_slpf``
        transparently re-reaches the sealed chunks (counted in
        ``rebuilds``).  Results are unaffected — only the work is.
        """
        self._sealed_products = []
        self._tail_product = self._eye
        self._join = None
        self._cold = True

    def sealed_cache_entries(self) -> List[Tuple[int, int, int]]:
        """(index, chunk_chars, bytes) of each RESIDENT sealed product — the
        per-product eviction candidates the serving layer ranks (the cost-
        aware policy drops largest chunks first)."""
        if self._cold:
            return []
        return [
            (i, len(self._sealed_classes[i]), int(p.size) * p.dtype.itemsize)
            for i, p in enumerate(self._sealed_products)
            if p is not None
        ]

    def drop_sealed_product(self, i: int) -> int:
        """Release ONE sealed chunk's cached product; returns bytes freed.

        Finer-grained than ``drop_cache``: the join cache and the other
        products stay resident, and only the dropped chunk re-reaches on the
        next rebuild.  No-op (0 bytes) when already cold or dropped.
        """
        if self._cold or self._sealed_products[i] is None:
            return 0
        p = self._sealed_products[i]
        self._sealed_products[i] = None
        return int(p.size) * p.dtype.itemsize

    def _count_rebuild(self) -> None:
        self.rebuilds += 1
        self.engine.obs.metrics.counter("stream_rebuilds_total").inc()

    def _ensure_cache(self) -> None:
        if self._cold:
            self._cold = False
            self._count_rebuild()
            self._sealed_products = [
                self._reach_piece(s) for s in self._sealed_classes
            ]
            self._tail_product = self._eye
            if self._tail_len:
                tail = np.concatenate(self._tail_pieces)
                self._tail_product = self._reach_piece(tail)
            return
        if any(p is None for p in self._sealed_products):
            # partial eviction: re-reach only the dropped chunks
            self._count_rebuild()
            self._sealed_products = [
                p if p is not None else self._reach_piece(s)
                for p, s in zip(self._sealed_products, self._sealed_classes)
            ]
