"""Streaming incremental parse: a balanced monoid tree of chunk products.

The batch engine (``core/engine.py``) re-pays the full reach pass over the
whole text for every parse.  But the paper derives *all* cross-chunk
structure from the per-chunk summaries ``P_i`` (Eq. 6) and the log-depth
join (Eq. 7) — and those summaries form a monoid that composes
incrementally (the Simultaneous-Finite-Automata view, PAPERS.md):

    P(prefix · piece) = P(piece) ⊗ P(prefix)

so appending text only requires the *new* piece's reach product plus a
re-join over the cached summaries, and — because ``compose`` is
associative — *any* re-association of the chunk sequence is equally valid.
``StreamingParser`` exploits both:

  segment tree     sealed chunks live as the leaves of a height-balanced
                   binary tree (an AVL-style rope keyed by character
                   position); every internal node can cache the composed
                   product of its subtree in the backend's opaque
                   representation.  Appends touch only the right spine;
                   ``edit(lo, hi, replacement)`` splices a leaf range and
                   re-composes ONE leaf-to-root path — O(log n) device
                   work — instead of re-joining the whole suffix (the
                   Bille & Gørtz query-interface workload, PAPERS.md).
                   Products are opaque per the ``core/backend.py``
                   contract, so cache residency follows the backend
                   (packed words cut bytes 32×; sparse rows shrink to the
                   speculation width) and the ``cache_nbytes`` accounting
                   sees the reduction automatically.
  mutable tail     the unsealed suffix; its running product is *extended*
                   (one ``compose`` per appended piece), never re-folded.
  join cache       forward/backward entries over [leaves…, tail] from
                   ``core/scan.py``'s ``exclusive_entries`` — O(c) product
                   compositions per refresh, c = number of leaves.

Geometric chunk-sealing: the tail seals when it reaches ``next_seal_len``,
which then doubles (capped at ``max_seal_len``) — so an append-only prefix
of length n holds O(log n) leaves, every sealed length is
first_seal_len·2^i, and every device shape lands in a power-of-two bucket;
appending never re-jits.  Under a ``max_seal_len`` cap the leaf count is
n/cap, and the tree keeps edits at O(cap + log n): an edit re-reaches only
the spliced leaves and re-composes the internal products along the new
spine, so ``accepted`` after an edit costs one tiny 2-product join over
the refreshed root product — never a full O(#leaves) re-join.

The product stack fed to the join is padded with identity products to the
next power of two **plus at least one identity** — identities are no-ops
for both scan directions, and the guaranteed pad slot makes the forward
state *after* the last real chunk available as ``Jf[c_real]`` (the
streaming acceptance state) without an extra inclusive scan.

``current_slpf()`` materializes the full clean SLPF of the prefix: one
join over the leaf products plus build&merge per leaf — no reach work for
sealed chunks.  Output is bit-identical to a cold ``ParserEngine.parse``
of the same prefix (the clean SLPF is unique) — including after any
sequence of edits — validated against ``core/reference.py`` in tests.

``snapshot()``/``restore()`` capture/reinstate the whole stream state in
O(1) device work (products are immutable jax arrays; only class buffers
are copied).  ``restore`` clamps the snapshot's seal boundary to this
parser's ``max_seal_len`` (the cap is a promise, never exceeded — a
snapshot from a larger/uncapped config reseals its oversized tail into
cap-sized leaves).  ``drop_cache()`` releases the device arrays
(serving-layer eviction) and ``drop_sealed_product(key)`` releases a
single tree node's product — internal nodes are first-class eviction
candidates: they cover the most characters and rebuild with one
``compose``.  Dropping a product also releases the join entries (they are
only reachable through the same budget, so keeping them would let a
session sit over budget with nothing left to evict); classes are retained
host-side and missing products rebuild transparently on the next touch,
counted per re-reached chunk in ``rebuilds``.

On a mesh engine (``ParserEngine(mesh=...)``) the join over the cached
summaries routes through ``core/distributed.py``: the tree's flattened
leaf frontier — the in-order leaf products — is exactly the distributed
runtime's all-gather payload, so it lives sharded over the chunk axes and
one collective feeds the replicated join — sharded streaming (and sharded
post-edit queries) with no streaming-specific distribution code.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .backend import ParserBackend
from .engine import _next_pow2, _resolve_engine
from .matrices import unpack_bits
from .slpf import SLPF

# ---------------------------------------------------------------------------
# The product segment tree: an AVL-style rope whose leaves are sealed chunks
# (host-side class buffer + cached device product) and whose internal nodes
# lazily cache the composed product of their subtree.  Nodes are immutable
# in *structure* (concat/split share untouched subtrees, so a snapshot's
# leaf view stays valid); the only mutation is the ``product`` slot, which
# is a memo: None ⇔ evicted / not yet composed.
# ---------------------------------------------------------------------------

_uid = itertools.count()


class _Node:
    __slots__ = ("uid", "classes", "left", "right", "product",
                 "n_chars", "n_leaves", "height")


def _leaf(classes: np.ndarray, product) -> _Node:
    nd = _Node()
    nd.uid = next(_uid)
    nd.classes = np.asarray(classes, dtype=np.int32)
    nd.left = nd.right = None
    nd.product = product
    nd.n_chars = int(len(classes))
    nd.n_leaves = 1
    nd.height = 0
    return nd


def _branch(l: _Node, r: _Node) -> _Node:
    nd = _Node()
    nd.uid = next(_uid)
    nd.classes = None
    nd.left, nd.right = l, r
    nd.product = None          # composed lazily (memoized) on first demand
    nd.n_chars = l.n_chars + r.n_chars
    nd.n_leaves = l.n_leaves + r.n_leaves
    nd.height = 1 + max(l.height, r.height)
    return nd


def _balanced(l: _Node, r: _Node) -> _Node:
    """Join two trees whose heights differ by at most 2 (one rotation)."""
    if l.height > r.height + 1:
        if l.left.height >= l.right.height:
            return _branch(l.left, _branch(l.right, r))
        lr = l.right
        return _branch(_branch(l.left, lr.left), _branch(lr.right, r))
    if r.height > l.height + 1:
        if r.right.height >= r.left.height:
            return _branch(_branch(l, r.left), r.right)
        rl = r.left
        return _branch(_branch(l, rl.left), _branch(rl.right, r.right))
    return _branch(l, r)


def _concat(l: Optional[_Node], r: Optional[_Node]) -> Optional[_Node]:
    """Height-balanced concatenation; shares every untouched subtree (and
    its cached product) between the input and output trees."""
    if l is None:
        return r
    if r is None:
        return l
    if l.height > r.height + 1:
        return _balanced(l.left, _concat(l.right, r))
    if r.height > l.height + 1:
        return _balanced(_concat(l, r.left), r.right)
    return _branch(l, r)


def _split_leaves(node: Optional[_Node], k: int):
    """Split ``node`` into (tree of the first ``k`` leaves, tree of the rest)."""
    if node is None or k <= 0:
        return None, node
    if k >= node.n_leaves:
        return node, None
    if k <= node.left.n_leaves:
        a, b = _split_leaves(node.left, k)
        return a, _concat(b, node.right)
    a, b = _split_leaves(node.right, k - node.left.n_leaves)
    return _concat(node.left, a), b


def _build(leaves: List[_Node]) -> Optional[_Node]:
    """Perfectly balanced tree over a leaf list."""
    if not leaves:
        return None

    def rec(lo: int, hi: int) -> _Node:
        if hi - lo == 1:
            return leaves[lo]
        mid = (lo + hi) // 2
        return _branch(rec(lo, mid), rec(mid, hi))

    return rec(0, len(leaves))


def _iter_leaves(node: Optional[_Node]) -> Iterator[_Node]:
    """Leaves left-to-right (the flattened chunk frontier)."""
    if node is None:
        return
    stack = [node]
    while stack:
        nd = stack.pop()
        if nd.classes is not None:
            yield nd
        else:
            stack.append(nd.right)
            stack.append(nd.left)


def _iter_nodes(node: Optional[_Node]) -> Iterator[_Node]:
    """Every node of the tree (order unspecified)."""
    if node is None:
        return
    stack = [node]
    while stack:
        nd = stack.pop()
        yield nd
        if nd.classes is None:
            stack.append(nd.left)
            stack.append(nd.right)


def _locate(node: _Node, pos: int) -> Tuple[int, int, _Node]:
    """(leaf index, leaf start char, leaf) of the leaf containing ``pos``."""
    idx = 0
    start = 0
    while node.classes is None:
        if pos < node.left.n_chars:
            node = node.left
        else:
            pos -= node.left.n_chars
            idx += node.left.n_leaves
            start += node.left.n_chars
            node = node.right
    return idx, start, node


@dataclass(frozen=True)
class StreamSnapshot:
    """Immutable capture of a stream's full state.

    Products are jax arrays (immutable — shared by reference); class buffers
    are copied numpy arrays.  A snapshot of an evicted (cold) parser carries
    ``sealed_products=None`` — restoring it reinstates the cold state and the
    cache rebuilds on the next touch, so ``snapshot`` is O(1) device work in
    every state.  A warm snapshot under *partial* eviction preserves the
    ``None`` holes per chunk.  ``restore`` accepts snapshots across
    ``StreamingParser`` instances that share an engine — including across
    differing seal configs: the boundary clamps to the restoring parser's
    ``max_seal_len``.
    """

    sealed_classes: Tuple[np.ndarray, ...]
    sealed_products: Optional[Tuple[Optional[jnp.ndarray], ...]]
    tail_classes: np.ndarray
    tail_product: Optional[jnp.ndarray]
    next_seal_len: int


class StreamingParser:
    """Incremental parser over a balanced product segment tree."""

    def __init__(
        self,
        matrices_or_engine,
        *,
        backend: Union[str, ParserBackend, None] = None,
        first_seal_len: int = 8,
        max_seal_len: Optional[int] = None,
        mesh=None,
        mesh_rules=None,
    ):
        self.engine = _resolve_engine(matrices_or_engine, backend, mesh, mesh_rules)
        self.first_seal_len = _next_pow2(max(1, first_seal_len))
        if max_seal_len is None:
            self.max_seal_len = None
        else:
            # floor to a power of two: the cap is a promise, never exceeded
            floored = 1 << (max(1, max_seal_len).bit_length() - 1)
            self.max_seal_len = max(self.first_seal_len, floored)
        t = self.engine.tables
        # the monoid identity in the engine backend's product representation
        # (f32 eye / packed-word eye) — tail init and join-stack pad slots
        self._eye = self.engine.backend.identity_product(t.ell_pad, dtype=t.N.dtype)

        # prefix cache -----------------------------------------------------
        self._root: Optional[_Node] = None     # sealed chunks, leaf-ordered
        self._tail_pieces: List[np.ndarray] = []
        self._tail_len = 0
        self._tail_product: jnp.ndarray = self._eye
        self._next_seal = self.first_seal_len
        self._cold = False            # True ⇔ products evicted, classes kept
        # join cache over [leaves…, tail]: (Jf, Jb, packed col0, c_real)
        self._join: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]] = None
        # uid → node map rebuilt by sealed_cache_entries (eviction keys)
        self._evict_index: Dict[int, _Node] = {}

        # counters ---------------------------------------------------------
        self.appended_bytes = 0
        self.rebuilds = 0             # evicted chunks re-reached (per chunk)
        self.edits = 0
        self._recomposed = 0          # internal-node products composed

    # ------------------------------------------------------------- geometry

    @property
    def n(self) -> int:
        """Current prefix length (characters appended so far)."""
        return (self._root.n_chars if self._root is not None else 0) + self._tail_len

    @property
    def n_sealed_chunks(self) -> int:
        return self._root.n_leaves if self._root is not None else 0

    def tail_room(self) -> int:
        """Characters the tail accepts before the next seal boundary."""
        return self._next_seal - self._tail_len

    @property
    def compile_count(self) -> int:
        return self.engine.compile_count

    @property
    def tree_height(self) -> int:
        """Height of the product segment tree (0 for ≤1 sealed chunk)."""
        return self._root.height if self._root is not None else 0

    # back-compat views of the leaf frontier (tests and tooling peek here)
    @property
    def _sealed_classes(self) -> List[np.ndarray]:
        return [lf.classes for lf in _iter_leaves(self._root)]

    @property
    def _sealed_products(self) -> List[Optional[jnp.ndarray]]:
        if self._cold:
            return []
        return [lf.product for lf in _iter_leaves(self._root)]

    @property
    def cache_nbytes(self) -> int:
        """Device bytes held by the prefix cache: every resident node
        product (leaves AND internal memos) + tail product + join entries.

        An empty tail holds the shared identity matrix, not cache — counting
        it would report phantom bytes eviction cannot free.  Every byte
        counted here is releasable through ``drop_sealed_product`` /
        ``drop_cache`` (the join entries ride along with the first product
        drop), so a bytes-budget eviction loop always converges."""
        if self._cold:
            return 0
        total = 0
        for nd in _iter_nodes(self._root):
            if nd.product is not None:
                total += int(nd.product.size) * nd.product.dtype.itemsize
        if self._tail_len:
            total += int(self._tail_product.size) * self._tail_product.dtype.itemsize
        total += self._join_nbytes()
        return total

    def _join_nbytes(self) -> int:
        if self._join is None:
            return 0
        Jf, Jb, col0p, _ = self._join
        return sum(int(a.size) * a.dtype.itemsize for a in (Jf, Jb, col0p))

    # --------------------------------------------------------------- append

    def append(self, text) -> int:
        """Extend the stream; returns the number of characters appended.

        Incremental cost: one bucketed reach over each appended piece (a
        piece never crosses a seal boundary — large appends split into
        O(log) geometric pieces), one ``compose`` per piece to extend the
        tail product, and one exclusive join over the cached summaries —
        eager on purpose, so ``accepted`` is O(1) after every append (the
        batched service path goes through ``absorb_product`` instead, which
        defers the join to first query).  No sealed product is ever
        recomputed.
        """
        classes = self.engine.classes_of_text(text)
        if len(classes) == 0:
            return 0
        self._ensure_cache()
        i = 0
        while i < len(classes):
            piece = classes[i : i + self.tail_room()]
            i += len(piece)
            self.absorb_product(piece, self._reach_piece(piece))
        self._refresh_join()
        return len(classes)

    def _reach_piece(self, piece: np.ndarray) -> jnp.ndarray:
        """Reach product of one piece via the bucketed phase program."""
        k = self._bucket_len(len(piece))
        chunk = self.engine._pad_to(piece, 1, k)
        return self.engine.phases.reach(self.engine.tables.N, jnp.asarray(chunk))[0]

    def _bucket_len(self, m: int) -> int:
        return _next_pow2(max(self.engine.min_chunk_len, m))

    def absorb_product(self, piece: np.ndarray, product: jnp.ndarray) -> None:
        """Fold one already-reached piece into the tail (service fast path).

        ``piece`` must fit inside the current seal boundary (``tail_room``);
        ``product`` is its reach product *in the engine backend's product
        representation* (f32 matrix / packed words — opaque per the
        ``core/backend.py`` contract) — from ``_reach_piece`` or from a
        batched reach the serving layer ran across sessions.
        """
        if len(piece) > self.tail_room():
            from ..errors import BudgetExceeded

            raise BudgetExceeded(
                f"piece of {len(piece)} chars crosses the seal boundary "
                f"(tail_room={self.tail_room()}); split it first",
                budget=self.tail_room(),
                requested=len(piece),
            )
        self._ensure_cache()
        self._tail_product = self.engine.phases.compose(product, self._tail_product)
        self._tail_pieces.append(np.asarray(piece, dtype=np.int32))
        self._tail_len += len(piece)
        self.appended_bytes += len(piece)
        self._join = None
        if self._tail_len == self._next_seal:
            self._seal()

    def _seal(self) -> None:
        """Seal the full tail as a new rightmost leaf with its product."""
        leaf = _leaf(np.concatenate(self._tail_pieces), self._tail_product)
        self._root = _concat(self._root, leaf)
        self._tail_pieces = []
        self._tail_len = 0
        self._tail_product = self._eye
        grown = self._next_seal * 2
        if self.max_seal_len is not None:
            grown = min(grown, self.max_seal_len)
        self._next_seal = grown

    # ----------------------------------------------------------------- edit

    def edit(self, lo: int, hi: int, replacement) -> int:
        """Splice: replace characters ``[lo, hi)`` with ``replacement``.

        Returns the new prefix length.  Device cost is O(cap + log n): the
        touched leaves re-reach (each at most ``max_seal_len`` chars, or the
        largest covered leaf when uncapped) and the internal products along
        the new leaf-to-root spine re-compose — the untouched subtrees keep
        their cached products by structural sharing.  The result is
        bit-identical to a cold parse of the edited text: the join is
        associative, so re-associating the spliced chunk sequence changes
        no downstream value (SFA view, PAPERS.md).
        """
        repl = self.engine.classes_of_text(replacement)
        lo, hi = int(lo), int(hi)
        if not (0 <= lo <= hi <= self.n):
            raise ValueError(
                f"edit range [{lo}, {hi}) out of bounds for prefix of {self.n}"
            )
        with self.engine.obs.span(
            "stream.edit", lo=lo, hi=hi, repl_chars=int(len(repl)), n_chars=self.n
        ):
            return self._edit(lo, hi, repl)

    def delete(self, lo: int, hi: int) -> int:
        """Remove characters ``[lo, hi)`` — ``edit`` with empty replacement."""
        return self.edit(lo, hi, np.zeros(0, dtype=np.int32))

    def insert(self, pos: int, text) -> int:
        """Insert ``text`` before position ``pos`` — a zero-width ``edit``."""
        return self.edit(pos, pos, text)

    def _edit(self, lo: int, hi: int, repl: np.ndarray) -> int:
        sealed_chars = self._root.n_chars if self._root is not None else 0
        if self._cold:
            # wake without the eager full rebuild: the edit re-reaches only
            # what it touches; untouched evicted products rebuild lazily on
            # the next query.  The tail product must come back NOW only when
            # the edit keeps the tail (otherwise the splice rebuilds it).
            self._cold = False
            if self._tail_len and hi <= sealed_chars and lo < sealed_chars:
                self._tail_product = self._reach_piece(
                    np.concatenate(self._tail_pieces)
                )
                self._count_rebuild()
            elif not self._tail_len:
                self._tail_product = self._eye
        self._join = None

        if lo >= sealed_chars:
            # tail-only splice (covers insert-at-n and the empty stream)
            tail = (
                np.concatenate(self._tail_pieces)
                if self._tail_len
                else np.zeros(0, dtype=np.int32)
            )
            off = lo - sealed_chars
            cut = hi - sealed_chars
            self._rebuild_tail(np.concatenate([tail[:off], repl, tail[cut:]]))
        else:
            a_idx, a_start, _ = _locate(self._root, lo)
            touch_tail = hi > sealed_chars
            if touch_tail:
                b_idx = self._root.n_leaves - 1
            else:
                b_idx, _, _ = _locate(self._root, max(hi - 1, lo))
            left, rest = _split_leaves(self._root, a_idx)
            middle, right = _split_leaves(rest, b_idx - a_idx + 1)
            mid_classes = [lf.classes for lf in _iter_leaves(middle)]
            if touch_tail:
                mid_classes.extend(self._tail_pieces)
                self._tail_pieces = []
                self._tail_len = 0
                self._tail_product = self._eye
            merged = np.concatenate(mid_classes)
            off = lo - a_start
            cut = hi - a_start
            new_middle = np.concatenate([merged[:off], repl, merged[cut:]])

            # leaf cap for the re-sealed splice: the configured cap, else the
            # pow2 bucket of the largest covered leaf (shapes stay bucketed)
            if self.max_seal_len is not None:
                cap = self.max_seal_len
            else:
                biggest = max((len(c) for c in mid_classes), default=1)
                cap = max(self.first_seal_len, _next_pow2(max(1, biggest)))

            new_leaves: List[_Node] = []
            pos = 0
            if touch_tail:
                # full-cap leaves, remainder becomes the new tail
                while len(new_middle) - pos >= cap:
                    piece = new_middle[pos : pos + cap]
                    pos += cap
                    new_leaves.append(_leaf(piece, self._reach_piece(piece)))
                self._root = _concat(left, _build(new_leaves))
                self._next_seal = cap
                self._rebuild_tail(new_middle[pos:])
            else:
                while pos < len(new_middle):
                    piece = new_middle[pos : pos + cap]
                    pos += len(piece)
                    new_leaves.append(_leaf(piece, self._reach_piece(piece)))
                self._root = _concat(_concat(left, _build(new_leaves)), right)

        # refresh the root product now: the spine composes (that IS the
        # O(log n) claim — record its depth) and `accepted` stays O(1)
        depth = 0
        if self._root is not None:
            before = self._recomposed
            self._node_product(self._root)
            depth = self._recomposed - before
        self.edits += 1
        m = self.engine.obs.metrics
        m.counter("stream_edits_total").inc()
        m.histogram("stream_edit_recompose_depth").observe(float(depth))
        return self.n

    def _rebuild_tail(self, classes: np.ndarray) -> None:
        """Re-absorb ``classes`` as the new tail, sealing at boundaries.

        The edit-path twin of the ``append`` loop: same piece splitting,
        same seal geometry — but spliced characters are not *appended*
        traffic, so ``appended_bytes`` stays untouched."""
        self._tail_pieces = []
        self._tail_len = 0
        self._tail_product = self._eye
        classes = np.asarray(classes, dtype=np.int32)
        i = 0
        while i < len(classes):
            piece = classes[i : i + self.tail_room()]
            i += len(piece)
            self._tail_product = self.engine.phases.compose(
                self._reach_piece(piece), self._tail_product
            )
            self._tail_pieces.append(piece)
            self._tail_len += len(piece)
            if self._tail_len == self._next_seal:
                self._seal()

    def _node_product(self, node: _Node) -> jnp.ndarray:
        """Memoized subtree product: compose(right, left) bottoms out at
        leaf products, re-reaching evicted leaves (counted per chunk)."""
        if node.product is None:
            if node.classes is not None:
                node.product = self._reach_piece(node.classes)
                self._count_rebuild()
            else:
                lp = self._node_product(node.left)
                rp = self._node_product(node.right)
                node.product = self.engine.phases.compose(rp, lp)
                self._recomposed += 1
        return node.product

    # ----------------------------------------------------------------- join

    def _chunk_classes(self) -> List[np.ndarray]:
        chunks = [lf.classes for lf in _iter_leaves(self._root)]
        if self._tail_len:
            chunks.append(np.concatenate(self._tail_pieces))
        return chunks

    def _stack_products(self) -> Tuple[jnp.ndarray, int]:
        """The flattened leaf frontier stacked (c_pad, …) in the backend's
        product representation; pad slots are identity.

        c_pad = next_pow2(c_real + 1): at least one identity pad, so the
        exclusive forward entries extend one slot past the real chunks and
        ``Jf[c_real]`` is the forward state after the whole prefix.
        """
        products = [lf.product for lf in _iter_leaves(self._root)]
        if self._tail_len:
            products.append(self._tail_product)
        c_real = len(products)
        c_pad = _next_pow2(c_real + 1)
        products.extend([self._eye] * (c_pad - c_real))
        return jnp.stack(products), c_real

    def _refresh_join(self) -> None:
        if self.n == 0:
            self._join = None
            return
        t = self.engine.tables
        P, c_real = self._stack_products()
        dist = self.engine.dist
        if dist is not None:
            # Sharded streaming: the flattened leaf frontier IS the
            # distributed runtime's all-gather payload — shard it over the
            # chunk axes and run the replicated join there
            # (core/distributed.py contract).
            Jf, Jb, col0p = dist.join_products(P)
        else:
            Jf, Jb, col0p = self.engine.phases.join(P, t.I, t.F)
        self._join = (Jf, Jb, col0p, c_real)

    def _joined(self):
        self._ensure_cache()
        if self._join is None:
            self._refresh_join()
        return self._join

    def _final_forward(self) -> np.ndarray:
        """Forward state after the whole prefix via the ROOT product: one
        memoized leaf-to-root path plus a single 2-product join — O(log n)
        after an edit, never the full O(#chunks) join."""
        if self._cold:
            self._ensure_cache()
        total = None
        if self._root is not None:
            total = self._node_product(self._root)
        if self._tail_len:
            total = (
                self._tail_product
                if total is None
                else self.engine.phases.compose(self._tail_product, total)
            )
        t = self.engine.tables
        # 2-slot stack [total, eye]: exclusive forward entries give Jf[1] =
        # I carried through `total` (2 is already a pow2, join contract holds)
        Jf, _, _ = self.engine.phases.join(
            jnp.stack([total, self._eye]), t.I, t.F
        )
        return np.asarray(Jf[1])

    @property
    def accepted(self) -> bool:
        """Is the current prefix a valid text?  O(1) from the join cache
        when present, else one root-product path (O(log n) after edits)."""
        t = self.engine.tables
        if self.n == 0:
            return bool(np.any(np.asarray(t.I) * np.asarray(t.F)))
        if self._join is not None:
            Jf, _, _, c_real = self._join
            final_fwd = np.asarray(Jf[c_real])
        else:
            final_fwd = self._final_forward()
        return bool(np.any(final_fwd * np.asarray(t.F)))

    # ----------------------------------------------------------------- slpf

    def current_slpf(self) -> SLPF:
        """Clean SLPF of the whole current prefix.

        Join over the cached products + one build&merge per chunk (bucketed
        shapes) — zero reach work for sealed chunks.  Bit-identical to a
        cold ``ParserEngine.parse`` of the same prefix.
        """
        with self.engine.obs.span(
            "stream.query", n_chars=self.n, n_sealed=self.n_sealed_chunks
        ):
            return self._current_slpf()

    def _current_slpf(self) -> SLPF:
        eng = self.engine
        t = eng.tables
        chunks = self._chunk_classes()
        classes = (
            np.concatenate(chunks) if chunks else np.zeros(0, dtype=np.int32)
        )
        if len(classes) == 0:
            col = (np.asarray(t.I, dtype=bool) & np.asarray(t.F, dtype=bool))
            return SLPF(table=eng.table, columns=col[None, : t.ell], classes=classes)

        Jf, Jb, col0p, c_real = self._joined()
        assert c_real == len(chunks)
        rows = [np.asarray(col0p)[None]]
        for i, ch in enumerate(chunks):
            k = self._bucket_len(len(ch))
            padded = jnp.asarray(eng._pad_to(ch, 1, k))
            Mp = eng.phases.build_merge(t.N, padded, Jf[i][None], Jb[i][None])
            rows.append(np.asarray(Mp)[0, : len(ch)])
        packed = np.concatenate(rows, axis=0)
        columns = unpack_bits(packed, t.ell, axis=-1)
        return SLPF(table=eng.table, columns=columns, classes=classes)

    def count_trees(self) -> int:
        return self.current_slpf().count_trees()

    # ----------------------------------------------------- snapshot / evict

    def snapshot(self) -> StreamSnapshot:
        """O(1)-device capture of the stream state (products shared by ref).

        A cold (evicted) parser snapshots without rebuilding: the snapshot
        records the cold state and restore defers the rebuild to next touch.
        """
        leaves = list(_iter_leaves(self._root))
        tail = (
            np.concatenate(self._tail_pieces)
            if self._tail_len
            else np.zeros(0, dtype=np.int32)
        )
        return StreamSnapshot(
            sealed_classes=tuple(lf.classes.copy() for lf in leaves),
            sealed_products=(
                None if self._cold else tuple(lf.product for lf in leaves)
            ),
            tail_classes=tail,
            tail_product=None if self._cold else self._tail_product,
            next_seal_len=self._next_seal,
        )

    def restore(self, snap: StreamSnapshot) -> None:
        """Reinstate a snapshot taken on this engine's table set.

        The seal boundary clamps to THIS parser's ``max_seal_len`` — the
        cap is a promise, never exceeded, even for snapshots taken under a
        larger or uncapped config.  A tail longer than the clamped boundary
        reseals into cap-sized leaves (products rebuild lazily)."""
        cold = snap.sealed_products is None
        prods = (
            [None] * len(snap.sealed_classes)
            if cold
            else list(snap.sealed_products)
        )
        self._root = _build(
            [_leaf(c.copy(), p) for c, p in zip(snap.sealed_classes, prods)]
        )
        self._tail_pieces = (
            [snap.tail_classes.copy()] if len(snap.tail_classes) else []
        )
        self._tail_len = int(len(snap.tail_classes))
        self._tail_product = self._eye if cold else snap.tail_product
        self._cold = cold
        self._join = None
        self._evict_index = {}
        next_seal = int(snap.next_seal_len)
        if self.max_seal_len is not None:
            next_seal = min(next_seal, self.max_seal_len)
        self._next_seal = next_seal
        if self._tail_len >= self._next_seal:
            self._reseal_oversized_tail()

    def _reseal_oversized_tail(self) -> None:
        """Carve a restored tail that meets/exceeds the (clamped) seal
        boundary into cap-sized leaves.  The snapshot's tail product covered
        the whole oversized tail, so the carved leaves start product-less
        (the partial-eviction state ``_ensure_cache`` already repairs) and
        a warm remainder re-reaches eagerly."""
        classes = np.concatenate(self._tail_pieces)
        cap = self._next_seal
        pos = 0
        while len(classes) - pos >= cap:
            piece = classes[pos : pos + cap]
            pos += cap
            self._root = _concat(self._root, _leaf(piece, None))
        rest = np.asarray(classes[pos:], dtype=np.int32)
        self._tail_pieces = [rest] if len(rest) else []
        self._tail_len = int(len(rest))
        self._tail_product = self._eye
        if not self._cold and self._tail_len:
            self._tail_product = self._reach_piece(rest)

    def drop_cache(self) -> None:
        """Release all device product arrays (serving-layer eviction).

        Classes stay host-side; the next ``append``/``current_slpf``
        transparently re-reaches the sealed chunks (counted per chunk in
        ``rebuilds``).  Results are unaffected — only the work is.
        """
        for nd in _iter_nodes(self._root):
            nd.product = None
        self._tail_product = self._eye
        self._join = None
        self._cold = True
        self._evict_index = {}

    def sealed_cache_entries(self) -> List[Tuple[int, int, int]]:
        """(key, covered_chars, bytes) of each RESIDENT node product — the
        per-product eviction candidates the serving layer ranks.  Leaves
        cover one chunk; internal nodes cover their whole subtree, so the
        cost-aware largest-first policy drops them first — the cheapest
        rebuild there is ONE compose over the children.  Keys are stable
        node ids, valid until the tree is next edited."""
        if self._cold:
            return []
        self._evict_index = {}
        out: List[Tuple[int, int, int]] = []
        leaves = list(_iter_leaves(self._root))
        internals = [nd for nd in _iter_nodes(self._root) if nd.classes is None]
        for nd in leaves + internals:
            if nd.product is not None:
                self._evict_index[nd.uid] = nd
                out.append(
                    (nd.uid, nd.n_chars, int(nd.product.size) * nd.product.dtype.itemsize)
                )
        return out

    def drop_sealed_product(self, key: int) -> int:
        """Release ONE tree node's cached product; returns bytes freed —
        INCLUDING the join entries, which are released alongside the first
        drop so the bytes budget only counts memory eviction can actually
        reclaim (a budget below the join size still converges).

        Finer-grained than ``drop_cache``: other products stay resident and
        only the dropped node rebuilds on the next touch (a re-reach for a
        leaf, one compose for an internal node).  No-op (0 bytes) when
        already cold, dropped, or the key predates an edit.
        """
        if self._cold:
            return 0
        nd = self._evict_index.get(key)
        if nd is None:
            self.sealed_cache_entries()    # tree may have changed; re-index
            nd = self._evict_index.get(key)
        if nd is None or nd.product is None:
            return 0
        freed = int(nd.product.size) * nd.product.dtype.itemsize
        nd.product = None
        freed += self._join_nbytes()
        self._join = None
        return freed

    def _count_rebuild(self) -> None:
        self.rebuilds += 1
        self.engine.obs.metrics.counter("stream_rebuilds_total").inc()

    def _ensure_cache(self) -> None:
        if self._cold:
            self._cold = False
            for lf in _iter_leaves(self._root):
                lf.product = self._reach_piece(lf.classes)
                self._count_rebuild()
            self._tail_product = self._eye
            if self._tail_len:
                self._tail_product = self._reach_piece(
                    np.concatenate(self._tail_pieces)
                )
                self._count_rebuild()
            return
        # partial eviction: re-reach only the dropped leaves (internal
        # memos rebuild lazily — one compose each — via _node_product)
        for lf in _iter_leaves(self._root):
            if lf.product is None:
                lf.product = self._reach_piece(lf.classes)
                self._count_rebuild()
