"""Shared Linearized Parse Forest (paper Sect. 2.3.5, App. B, App. C).

The SLPF of a text ``x`` (length ``n``) is a DAG stored as ``n+1`` columns; column
``C_r`` is the set of segments located between characters ``x_r`` and ``x_{r+1}``
(``C_0`` before the first character, ``C_n`` holding the final segments whose
end-letter is ⊣).  A segment ``q ∈ C_r`` for ``1 ≤ r ≤ n`` was reached *reading*
``x_r``: its end-letter matches ``x_r`` and its meta-prefix sits between ``x_{r-1}``
and ``x_r``.  Arcs are implicit — they are the parser-NFA arcs restricted to
consecutive columns (Sect. 2.3.5) — so the storage is exactly the Boolean column
series of Eq. (4), here a dense ``(n+1, ℓ)`` bool array (bit-packable, App. C).

A *clean* SLPF contains only useful segments: every node lies on a path from an
initial segment in ``C_0`` to a final one in ``C_n``; each such path is one LST.

This module provides the forest-level API of the tool (Sect. 4.2):
  * ``count_trees``        — number of LSTs (paths), exact big-int DP;
  * ``iter_trees``         — lazy enumeration of LSTs as segment paths;
  * ``lst_string``         — render a path as the parenthesized LST;
  * ``getMatches``         — spans of a numbered group / operator pair (App. A
                             extra parentheses), per-tree exact or column-scan fast;
  * ``getChildren``        — child spans of a match, from the tree structure;
  * ``pack / unpack``      — App. C bit-packed encoding (uint32 words);
  * ``SLPFCompressor``     — App. C SLPF-DFA compression (columns as interned
                             states + a transition table keyed on (state, class)).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .matrices import ParserMatrices, pack_bits, unpack_bits
from .numbering import CLOSE, OPEN
from .segments import SegmentTable


@dataclass
class SLPF:
    """Clean shared linearized parse forest of one text."""

    table: SegmentTable
    columns: np.ndarray        # (n+1, ℓ) bool
    classes: np.ndarray        # (n,) int32 — char classes of the text

    @property
    def n(self) -> int:
        return self.columns.shape[0] - 1

    @property
    def n_segments(self) -> int:
        return self.columns.shape[1]

    @property
    def accepted(self) -> bool:
        """Non-empty forest ⇔ the text is valid (clean SLPF of a valid text is
        non-empty everywhere; of an invalid text it is empty everywhere)."""
        return bool(self.columns[-1].any())

    # ----------------------------------------------------------------- arcs

    def arcs(self, r: int) -> List[Tuple[int, int]]:
        """NFA arcs from column r-1 to column r (1 ≤ r ≤ n)."""
        t = self.table
        cls = int(self.classes[r - 1])
        out = []
        src_col = np.flatnonzero(self.columns[r - 1])
        dst_col = set(np.flatnonzero(self.columns[r]).tolist())
        for p in src_col:
            for q in t.delta(int(p), cls):
                if q in dst_col:
                    out.append((int(p), int(q)))
        return out

    # ------------------------------------------------------------- counting

    def count_trees(self) -> int:
        """Exact number of LSTs = number of C_0→C_n paths (python big ints)."""
        if not self.accepted:
            return 0
        t = self.table
        ell = self.n_segments
        f = [1 if self.columns[0][q] else 0 for q in range(ell)]
        for r in range(1, self.n + 1):
            cls = int(self.classes[r - 1])
            g = [0] * ell
            for p in range(ell):
                if f[p]:
                    for q in t.delta(p, cls):
                        if self.columns[r][q]:
                            g[q] += f[p]
            f = g
        fin = self.table.final
        return sum(f[q] for q in range(ell) if self.columns[-1][q] and fin[q])

    # ---------------------------------------------------------- enumeration

    def iter_trees(self, limit: Optional[int] = None) -> Iterator[Tuple[int, ...]]:
        """Yield LSTs as tuples of segment ids (path through the columns)."""
        if not self.accepted:
            return
        t = self.table
        n = self.n
        emitted = 0
        stack: List[Tuple[int, Tuple[int, ...]]] = [
            (0, (int(q),)) for q in np.flatnonzero(self.columns[0])[::-1]
        ]
        while stack:
            r, path = stack.pop()
            if r == n:
                if not t.final[path[-1]]:
                    continue  # an LST must end with a ⊣ segment
                yield path
                emitted += 1
                if limit is not None and emitted >= limit:
                    return
                continue
            cls = int(self.classes[r])
            for q in reversed(t.delta(path[-1], cls)):
                if self.columns[r + 1][q]:
                    stack.append((r + 1, path + (q,)))

    def lst_string(self, path: Sequence[int], with_end: bool = False) -> str:
        """Render a segment path as the parenthesized LST string."""
        s = "".join(self.table.display(q) for q in path)
        return s if with_end else s.replace("⊣", "")

    # ------------------------------------------------------ match extraction

    def _group_positions(self, num: int) -> Tuple[List[int], List[int]]:
        """Columns whose segments' meta-prefixes contain the open/close paren
        numbered ``num``.  A segment in C_r sits between x_r and x_{r+1} and its
        end-letter reads x_{r+1}, so a paren in its meta-prefix lies at 0-based
        char boundary r.  Sound for clean SLPFs: every occurrence is on a tree."""
        syms = self.table.numbered.symbols
        opens_in = np.zeros(self.n_segments, dtype=bool)
        closes_in = np.zeros(self.n_segments, dtype=bool)
        for i, seg in enumerate(self.table.segs):
            for sid in seg[:-1]:
                s = syms[sid]
                if s.num == num and s.kind == OPEN:
                    opens_in[i] = True
                if s.num == num and s.kind == CLOSE:
                    closes_in[i] = True
            # ⊣ segments: parens before ⊣ are also in seg[:-1]; end-letter never a paren
        open_cols = [r for r in range(self.n + 1) if (self.columns[r] & opens_in).any()]
        close_cols = [r for r in range(self.n + 1) if (self.columns[r] & closes_in).any()]
        return open_cols, close_cols

    def get_matches(self, num: int, limit: Optional[int] = 1000) -> List[Tuple[int, int]]:
        """Spans (start, end) of text matched by paren pair ``num`` (App. A).

        Exact per-tree extraction: walks up to ``limit`` trees and pairs the
        open/close parens along each LST.  ``end`` is exclusive.
        """
        syms = self.table.numbered.symbols
        spans: Dict[Tuple[int, int], None] = {}
        for path in self.iter_trees(limit=limit):
            # path[r] ∈ C_r sits between x_r and x_{r+1}: parens in its metaprefix
            # lie at 0-based char boundary r (group spans are half-open [start, end)).
            starts: List[int] = []
            for r, q in enumerate(path):
                for sid in self.table.segs[q][:-1]:
                    s = syms[sid]
                    if s.num != num:
                        continue
                    if s.kind == OPEN:
                        starts.append(r)
                    elif s.kind == CLOSE:
                        st = starts.pop() if starts else 0
                        spans[(st, r)] = None
        return sorted(spans.keys())

    def get_children(self, path: Sequence[int]) -> List[Tuple[int, int, int]]:
        """(paren_num, start, end) for every paren pair on one LST path."""
        syms = self.table.numbered.symbols
        out: List[Tuple[int, int, int]] = []
        stack: List[Tuple[int, int]] = []
        for r, q in enumerate(path):
            for sid in self.table.segs[q][:-1]:
                s = syms[sid]
                if s.kind == OPEN:
                    stack.append((s.num, r))
                elif s.kind == CLOSE:
                    num, st = stack.pop()
                    assert num == s.num, "mismatched parens in LST"
                    out.append((num, st, r))
        return sorted(out)

    # ------------------------------------------------------------ App. C

    def pack(self) -> np.ndarray:
        """Bit-packed columns: (n+1, W) uint32, W = ceil(ℓ/32)."""
        return pack_bits(self.columns, axis=-1)

    @classmethod
    def from_packed(
        cls, table: SegmentTable, packed: np.ndarray, classes: np.ndarray
    ) -> "SLPF":
        cols = unpack_bits(packed, table.n, axis=-1)
        return cls(table=table, columns=cols, classes=np.asarray(classes))


@dataclass
class CompressedSLPF:
    """App. C SLPF-DFA compression: columns interned; transitions keyed on
    (column-state, char class).  Reconstruction replays the text.

    Deviation from the paper (documented, DESIGN §8): for a *clean* SLPF the
    successor column is NOT always a function of (column, next char) — cleaning
    intersects with backward context, so the same (column, char) can have
    different successors at different positions (e.g. near the text end).  The
    paper's App. C delta table alone is therefore lossy; we keep it and add a
    sparse ``overrides`` map {position → state} recording the conflicting
    steps, which restores exact reconstruction (empirically a handful of
    entries, near the endpoints)."""

    table: SegmentTable
    initial_state: int
    states: List[np.ndarray]                       # state id → (ℓ,) bool column
    delta: Dict[Tuple[int, int], int]              # (state, class) → state
    overrides: Dict[int, int]                      # position r → state id
    classes: np.ndarray

    def nbytes(self) -> int:
        ell = self.table.n
        words = (ell + 31) // 32
        return (
            len(self.states) * words * 4
            + len(self.delta) * 12
            + len(self.overrides) * 8
            + self.classes.nbytes
        )

    def reconstruct(self) -> SLPF:
        cols = [self.states[self.initial_state]]
        s = self.initial_state
        for r in range(1, len(self.classes) + 1):
            if r in self.overrides:
                s = self.overrides[r]
            else:
                s = self.delta[(s, int(self.classes[r - 1]))]
            cols.append(self.states[s])
        return SLPF(table=self.table, columns=np.stack(cols), classes=self.classes)


def compress(slpf: SLPF) -> CompressedSLPF:
    """Build the SLPF-DFA of one forest (App. C + exactness overrides)."""
    index: Dict[bytes, int] = {}
    states: List[np.ndarray] = []

    def intern(col: np.ndarray) -> int:
        key = np.packbits(col).tobytes()
        if key not in index:
            index[key] = len(states)
            states.append(col.copy())
        return index[key]

    delta: Dict[Tuple[int, int], int] = {}
    overrides: Dict[int, int] = {}
    prev = intern(slpf.columns[0])
    init = prev
    for r in range(1, slpf.n + 1):
        cur = intern(slpf.columns[r])
        key = (prev, int(slpf.classes[r - 1]))
        if key not in delta:
            delta[key] = cur
        elif delta[key] != cur:
            overrides[r] = cur  # clean-SLPF non-determinism (see class docstring)
        prev = cur
    return CompressedSLPF(
        table=slpf.table, initial_state=init, states=states, delta=delta,
        overrides=overrides, classes=slpf.classes,
    )
