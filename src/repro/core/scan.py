"""Generic chunked three-phase scan — the paper's parallel schema as a primitive.

The paper's reach / join / build decomposition (Sect. 3.2) is an instance of a
general pattern for parallelizing any *associative* sequence recurrence:

    reach :  per chunk, fold the per-element monoid values into one summary
             (chunk products  P_i = e_k ⊗ … ⊗ e_1)                  — parallel
    join  :  exclusive scan of summaries across chunks
             (entry states    J_i = act(P_{i-1} ⊗ … ⊗ P_1, init))   — log-depth
    build :  per chunk, replay the recurrence from the known entry   — parallel

A monoid ``(M, ⊗)`` with identity acts on a state space via ``act(m, s)``; the
per-element recurrence is ``s_t = act(e_t, s_{t-1})``.

Instantiations in this framework:
  * Boolean (OR-AND) semiring on segment-transition matrices → the RE parser
    (``core/engine.py``): the chunk product *is* the ME-DFA analogue — all ℓ
    speculative entry states evaluated simultaneously as matrix columns, so the
    speculation bound is ℓ (paper Sect. 3.1), never the 2^ℓ DFA state count.
  * Affine real monoid on (decay, increment) pairs → Mamba-2 SSD chunked state
    passing (``models/mamba.py``): cross-chunk/device state propagation is the
    same join phase the parser uses.

Cross-device: when the chunk axis is sharded over mesh axes, ``join`` runs as a
single ``all_gather`` of the small per-chunk summaries followed by a replicated
local associative scan — O(c·|summary|) bytes of collective traffic, independent
of the sequence length (the paper's key scalability property, Sect. 3.3).
"""

from __future__ import annotations

from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

Combine = Callable[[Any, Any], Any]   # (later, earlier) -> composed; associative
Act = Callable[[Any, Any], Any]       # (monoid elem, state) -> state


def associative_prefix(combine: Combine, xs: Any, *, reverse: bool = False) -> Any:
    """Inclusive prefix combine along axis 0 (log-depth, pytree-aware).

    ``combine(later, earlier)``; with ``reverse=True`` computes suffix products.
    """
    return jax.lax.associative_scan(
        lambda a, b: combine(b, a), xs, axis=0, reverse=reverse
    )


def exclusive_entries(combine: Combine, act: Act, summaries: Any, init: Any) -> Any:
    """Join phase: entry state per chunk from stacked chunk summaries (axis 0).

    ``entries[0] = init``; ``entries[i] = act(summaries[i-1] ⊗ … ⊗ summaries[0],
    init)``.  Returns entries stacked along axis 0 (length c).
    """
    prefix = associative_prefix(combine, summaries)          # inclusive prefixes
    applied = jax.vmap(lambda m: act(m, init))(prefix)       # states after chunks

    def shift(leaf_applied, leaf_init):
        leaf_init = jnp.broadcast_to(
            jnp.asarray(leaf_init), leaf_applied.shape[1:]
        )[None]
        return jnp.concatenate([leaf_init, leaf_applied[:-1]], axis=0)

    return jax.tree.map(shift, applied, init)


def sharded_exclusive_entries(
    combine: Combine,
    act: Act,
    local_summary: Any,
    init: Any,
    axis_names: Sequence[str],
) -> Any:
    """Cross-device join: each device holds ONE chunk summary; returns this
    device's entry state.  One all_gather + replicated local scan + slice.

    Must run inside ``shard_map`` with ``axis_names`` bound.  Traffic per device
    is ``(c-1)·|summary|`` bytes — independent of chunk length.
    """
    gathered = jax.tree.map(lambda x: _all_gather_multi(x, axis_names), local_summary)
    entries = exclusive_entries(combine, act, gathered, init)
    idx = linear_index(axis_names)
    return jax.tree.map(lambda e: jax.lax.dynamic_index_in_dim(e, idx, 0, False), entries)


def _all_gather_multi(x: jnp.ndarray, axis_names: Sequence[str]) -> jnp.ndarray:
    """all_gather over possibly-multiple mesh axes, flattened to one chunk axis."""
    g = jax.lax.all_gather(x, tuple(axis_names), axis=0, tiled=False)
    if len(axis_names) > 1:
        g = g.reshape((-1,) + x.shape)
    return g


def axis_size(name: str):
    """``jax.lax.axis_size`` with a fallback for older jax (psum of 1)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def linear_index(axis_names: Sequence[str]) -> jnp.ndarray:
    """This device's linear position over possibly-multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name)
    return idx


def chunk_fold(combine: Combine, elems: Any, identity: Any) -> Any:
    """Reach phase for one chunk: fold elems (axis 0, length k) into a summary.

    Sequential ``lax.scan`` chain — O(k) combines of constant-size state; when
    the summary is a matrix each combine is one matmul (MXU work), and a chain
    has the same total FLOPs as a tree reduction with better locality.
    """

    def step(acc, e):
        return combine(e, acc), None

    out, _ = jax.lax.scan(step, identity, elems)
    return out


def chunk_replay(apply: Act, elems: Any, entry: Any) -> Tuple[Any, Any]:
    """Build phase for one chunk: replay the recurrence from ``entry``.

    Returns (final_state, stacked per-position states) — e.g. the SLPF columns.
    """

    def step(state, e):
        nxt = apply(e, state)
        return nxt, nxt

    return jax.lax.scan(step, entry, elems)


def chunked_scan(
    combine: Combine,
    apply: Act,
    elems: Any,
    init: Any,
    identity: Any,
    n_chunks: int,
) -> Any:
    """Single-program form of the full three-phase scan (jit-friendly).

    ``elems`` leaves: (n, ...) with n divisible by ``n_chunks``.  Returns the
    per-position states (n, ...) — identical to the serial left fold
    ``s_t = apply(e_t, s_{t-1})``, computed with the paper's reach/join/build
    structure (equivalence validated in tests).
    """

    def reshape(leaf):
        n = leaf.shape[0]
        assert n % n_chunks == 0, "sequence length must divide into chunks"
        k = n // n_chunks
        return leaf.reshape((n_chunks, k) + leaf.shape[1:])

    chunked = jax.tree.map(reshape, elems)
    summaries = jax.vmap(lambda e: chunk_fold(combine, e, identity))(chunked)
    entries = exclusive_entries(combine, act=apply, summaries=summaries, init=init)
    _, states = jax.vmap(lambda e, s: chunk_replay(apply, e, s))(chunked, entries)
    return jax.tree.map(lambda leaf: leaf.reshape((-1,) + leaf.shape[2:]), states)
