"""Pluggable three-phase parse backends (reach / join / build&merge).

The paper's decomposition (Sect. 3.2) exists in this repo at three levels:
the pure-jnp engine, the generic monoid-scan primitive (``core/scan.py``),
and the Pallas TPU kernels (``repro/kernels``).  This module collapses them
into ONE runtime schema with swappable phase implementations:

  reach        (c, k) class chunks → (c, ℓp, ℓp) chunk products
  join         chunk products → forward/backward entry states, expressed as
               ``core/scan.py``'s ``exclusive_entries`` over the Boolean
               OR-AND matrix monoid — the SAME scan the Mamba-2 SSD state
               passing uses, so there is exactly one join implementation.
  build&merge  (chunks, entries) → clean SLPF columns (Fig. 14, fused)

Backends:
  * ``JnpBackend``    — pure ``jax.numpy`` phase bodies (vmap over chunks and
    over the batch axis); the reference device program, runs anywhere.
  * ``PallasBackend`` — the ``kernels/reach.py`` + ``kernels/build.py``
    Mosaic kernels, scalar-prefetch DMA pipelining on TPU; on CPU the same
    calls run with ``interpret=True`` so tests exercise the real BlockSpecs.
    Chunks and batch rows are driven by ``lax.map`` (the kernels own the
    intra-chunk grid).
  * ``PackedBackend`` — chunk products as uint32 bit-words (32 segments per
    lane word); reach / compose / join-combine / build&merge run as OR-AND
    word ops (``core/matrices.py`` packed semiring) — a 32× bandwidth cut on
    the SLPF path for large automata.
  * ``SparseBackend`` — speculation-width reduction on top of the packed
    words: products carry only the *feasible-start rows* (the states that
    survive the chunk's leading character(s), PaREM's boundary set), so the
    product path pays |feasible| ≤ S rows instead of ℓp.

``ParserEngine(backend=...)`` selects by name; ``register_backend`` adds new
ones (GPU, …) without touching the engine.

The product-representation contract
-----------------------------------

A *chunk product* is an opaque, backend-owned device array; callers
(``ParserEngine.phases``, ``core/stream.py``'s prefix cache,
``core/distributed.py``'s all-gather join) may only assume:

  * axis 0 of ``reach``'s output indexes chunks; slicing / restacking /
    concatenating along it (``P[i]``, ``jnp.stack``, all-gather) is legal,
    as is measuring ``size * dtype.itemsize`` for cache accounting;
  * ``compose(later, earlier)`` and ``identity_product(ℓp)`` stay inside the
    representation (monoid closure); identity products are semantic no-ops
    in every position of a join stack;
  * dtype/shape beyond that are backend-private — f32 (ℓp, ℓp) matrices for
    ``jnp``/``pallas``, uint32 (ℓp, W = ℓp/32) packed target-set rows for
    ``packed``, and a *reduced* uint32 (S, 1+W) row-subset layout for
    ``sparse``.  Nothing outside the backend may arithmetic on a product.
  * backends whose representation depends on the concrete automaton hook
    ``bind_tables(tables)``, called once by ``ParserEngine.__init__`` before
    any phase is traced; the default is a no-op.

The sparse reduced representation: a chunk's product columns can only be
nonzero at start states that survive the chunk's first character(s) — the
feasible start-state set F(chunk).  ``sparse`` therefore stores, per chunk,
an (S, 1+W) uint32 array of gathered rows (slot = [source index | packed
target words]; see ``core/matrices.py``), where S is a static power-of-two
bucket of the automaton's worst-case single-character feasible width
max_a nnz-cols(N[a]) — a bound every depth-d set respects, so compiled
shapes stay fixed while the payload tracks the automaton, not ℓp.  The
monoid identity (which is not row-sparse) is carried as a flagged sentinel
product; all-PAD padding chunks produce exactly it, keeping identity slots
semantic no-ops in join stacks.  *Dense-fallback rule*: when the pow2
bucket reaches ℓp (an automaton whose first characters admit ~all states),
S = ℓp — the representation degenerates to dense packed rows plus an index
column and every op stays correct, just without the reduction.

The non-product boundaries are fixed across backends: ``join`` consumes a
(c, …) product stack and returns f32 (c, ℓp) entry vectors {0,1};
``start_column`` returns the f32 (ℓp,) text-start column; and
``build_merge_packed`` emits the engine-boundary output format — uint32
bit-packed SLPF columns (c, k, W), bit-identical across backends.  Those
fixed f32/u32 seams are what let every route (fused, phase-split,
streaming, mesh) swap backends without conversion code.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple, Type, Union

import numpy as np

import jax
import jax.numpy as jnp

from .matrices import (
    SPARSE_EMPTY,
    pack_bits_jnp,
    pack_transition_table_jnp,
    packed_identity,
    packed_matvec,
    packed_matvec_T,
    packed_matvec_T_words,
    packed_matvec_words,
    packed_semiring_matmul,
    sparse_compose,
    sparse_identity,
    sparse_init_rows,
    sparse_matvec,
    sparse_matvec_T,
)
from .scan import exclusive_entries


def _next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


# ----------------------------------------------------------- semiring ops


def semiring_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Boolean OR-AND product on {0,1} floats: clamp(a @ b)."""
    return jnp.minimum(jnp.matmul(a, b, precision=jax.lax.Precision.DEFAULT), 1.0)


def semiring_matvec(m: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(m @ v, 1.0)


def pack_columns_u32(cols: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp) {0,1} floats → (…, ℓp/32) uint32, little-endian bits.

    Engine-boundary alias of the packed semiring's packer — ONE device-side
    bit layout repo-wide (``core/matrices.py``).
    """
    return pack_bits_jnp(cols)


# ------------------------------------------------------ jnp phase bodies


def reach_chunk(N: jnp.ndarray, chunk: jnp.ndarray) -> jnp.ndarray:
    """Chunk product P = N[y_k] ⊗ … ⊗ N[y_1] — the reach phase (Eq. 6)."""
    lp = N.shape[-1]

    def step(P, cls):
        return semiring_matmul(N[cls], P), None

    P, _ = jax.lax.scan(step, jnp.eye(lp, dtype=N.dtype), chunk)
    return P


def build_merge_chunk(
    N: jnp.ndarray, chunk: jnp.ndarray, entry_f: jnp.ndarray, entry_b: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fig. 14 fused builder&merger for one chunk.

    Returns (M, beta0): M (k, ℓp) clean columns at positions 1..k of the chunk;
    beta0 (ℓp,) the backward state at the chunk start (used for global C_0).
    """

    def fstep(v, cls):
        nv = semiring_matvec(N[cls], v)
        return nv, nv

    _, fwd = jax.lax.scan(fstep, entry_f, chunk)            # fwd[t] = B_{t+1}

    def bstep(v, cls):
        nv = semiring_matvec(N[cls].T, v)
        return nv, nv

    _, bwd_rev = jax.lax.scan(bstep, entry_b, chunk[::-1])  # β_{k-1} … β_0
    bwd = bwd_rev[::-1]                                     # β_0 … β_{k-1}
    beta0 = bwd[0]
    # merge: M[t] = fwd[t] ∧ β_{t+1};  β_k = entry_b
    bwd_for_merge = jnp.concatenate([bwd[1:], entry_b[None]], axis=0)
    return fwd * bwd_for_merge, beta0


# ------------------------------------------------------- shared join phase


def join_entries(
    P: jnp.ndarray, I: jnp.ndarray, F: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Join phase (Eq. 7) from stacked chunk products P (c, ℓp, ℓp).

    Forward entry of chunk i:  J_i = (P_{i-1} ⊗ … ⊗ P_0) I.
    Backward entry of chunk i: Ĵ_i = (P_{c-1} ⊗ … ⊗ P_{i+1})ᵀ F — the
    transposed-suffix form that makes the backward reach free (DESIGN §2).

    Both directions are instances of ``core/scan.exclusive_entries`` over the
    Boolean matrix monoid — the identical scan the Mamba-2 SSD chunked state
    passing uses, so the parser and the model share one join implementation.
    """
    Jf = exclusive_entries(
        combine=semiring_matmul,                     # (later, earlier) → later ⊗ earlier
        act=semiring_matvec,
        summaries=P,
        init=I,
    )
    # Backward: scan the reversed products with flipped composition, acting by
    # the transpose; index j of the reversed scan is chunk c-1-j.
    Jb_rev = exclusive_entries(
        combine=lambda later, earlier: semiring_matmul(earlier, later),
        act=lambda m, v: semiring_matvec(m.T, v),
        summaries=P[::-1],
        init=F,
    )
    return Jf, Jb_rev[::-1]


# --------------------------------------------------------------- backends


class ParserBackend:
    """Swappable implementations of the three phases over EngineTables arrays.

    Table inputs use the engine's padded layout — N (A+1, ℓp, ℓp) f32, chunks
    (c, k) int32 — while chunk *products* are backend-owned opaque arrays (see
    the module docstring's product-representation contract).  Entries stay
    f32 (c, ℓp) and SLPF columns leave ``build_merge_packed`` as uint32 words
    in every backend.  ``join`` is shared (scan-based); subclasses provide
    ``reach`` and ``build_merge`` plus a batching strategy, and override the
    product-touching ops together when they change the representation.
    """

    name: str = "abstract"
    min_lane_pad: int = 32   # segment-dim alignment this backend requires

    def bind_tables(self, tables) -> None:
        """One-time hook: the concrete ``EngineTables`` this backend will run.

        Called by ``ParserEngine.__init__`` before any phase program is
        traced.  Backends whose product representation depends on the
        automaton (the sparse width bucket S) derive their static shapes
        here; the default is a no-op — most backends are table-agnostic.
        """

    def reach(self, N: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
        """(c, k) chunks → stacked chunk products (axis 0 = chunk)."""
        raise NotImplementedError

    def compose(self, later: jnp.ndarray, earlier: jnp.ndarray) -> jnp.ndarray:
        """Monoid composition of two chunk products: ``later ⊗ earlier``.

        The single-step form of the reach fold — the streaming prefix cache
        extends its tail product with this instead of re-folding the whole
        tail.  Backends with a different product representation (bit-packed
        uint32 words, …) override it together with ``reach``.
        """
        return semiring_matmul(later, earlier)

    def identity_product(self, ell_pad: int, dtype=jnp.float32) -> jnp.ndarray:
        """The monoid identity in this backend's product representation.

        Used by the streaming tail (empty-product init) and as the semantic
        no-op pad slot of every join stack (``core/stream.py``,
        ``core/distributed.py``).
        """
        return jnp.eye(ell_pad, dtype=dtype)

    def join(
        self, P: jnp.ndarray, I: jnp.ndarray, F: jnp.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Product stack (c, …) + I/F (ℓp,) f32 → f32 (c, ℓp) entries ×2."""
        return join_entries(P, I, F)

    def start_column(
        self, P: jnp.ndarray, I: jnp.ndarray, Jb0: jnp.ndarray
    ) -> jnp.ndarray:
        """Text-start column C₀ = I ∧ (P₀ᵀ Ĵ₀) as an f32 (ℓp,) vector.

        The backward state at text start is recovered from the first chunk's
        reach product — the only place outside the backend that would
        otherwise need product arithmetic, so it lives on the contract.
        """
        return I * semiring_matvec(P[0].T, Jb0)

    def build_merge(
        self, N: jnp.ndarray, chunks: jnp.ndarray, Jf: jnp.ndarray, Jb: jnp.ndarray
    ) -> jnp.ndarray:
        """(c, k) chunks + entries → (c, k, ℓp) clean columns."""
        raise NotImplementedError

    def build_merge_packed(
        self, N: jnp.ndarray, chunks: jnp.ndarray, Jf: jnp.ndarray, Jb: jnp.ndarray
    ) -> jnp.ndarray:
        """(c, k) chunks + entries → (c, k, W) uint32 bit-packed clean columns.

        The engine-boundary output format (identical across backends);
        word-native backends override it to emit packed columns directly.
        """
        return pack_columns_u32(self.build_merge(N, chunks, Jf, Jb))

    def batch_core(self, core: Callable) -> Callable:
        """Lift ``core(N, I, F, (c,k) chunks)`` to a (B, c, k) batch axis."""
        raise NotImplementedError

    def lift_batch(self, fn: Callable) -> Callable:
        """Lift a single phase body over a leading batch axis (all args).

        The per-phase analogue of ``batch_core``, used where the phases run
        as separate programs with a batch dim — the distributed batched route
        maps phase bodies per batch row *inside* ``shard_map``.  vmap by
        default; backends whose kernels own the device grid override with a
        sequential ``lax.map``.
        """
        return jax.vmap(fn)


class JnpBackend(ParserBackend):
    """Pure-jnp phase bodies — vmap everywhere; the reference device program."""

    name = "jnp"
    min_lane_pad = 32

    def reach(self, N, chunks):
        return jax.vmap(lambda ch: reach_chunk(N, ch))(chunks)

    def build_merge(self, N, chunks, Jf, Jb):
        M, _ = jax.vmap(lambda ch, ef, eb: build_merge_chunk(N, ch, ef, eb))(
            chunks, Jf, Jb
        )
        return M

    def batch_core(self, core):
        return jax.vmap(core, in_axes=(None, None, None, 0))


class PallasBackend(ParserBackend):
    """Mosaic kernels for the two hot loops (scalar-prefetch DMA pipelining).

    ``interpret=None`` auto-selects: real Mosaic on TPU, interpret mode on CPU
    (kernel bodies run under the Pallas interpreter, validating BlockSpecs and
    index maps — the CI-checkable form of the TPU program).  Chunk and batch
    axes run under ``lax.map``: the kernels own the intra-chunk grid, and the
    sequential outer loop keeps a single (ℓp, ℓp) VMEM working set live.
    """

    name = "pallas"
    min_lane_pad = 128   # MXU tile alignment required by the kernels

    def __init__(self, interpret: Union[bool, None] = None):
        self.interpret = interpret

    def _interp(self) -> bool:
        if self.interpret is None:
            from ..kernels.ops import use_interpret

            return use_interpret()
        return self.interpret

    def reach(self, N, chunks):
        from ..kernels.reach import reach_chunk_product

        interp = self._interp()
        return jax.lax.map(
            lambda ch: reach_chunk_product(N, ch, interpret=interp), chunks
        )

    def build_merge(self, N, chunks, Jf, Jb):
        from ..kernels.build import build_merge_chunk as kernel_build_merge

        interp = self._interp()
        return jax.lax.map(
            lambda args: kernel_build_merge(N, *args, interpret=interp),
            (chunks, Jf, Jb),
        )

    def batch_core(self, core):
        return lambda N, I, F, batch: jax.lax.map(
            lambda ch: core(N, I, F, ch), batch
        )

    def lift_batch(self, fn):
        # sequential over batch rows: the kernels own the intra-chunk grid and
        # a vmapped pallas_call would multiply the live VMEM working set
        return lambda *args: jax.lax.map(lambda a: fn(*a), args)


class PackedBackend(ParserBackend):
    """Bit-packed uint32 phase bodies — OR-AND word ops on the VPU path.

    Chunk products are (ℓp, W = ℓp/32) uint32 packed target-set rows (the
    ``pack_transition_table`` orientation; see ``core/matrices.py``'s packed
    semiring).  Reach, compose, and the join's scan combine run as word
    AND/OR/shift — ℓp³/32 word ops and ℓp²/8 product bytes vs the f32
    layout's ℓp³ MACs and 4ℓp² bytes — and build&merge scans packed state
    words end-to-end, emitting the packed SLPF columns with no unpack pass.
    The padded f32 tables (N, I, F) are packed *inside* the jitted phase
    bodies, so every entry point keeps the engine's table layout; entries
    crossing phase boundaries stay f32 per the module contract.  The in-jit
    table packing costs O((A+1)·ℓp²) bit-gathers per call — ≤ ~(A+1)/k of
    the reach work, bounded because chunk buckets floor at
    ``ParserEngine.min_chunk_len`` (8) — the price of keeping one table
    layout at every boundary; a table-resident packed N belongs to the
    real-TPU tuning item (ROADMAP).

    ``kernel=True`` routes reach through the Pallas packed OR-AND kernel
    (``kernels/packed_reach.py``; interpret mode off-TPU) instead of the
    XLA word ops — the TPU-experiment path, bit-identical by test.
    """

    name = "packed"
    min_lane_pad = 32   # exact uint32 word packing needs ℓp % 32 == 0

    def __init__(self, kernel: bool = False, interpret: Union[bool, None] = None):
        self.kernel = kernel
        self.interpret = interpret

    def reach(self, N, chunks):
        Np = pack_transition_table_jnp(N)            # (A+1, ℓp, W)
        if self.kernel:
            from ..kernels.ops import use_interpret
            from ..kernels.packed_reach import packed_reach_chunk_product

            interp = use_interpret() if self.interpret is None else self.interpret
            return jax.lax.map(
                lambda ch: packed_reach_chunk_product(Np, ch, interpret=interp),
                chunks,
            )
        eye = packed_identity(N.shape[-1])

        def one(chunk):
            def step(Q, cls):
                return packed_semiring_matmul(Np[cls], Q), None

            Q, _ = jax.lax.scan(step, eye, chunk)
            return Q

        return jax.vmap(one)(chunks)

    def compose(self, later, earlier):
        return packed_semiring_matmul(later, earlier)

    def identity_product(self, ell_pad, dtype=jnp.float32):
        return packed_identity(ell_pad)

    def join(self, P, I, F):
        Jf = exclusive_entries(
            combine=packed_semiring_matmul,
            act=packed_matvec,
            summaries=P,
            init=I,
        )
        Jb_rev = exclusive_entries(
            combine=lambda later, earlier: packed_semiring_matmul(earlier, later),
            act=packed_matvec_T,                     # transpose is free packed
            summaries=P[::-1],
            init=F,
        )
        return Jf, Jb_rev[::-1]

    def start_column(self, P, I, Jb0):
        return I * packed_matvec_T(P[0], Jb0)

    def build_merge_packed(self, N, chunks, Jf, Jb):
        Np = pack_transition_table_jnp(N)

        def one(chunk, ef, eb):
            def fstep(vp, cls):
                nvp = packed_matvec_words(Np[cls], vp)
                return nvp, nvp

            _, fwd = jax.lax.scan(fstep, pack_bits_jnp(ef), chunk)

            ebp = pack_bits_jnp(eb)

            def bstep(vp, cls):
                nvp = packed_matvec_T_words(Np[cls], vp)
                return nvp, nvp

            _, bwd_rev = jax.lax.scan(bstep, ebp, chunk[::-1])
            bwd = bwd_rev[::-1]                      # β₀ … β_{k-1} packed words
            # merge: M[t] = fwd[t] ∧ β_{t+1};  β_k = entry_b — one word-AND
            bwd_next = jnp.concatenate([bwd[1:], ebp[None]], axis=0)
            return fwd & bwd_next                    # (k, W) packed columns

        return jax.vmap(one)(chunks, Jf, Jb)

    def build_merge(self, N, chunks, Jf, Jb):
        from .matrices import unpack_bits_jnp

        return unpack_bits_jnp(
            self.build_merge_packed(N, chunks, Jf, Jb), N.shape[-1]
        )

    def batch_core(self, core):
        return jax.vmap(core, in_axes=(None, None, None, 0))


class SparseBackend(PackedBackend):
    """Feasible-start sparse products — the speculation-width reduction.

    The paper pays ℓp speculative start states per chunk; PaREM's observation
    is that boundary information prunes that to the *feasible start-state
    set*: only states with an outgoing transition on the chunk's first
    character(s) can have a nonzero product column.  This backend computes
    that set per chunk inside the jitted reach body (a depth-``d`` backward
    Boolean mat-vec over the chunk's leading classes), gathers the surviving
    rows, and folds ONLY those through the packed OR-AND word ops — S·ℓp·W
    word ops and S·(1+W)·4 product bytes per chunk vs the dense packed
    ℓp²·W ops and ℓp·W·4 bytes.

    Products are uint32 (S, 1+W) gathered-row arrays (module contract /
    ``core/matrices.py``): slot = [source index | packed target words],
    ``SPARSE_EMPTY`` index = unused slot, identity carried as the
    ``SPARSE_IDENT`` flag (all-PAD padding chunks emit exactly it).  S is
    static per automaton — ``bind_tables`` buckets the worst-case
    single-character feasible width max_a nnz-cols(N[a]) to the next pow2
    (floor ``min_width``), with the dense-fallback rule S = ℓp when the
    bucket reaches ℓp.  Every depth-d feasible set is a subset of the
    depth-1 set of the chunk's first class, so S slots always suffice and
    compiled shapes never depend on the text.

    The reduced representation flows end-to-end: the join scan composes
    (S, 1+W) summaries, ``StreamingParser``'s sealed cache stores them
    (``size·itemsize`` accounting sees the cut), and ``DistributedEngine``'s
    all-gather moves them across the mesh.  Entries, start column, and
    build&merge keep the contract's fixed f32/u32 seams (build&merge is
    entry-driven and inherits the packed word path unchanged).

    ``kernel=True`` routes the gathered-row fold through the Pallas kernel
    (``kernels/sparse_reach.py``; interpret mode off-TPU).  ``depth`` is the
    feasible-prefix depth: characters of the chunk consulted when pruning
    (``ParserConfig.feasible_depth``); deeper prunes harder at the cost of
    d sequential mat-vecs before the fold.
    """

    name = "sparse"
    min_lane_pad = 32

    def __init__(
        self,
        kernel: bool = False,
        interpret: Union[bool, None] = None,
        depth: int = 1,
        min_width: int = 8,
    ):
        super().__init__(kernel=kernel, interpret=interpret)
        if depth < 1:
            raise ValueError(f"feasible-prefix depth must be ≥ 1, got {depth}")
        self.depth = int(depth)
        self.min_width = int(min_width)
        self._width: Union[int, None] = None      # S: static product rows
        self._ell_pad: Union[int, None] = None
        self.class_widths: Union[np.ndarray, None] = None

    # -------------------------------------------------- static width bucket

    def bind_tables(self, tables) -> None:
        N = np.asarray(tables.N) > 0
        lp = int(N.shape[-1])
        # per real class (PAD excluded): nnz columns of N[a] = states with an
        # outgoing transition on a = the depth-1 feasible width upper bound
        widths = N[:-1].any(axis=1).sum(axis=1).astype(np.int64)
        w_static = int(widths.max()) if widths.size else 1
        self.class_widths = widths
        self.bind_shape(lp, w_static)

    def bind_shape(self, ell_pad: int, raw_width: int) -> None:
        """Bind static product shapes from an ℓp and a raw feasible-width bound.

        The fleet path calls this directly: one SparseBackend instance serves
        every tenant of an (Ab, ℓp) automaton bucket, bound at the bucket's
        worst-case width (max over member tenants) — a width ≥ any member's
        own bound keeps every gather correct, the extra slots just carry
        ``SPARSE_EMPTY``.  Applies the same pow2 bucketing + dense-fallback
        rule as ``bind_tables``.
        """
        lp = int(ell_pad)
        S = _next_pow2(max(self.min_width, int(raw_width), 1))
        # dense-fallback rule: no reduction to be had → carry every row
        self._width = lp if S >= lp else S
        self._ell_pad = lp

    def _require_bound(self, lp: int) -> int:
        if self._width is None:
            raise RuntimeError(
                "sparse backend is unbound — ParserEngine.__init__ calls "
                "bind_tables(tables) before tracing; standalone use must too"
            )
        if lp != self._ell_pad:
            raise ValueError(
                f"sparse backend bound to ℓp={self._ell_pad}, got ℓp={lp}; "
                "one SparseBackend instance serves one automaton"
            )
        return self._width

    # ------------------------------------------------------------ phase ops

    def reach(self, N, chunks):
        lp = N.shape[-1]
        S = self._require_bound(lp)
        Np = pack_transition_table_jnp(N)            # (A+1, ℓp, W)
        pad_cls = N.shape[0] - 1
        depth = min(self.depth, chunks.shape[-1])
        ident = sparse_identity(S, lp // 32)
        if self.kernel:
            from ..kernels.ops import use_interpret
            from ..kernels.sparse_reach import sparse_reach_rows

            interp = use_interpret() if self.interpret is None else self.interpret

        def feasible_idx(chunk):
            u = jnp.ones((lp,), N.dtype)
            for j in range(depth - 1, -1, -1):
                u = jnp.minimum(N[chunk[j]].T @ u, 1.0)
            return jnp.sort(
                jnp.where(
                    u > 0.5,
                    jnp.arange(lp, dtype=jnp.int32),
                    jnp.int32(SPARSE_EMPTY),
                )
            )[:S]

        def one(chunk):
            idx = feasible_idx(chunk)
            R0 = sparse_init_rows(idx, lp)           # (S, W) packed e_idx rows
            if self.kernel:
                R = sparse_reach_rows(Np, chunk, R0, interpret=interp)
            else:
                def step(R, cls):
                    return (
                        jax.vmap(lambda vp: packed_matvec_words(Np[cls], vp))(R),
                        None,
                    )

                R, _ = jax.lax.scan(step, R0, chunk)
            body = jnp.concatenate([idx.astype(jnp.uint32)[:, None], R], axis=1)
            # all-PAD padding chunk ⇔ first class is PAD (PAD only pads the
            # tail) ⇒ product is exactly the identity → flagged encoding
            return jnp.where(chunk[0] == pad_cls, ident, body)

        if self.kernel:
            # sequential over chunks: the kernel owns the intra-chunk grid
            return jax.lax.map(one, chunks)
        return jax.vmap(one)(chunks)

    def compose(self, later, earlier):
        return sparse_compose(later, earlier)

    def identity_product(self, ell_pad, dtype=jnp.float32):
        S = self._require_bound(ell_pad)
        return sparse_identity(S, ell_pad // 32)

    def join(self, P, I, F):
        Jf = exclusive_entries(
            combine=sparse_compose,
            act=sparse_matvec,
            summaries=P,
            init=I,
        )
        Jb_rev = exclusive_entries(
            combine=lambda later, earlier: sparse_compose(earlier, later),
            act=sparse_matvec_T,                     # transpose free on rows
            summaries=P[::-1],
            init=F,
        )
        return Jf, Jb_rev[::-1]

    def start_column(self, P, I, Jb0):
        return I * sparse_matvec_T(P[0], Jb0)


_BACKENDS: Dict[str, Type[ParserBackend]] = {}


def register_backend(cls: Type[ParserBackend]) -> Type[ParserBackend]:
    _BACKENDS[cls.name] = cls
    return cls


register_backend(JnpBackend)
register_backend(PallasBackend)
register_backend(PackedBackend)
register_backend(SparseBackend)


def list_backends() -> list:
    """Sorted names of every registered parse backend."""
    return sorted(_BACKENDS)


def get_backend(backend: Union[str, ParserBackend]) -> ParserBackend:
    """Resolve a backend name (or pass an instance through)."""
    if isinstance(backend, ParserBackend):
        return backend
    try:
        return _BACKENDS[backend]()
    except KeyError:
        raise ValueError(
            f"unknown parse backend {backend!r}; known: {sorted(_BACKENDS)}"
        ) from None
