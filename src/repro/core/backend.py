"""Pluggable three-phase parse backends (reach / join / build&merge).

The paper's decomposition (Sect. 3.2) exists in this repo at three levels:
the pure-jnp engine, the generic monoid-scan primitive (``core/scan.py``),
and the Pallas TPU kernels (``repro/kernels``).  This module collapses them
into ONE runtime schema with swappable phase implementations:

  reach        (c, k) class chunks → (c, ℓp, ℓp) chunk products
  join         chunk products → forward/backward entry states, expressed as
               ``core/scan.py``'s ``exclusive_entries`` over the Boolean
               OR-AND matrix monoid — the SAME scan the Mamba-2 SSD state
               passing uses, so there is exactly one join implementation.
  build&merge  (chunks, entries) → clean SLPF columns (Fig. 14, fused)

Backends:
  * ``JnpBackend``    — pure ``jax.numpy`` phase bodies (vmap over chunks and
    over the batch axis); the reference device program, runs anywhere.
  * ``PallasBackend`` — the ``kernels/reach.py`` + ``kernels/build.py``
    Mosaic kernels, scalar-prefetch DMA pipelining on TPU; on CPU the same
    calls run with ``interpret=True`` so tests exercise the real BlockSpecs.
    Chunks and batch rows are driven by ``lax.map`` (the kernels own the
    intra-chunk grid).

``ParserEngine(backend=...)`` selects by name; ``register_backend`` adds new
ones (bit-packed VPU, GPU, …) without touching the engine.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple, Type, Union

import jax
import jax.numpy as jnp

from .scan import exclusive_entries


# ----------------------------------------------------------- semiring ops


def semiring_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Boolean OR-AND product on {0,1} floats: clamp(a @ b)."""
    return jnp.minimum(jnp.matmul(a, b, precision=jax.lax.Precision.DEFAULT), 1.0)


def semiring_matvec(m: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(m @ v, 1.0)


def pack_columns_u32(cols: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp) {0,1} floats → (…, ℓp/32) uint32, little-endian bits."""
    shape = cols.shape
    lp = shape[-1]
    assert lp % 32 == 0
    bits = cols.reshape(shape[:-1] + (lp // 32, 32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


# ------------------------------------------------------ jnp phase bodies


def reach_chunk(N: jnp.ndarray, chunk: jnp.ndarray) -> jnp.ndarray:
    """Chunk product P = N[y_k] ⊗ … ⊗ N[y_1] — the reach phase (Eq. 6)."""
    lp = N.shape[-1]

    def step(P, cls):
        return semiring_matmul(N[cls], P), None

    P, _ = jax.lax.scan(step, jnp.eye(lp, dtype=N.dtype), chunk)
    return P


def build_merge_chunk(
    N: jnp.ndarray, chunk: jnp.ndarray, entry_f: jnp.ndarray, entry_b: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fig. 14 fused builder&merger for one chunk.

    Returns (M, beta0): M (k, ℓp) clean columns at positions 1..k of the chunk;
    beta0 (ℓp,) the backward state at the chunk start (used for global C_0).
    """

    def fstep(v, cls):
        nv = semiring_matvec(N[cls], v)
        return nv, nv

    _, fwd = jax.lax.scan(fstep, entry_f, chunk)            # fwd[t] = B_{t+1}

    def bstep(v, cls):
        nv = semiring_matvec(N[cls].T, v)
        return nv, nv

    _, bwd_rev = jax.lax.scan(bstep, entry_b, chunk[::-1])  # β_{k-1} … β_0
    bwd = bwd_rev[::-1]                                     # β_0 … β_{k-1}
    beta0 = bwd[0]
    # merge: M[t] = fwd[t] ∧ β_{t+1};  β_k = entry_b
    bwd_for_merge = jnp.concatenate([bwd[1:], entry_b[None]], axis=0)
    return fwd * bwd_for_merge, beta0


# ------------------------------------------------------- shared join phase


def join_entries(
    P: jnp.ndarray, I: jnp.ndarray, F: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Join phase (Eq. 7) from stacked chunk products P (c, ℓp, ℓp).

    Forward entry of chunk i:  J_i = (P_{i-1} ⊗ … ⊗ P_0) I.
    Backward entry of chunk i: Ĵ_i = (P_{c-1} ⊗ … ⊗ P_{i+1})ᵀ F — the
    transposed-suffix form that makes the backward reach free (DESIGN §2).

    Both directions are instances of ``core/scan.exclusive_entries`` over the
    Boolean matrix monoid — the identical scan the Mamba-2 SSD chunked state
    passing uses, so the parser and the model share one join implementation.
    """
    Jf = exclusive_entries(
        combine=semiring_matmul,                     # (later, earlier) → later ⊗ earlier
        act=semiring_matvec,
        summaries=P,
        init=I,
    )
    # Backward: scan the reversed products with flipped composition, acting by
    # the transpose; index j of the reversed scan is chunk c-1-j.
    Jb_rev = exclusive_entries(
        combine=lambda later, earlier: semiring_matmul(earlier, later),
        act=lambda m, v: semiring_matvec(m.T, v),
        summaries=P[::-1],
        init=F,
    )
    return Jf, Jb_rev[::-1]


# --------------------------------------------------------------- backends


class ParserBackend:
    """Swappable implementations of the three phases over EngineTables arrays.

    All arrays use the engine's padded layout: N (A+1, ℓp, ℓp) f32, chunks
    (c, k) int32, entries (c, ℓp) f32.  ``join`` is shared (scan-based);
    subclasses provide ``reach`` and ``build_merge`` plus a batching strategy.
    """

    name: str = "abstract"
    min_lane_pad: int = 32   # segment-dim alignment this backend requires

    def reach(self, N: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
        """(c, k) chunks → (c, ℓp, ℓp) chunk products."""
        raise NotImplementedError

    def compose(self, later: jnp.ndarray, earlier: jnp.ndarray) -> jnp.ndarray:
        """Monoid composition of two chunk products: ``later ⊗ earlier``.

        The single-step form of the reach fold — the streaming prefix cache
        extends its tail product with this instead of re-folding the whole
        tail.  Backends with a different product representation (bit-packed
        uint32 words, …) override it together with ``reach``.
        """
        return semiring_matmul(later, earlier)

    def join(
        self, P: jnp.ndarray, I: jnp.ndarray, F: jnp.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return join_entries(P, I, F)

    def build_merge(
        self, N: jnp.ndarray, chunks: jnp.ndarray, Jf: jnp.ndarray, Jb: jnp.ndarray
    ) -> jnp.ndarray:
        """(c, k) chunks + entries → (c, k, ℓp) clean columns."""
        raise NotImplementedError

    def batch_core(self, core: Callable) -> Callable:
        """Lift ``core(N, I, F, (c,k) chunks)`` to a (B, c, k) batch axis."""
        raise NotImplementedError

    def lift_batch(self, fn: Callable) -> Callable:
        """Lift a single phase body over a leading batch axis (all args).

        The per-phase analogue of ``batch_core``, used where the phases run
        as separate programs with a batch dim — the distributed batched route
        maps phase bodies per batch row *inside* ``shard_map``.  vmap by
        default; backends whose kernels own the device grid override with a
        sequential ``lax.map``.
        """
        return jax.vmap(fn)


class JnpBackend(ParserBackend):
    """Pure-jnp phase bodies — vmap everywhere; the reference device program."""

    name = "jnp"
    min_lane_pad = 32

    def reach(self, N, chunks):
        return jax.vmap(lambda ch: reach_chunk(N, ch))(chunks)

    def build_merge(self, N, chunks, Jf, Jb):
        M, _ = jax.vmap(lambda ch, ef, eb: build_merge_chunk(N, ch, ef, eb))(
            chunks, Jf, Jb
        )
        return M

    def batch_core(self, core):
        return jax.vmap(core, in_axes=(None, None, None, 0))


class PallasBackend(ParserBackend):
    """Mosaic kernels for the two hot loops (scalar-prefetch DMA pipelining).

    ``interpret=None`` auto-selects: real Mosaic on TPU, interpret mode on CPU
    (kernel bodies run under the Pallas interpreter, validating BlockSpecs and
    index maps — the CI-checkable form of the TPU program).  Chunk and batch
    axes run under ``lax.map``: the kernels own the intra-chunk grid, and the
    sequential outer loop keeps a single (ℓp, ℓp) VMEM working set live.
    """

    name = "pallas"
    min_lane_pad = 128   # MXU tile alignment required by the kernels

    def __init__(self, interpret: Union[bool, None] = None):
        self.interpret = interpret

    def _interp(self) -> bool:
        if self.interpret is None:
            from ..kernels.ops import use_interpret

            return use_interpret()
        return self.interpret

    def reach(self, N, chunks):
        from ..kernels.reach import reach_chunk_product

        interp = self._interp()
        return jax.lax.map(
            lambda ch: reach_chunk_product(N, ch, interpret=interp), chunks
        )

    def build_merge(self, N, chunks, Jf, Jb):
        from ..kernels.build import build_merge_chunk as kernel_build_merge

        interp = self._interp()
        return jax.lax.map(
            lambda args: kernel_build_merge(N, *args, interpret=interp),
            (chunks, Jf, Jb),
        )

    def batch_core(self, core):
        return lambda N, I, F, batch: jax.lax.map(
            lambda ch: core(N, I, F, ch), batch
        )

    def lift_batch(self, fn):
        # sequential over batch rows: the kernels own the intra-chunk grid and
        # a vmapped pallas_call would multiply the live VMEM working set
        return lambda *args: jax.lax.map(lambda a: fn(*a), args)


_BACKENDS: Dict[str, Type[ParserBackend]] = {}


def register_backend(cls: Type[ParserBackend]) -> Type[ParserBackend]:
    _BACKENDS[cls.name] = cls
    return cls


register_backend(JnpBackend)
register_backend(PallasBackend)


def get_backend(backend: Union[str, ParserBackend]) -> ParserBackend:
    """Resolve a backend name (or pass an instance through)."""
    if isinstance(backend, ParserBackend):
        return backend
    try:
        return _BACKENDS[backend]()
    except KeyError:
        raise ValueError(
            f"unknown parse backend {backend!r}; known: {sorted(_BACKENDS)}"
        ) from None
