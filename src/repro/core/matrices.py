"""Boolean connection matrices of the parser NFA (paper Sect. 2.4).

For each character class ``c`` (App. A alphabet partition) the matrix ``N_c`` has
``N_c[row, col] = 1`` iff the NFA has an arc labeled ``c`` from segment ``col`` to
segment ``row`` — i.e. ``row ∈ FolSeg(col)`` and ``col``'s end-letter reads ``c``.

Layout: ``N`` is a dense ``(n_classes + 1, ℓ, ℓ)`` array.  Index ``n_classes`` is the
synthetic PAD class whose matrix is the identity: padding a text with PAD characters
is a semantic no-op for both the column recurrence and chunk products, which lets the
parallel engine use statically-shaped equal chunks (the TPU replacement for the
paper's load-balancing fragments).

Bit-packing: segments are packed 32-per-lane into uint32 words.  ``N_packed`` has
shape ``(n_classes + 1, ℓ, W)`` with ``W = ceil(ℓ/32)``; row-major packing along the
*target* dimension so the Boolean mat-vec ``out = OR_col v[col] & N[col]`` becomes a
masked OR-reduction — the VPU-friendly form used by the bit-packed kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from .segments import SegmentTable


@dataclass
class ParserMatrices:
    table: SegmentTable
    N: np.ndarray          # (A+1, ℓ, ℓ) bool;  N[A] = I (PAD class)
    I: np.ndarray          # (ℓ,) bool — initial segments
    F: np.ndarray          # (ℓ,) bool — final segments
    byte_to_class: np.ndarray  # (256,) int32

    @property
    def n_segments(self) -> int:
        return self.N.shape[1]

    @property
    def n_classes(self) -> int:  # including DEAD, excluding PAD
        return self.N.shape[0] - 1

    @property
    def pad_class(self) -> int:
        return self.N.shape[0] - 1

    def classes_of_text(self, text: bytes | str) -> np.ndarray:
        if isinstance(text, str):
            text = text.encode("utf-8")
        return self.byte_to_class[np.frombuffer(text, dtype=np.uint8)]


def build_matrices(table: SegmentTable) -> ParserMatrices:
    ell = table.n
    A = table.numbered.n_classes
    N = np.zeros((A + 1, ell, ell), dtype=bool)
    for col in range(ell):
        succs = table.folseg[col]
        if not succs:
            continue
        for cls in table.seg_classes[col]:
            for row in succs:
                N[cls, row, col] = True
    N[A] = np.eye(ell, dtype=bool)  # PAD class = identity
    return ParserMatrices(
        table=table,
        N=N,
        I=table.initial.copy(),
        F=table.final.copy(),
        byte_to_class=np.asarray(table.numbered.byte_to_class, dtype=np.int32),
    )


def pack_bits(mat: np.ndarray, axis: int = -1) -> np.ndarray:
    """Pack a boolean array along ``axis`` into uint32 words (little-endian bits)."""
    mat = np.moveaxis(np.asarray(mat, dtype=bool), axis, -1)
    n = mat.shape[-1]
    W = (n + 31) // 32
    padded = np.zeros(mat.shape[:-1] + (W * 32,), dtype=bool)
    padded[..., :n] = mat
    r = padded.reshape(mat.shape[:-1] + (W, 32))
    weights = (np.uint64(1) << np.arange(32, dtype=np.uint64)).astype(np.uint64)
    packed = (r.astype(np.uint64) * weights).sum(axis=-1).astype(np.uint32)
    return np.moveaxis(packed, -1, axis if axis >= 0 else len(packed.shape) + axis)


def unpack_bits(packed: np.ndarray, n: int, axis: int = -1) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    packed = np.moveaxis(np.asarray(packed, dtype=np.uint32), axis, -1)
    bits = (packed[..., :, None] >> np.arange(32, dtype=np.uint32)) & np.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (-1,))[..., :n].astype(bool)
    return np.moveaxis(flat, -1, axis if axis >= 0 else len(flat.shape) + axis)


def pack_transition_table(N: np.ndarray) -> np.ndarray:
    """``(A, ℓ, ℓ)`` bool → ``(A, ℓ, W)`` uint32 packed along the *row* (target) dim.

    ``N_packed[c, col]`` is the packed target set of source segment ``col`` — the
    transposed orientation needed by the OR-AND mat-vec (out = OR of rows of packed
    selected by the source vector's set bits).
    """
    return pack_bits(np.swapaxes(N, -1, -2), axis=-1)


def boolean_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Boolean-semiring product of (…, m, k) @ (…, k, n) boolean arrays."""
    return np.matmul(a.astype(np.uint8), b.astype(np.uint8)) > 0


def boolean_matvec(mat: np.ndarray, vec: np.ndarray) -> np.ndarray:
    return (mat.astype(np.uint8) @ vec.astype(np.uint8)) > 0
