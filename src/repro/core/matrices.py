"""Boolean connection matrices of the parser NFA (paper Sect. 2.4).

For each character class ``c`` (App. A alphabet partition) the matrix ``N_c`` has
``N_c[row, col] = 1`` iff the NFA has an arc labeled ``c`` from segment ``col`` to
segment ``row`` — i.e. ``row ∈ FolSeg(col)`` and ``col``'s end-letter reads ``c``.

Layout: ``N`` is a dense ``(n_classes + 1, ℓ, ℓ)`` array.  Index ``n_classes`` is the
synthetic PAD class whose matrix is the identity: padding a text with PAD characters
is a semantic no-op for both the column recurrence and chunk products, which lets the
parallel engine use statically-shaped equal chunks (the TPU replacement for the
paper's load-balancing fragments).

Bit-packing: segments are packed 32-per-lane into uint32 words.  ``N_packed`` has
shape ``(n_classes + 1, ℓ, W)`` with ``W = ceil(ℓ/32)``; row-major packing along the
*target* dimension so the Boolean mat-vec ``out = OR_col v[col] & N[col]`` becomes a
masked OR-reduction — the VPU-friendly form used by the bit-packed kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from .segments import SegmentTable


@dataclass
class ParserMatrices:
    table: SegmentTable
    N: np.ndarray          # (A+1, ℓ, ℓ) bool;  N[A] = I (PAD class)
    I: np.ndarray          # (ℓ,) bool — initial segments
    F: np.ndarray          # (ℓ,) bool — final segments
    byte_to_class: np.ndarray  # (256,) int32

    @property
    def n_segments(self) -> int:
        return self.N.shape[1]

    @property
    def n_classes(self) -> int:  # including DEAD, excluding PAD
        return self.N.shape[0] - 1

    @property
    def pad_class(self) -> int:
        return self.N.shape[0] - 1

    def classes_of_text(self, text: bytes | str) -> np.ndarray:
        if isinstance(text, str):
            text = text.encode("utf-8")
        return self.byte_to_class[np.frombuffer(text, dtype=np.uint8)]


def build_matrices(table: SegmentTable) -> ParserMatrices:
    ell = table.n
    A = table.numbered.n_classes
    N = np.zeros((A + 1, ell, ell), dtype=bool)
    for col in range(ell):
        succs = table.folseg[col]
        if not succs:
            continue
        for cls in table.seg_classes[col]:
            for row in succs:
                N[cls, row, col] = True
    N[A] = np.eye(ell, dtype=bool)  # PAD class = identity
    return ParserMatrices(
        table=table,
        N=N,
        I=table.initial.copy(),
        F=table.final.copy(),
        byte_to_class=np.asarray(table.numbered.byte_to_class, dtype=np.int32),
    )


def pad_matrices_bundle(
    m: ParserMatrices, *, ell_pad: int, n_classes: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad one automaton's (N, I, F) to a shared fleet-bucket table shape.

    Returns float32 ``N (n_classes, ell_pad, ell_pad)``, ``I (ell_pad,)``,
    ``F (ell_pad,)`` — the multi-tenant generalization of
    ``EngineTables.from_matrices``'s lane padding, so automata of different
    sizes stack on a leading tenant axis and share ONE compiled program:

      * state axes zero-pad ℓ → ell_pad: padded states have no incoming or
        outgoing arcs and I/F zero there, so they are unreachable — products
        and entry vectors restricted to the first ℓ rows are bit-identical
        to the unpadded automaton's;
      * the tenant's real classes keep indices 0..A-1 (``byte_to_class`` is
        unchanged); every index from A through n_classes-1 — the relocated
        PAD class (now uniformly ``n_classes - 1`` across the bucket) and
        any unused padding classes below it — is the identity over the
        padded space, a semantic no-op in any chunk position.

    Padding is semantics-free for every backend: dense/packed consume the
    f32 layout directly (packing happens in-jit), and the sparse feasible
    width of an identity class is its diagonal — bounded by the bucket's
    shared width bucket S, which the fleet binds to the member maximum.
    """
    ell = m.n_segments
    A1 = m.N.shape[0]                       # tenant classes incl. its PAD
    if ell_pad < ell:
        raise ValueError(f"ell_pad {ell_pad} < automaton segments {ell}")
    if n_classes < A1:
        raise ValueError(f"n_classes {n_classes} < automaton classes {A1}")
    N = np.zeros((n_classes, ell_pad, ell_pad), dtype=np.float32)
    N[: A1 - 1, :ell, :ell] = m.N[:-1].astype(np.float32)
    N[A1 - 1 :] = np.eye(ell_pad, dtype=np.float32)  # PAD + unused = identity
    I = np.zeros(ell_pad, dtype=np.float32)
    I[:ell] = m.I
    F = np.zeros(ell_pad, dtype=np.float32)
    F[:ell] = m.F
    return N, I, F


def feasible_width_bound(m: ParserMatrices) -> int:
    """Worst-case single-character feasible-start width of one automaton.

    max over REAL classes (PAD and identity padding excluded — their
    "width" is ℓ by construction and would force the dense fallback) of
    nnz-cols(N[a]): the depth-1 bound every deeper feasible set respects.
    This is the host-side quantity the fleet maxes over an ℓp-bucket's
    members to pick the bucket's shared sparse width S.
    """
    N = np.asarray(m.N[:-1]) > 0
    widths = N.any(axis=1).sum(axis=1)
    return int(widths.max()) if widths.size else 1


def pack_bits(mat: np.ndarray, axis: int = -1) -> np.ndarray:
    """Pack a boolean array along ``axis`` into uint32 words (little-endian bits)."""
    mat = np.moveaxis(np.asarray(mat, dtype=bool), axis, -1)
    n = mat.shape[-1]
    W = (n + 31) // 32
    padded = np.zeros(mat.shape[:-1] + (W * 32,), dtype=bool)
    padded[..., :n] = mat
    r = padded.reshape(mat.shape[:-1] + (W, 32))
    weights = (np.uint64(1) << np.arange(32, dtype=np.uint64)).astype(np.uint64)
    packed = (r.astype(np.uint64) * weights).sum(axis=-1).astype(np.uint32)
    return np.moveaxis(packed, -1, axis if axis >= 0 else len(packed.shape) + axis)


_BIT_SHIFTS = np.arange(32, dtype=np.uint32)


def unpack_bits(packed: np.ndarray, n: int, axis: int = -1) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    packed = np.asarray(packed, dtype=np.uint32)
    last = axis == -1 or axis == packed.ndim - 1
    if not last:
        packed = np.moveaxis(packed, axis, -1)
    bits = (packed[..., :, None] >> _BIT_SHIFTS) & np.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (-1,))[..., :n].astype(bool)
    if last:
        return flat
    return np.moveaxis(flat, -1, axis if axis >= 0 else len(flat.shape) + axis)


def pack_transition_table(N: np.ndarray) -> np.ndarray:
    """``(A, ℓ, ℓ)`` bool → ``(A, ℓ, W)`` uint32 packed along the *row* (target) dim.

    ``N_packed[c, col]`` is the packed target set of source segment ``col`` — the
    transposed orientation needed by the OR-AND mat-vec (out = OR of rows of packed
    selected by the source vector's set bits).
    """
    return pack_bits(np.swapaxes(N, -1, -2), axis=-1)


def boolean_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Boolean-semiring product of (…, m, k) @ (…, k, n) boolean arrays."""
    return np.matmul(a.astype(np.uint8), b.astype(np.uint8)) > 0


def boolean_matvec(mat: np.ndarray, vec: np.ndarray) -> np.ndarray:
    return (mat.astype(np.uint8) @ vec.astype(np.uint8)) > 0


# ------------------------------------------------- jnp-side packed semiring
#
# Device-side (jit-traceable) counterparts of pack_bits/unpack_bits plus the
# Boolean OR-AND semiring evaluated directly on uint32 words — the compute
# layer of the "packed" ParserBackend (core/backend.py).
#
# Packed-matrix representation (the pack_transition_table orientation): a
# {0,1} matrix M (ℓp, ℓp) is stored as Q (ℓp, W) uint32 with W = ℓp/32 and
# bit b of Q[col, w] equal to M[32·w + b, col] — row ``col`` of Q is the
# packed *target* set of source segment ``col`` (little-endian bits along
# the row/target dim).  Every op below is pure word arithmetic (AND / OR /
# shift): a packed matmul is ℓp²·W word ops vs ℓp³ f32 MACs, and a packed
# product moves ℓp·W·4 = ℓp²/8 bytes vs ℓp²·4 — the 32× bandwidth cut on
# the SLPF path.

import jax
import jax.numpy as jnp

_WORD = 32
_SHIFTS = np.arange(_WORD, dtype=np.uint32)


def _or_reduce(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Bitwise-OR reduction along ``axis`` (uint32)."""
    axis = axis % x.ndim
    return jax.lax.reduce(x, jnp.uint32(0), jax.lax.bitwise_or, (axis,))


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp) {0,1} numeric → (…, ℓp/32) uint32 along the last axis.

    Device-side twin of :func:`pack_bits` (last axis only, ℓp % 32 == 0);
    bit-identical to the numpy packer and to ``backend.pack_columns_u32``.
    """
    n = bits.shape[-1]
    assert n % _WORD == 0, f"packed dim {n} must be a multiple of 32"
    r = bits.reshape(bits.shape[:-1] + (n // _WORD, _WORD)).astype(jnp.uint32)
    return _or_reduce(r << jnp.asarray(_SHIFTS), axis=-1)


def unpack_bits_jnp(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """(…, W) uint32 → (…, n) f32 {0,1} along the last axis (inverse pack)."""
    bits = (packed[..., :, None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (-1,))
    return flat[..., :n].astype(jnp.float32)


def pack_transition_table_jnp(N: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp, ℓp) {0,1} → (…, ℓp, W) uint32 packed along the row (target) dim.

    Device-side twin of :func:`pack_transition_table`: ``out[…, col]`` is the
    packed target set of source ``col`` — the packed-matrix representation of
    each leading-dim matrix.
    """
    return pack_bits_jnp(jnp.swapaxes(N, -1, -2))


def packed_identity(ell_pad: int) -> jnp.ndarray:
    """Packed identity matrix (ℓp, W): bit ``j`` set in row ``j``."""
    assert ell_pad % _WORD == 0
    j = jax.lax.broadcasted_iota(jnp.uint32, (ell_pad, ell_pad // _WORD), 0)
    w = jax.lax.broadcasted_iota(jnp.uint32, (ell_pad, ell_pad // _WORD), 1)
    return jnp.where(j // _WORD == w, jnp.uint32(1) << (j % _WORD), jnp.uint32(0))


def packed_semiring_matmul(later: jnp.ndarray, earlier: jnp.ndarray) -> jnp.ndarray:
    """OR-AND product ``later ⊗ earlier`` of packed matrices (…, ℓp, W).

    Column j of the result is the OR of ``later``'s rows selected by the set
    bits of ``earlier``'s column j:  Qc[j] = OR_k bit_k(Qe[j]) · Ql[k].  The
    contraction runs as a scan over 32-bit word blocks of k, so the live
    intermediate is (…, ℓp, 32, W) words = one f32 matrix's worth, never ℓp³.
    Leading batch dims broadcast like ``matmul`` (``associative_scan`` calls
    its combine on stacked blocks).
    """
    lp, W = later.shape[-2:]
    later, earlier = jnp.broadcast_arrays(later, earlier)
    batch = later.shape[:-2]
    blocks = later.reshape(batch + (W, _WORD, W))     # rows, k-word-grouped
    # scan over the k word-blocks: put that axis first
    words_seq = jnp.moveaxis(earlier, -1, 0)          # (W, …, ℓp)
    blocks_seq = jnp.moveaxis(blocks, -3, 0)          # (W, …, 32, W)

    def body(acc, xs):
        words, block = xs                             # (…, ℓp) · (…, 32, W)
        bits = (words[..., None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)
        mask = jnp.uint32(0) - bits                   # {0, 0xFFFFFFFF}
        sel = mask[..., :, None] & block[..., None, :, :]   # (…, ℓp, 32, W)
        return acc | _or_reduce(sel, axis=-2), None

    acc0 = jnp.zeros(batch + (lp, W), jnp.uint32)
    acc, _ = jax.lax.scan(body, acc0, (words_seq, blocks_seq))
    return acc


def _select_or(Q: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """OR of ``Q``'s rows (ℓp, W) selected by ``bits`` (ℓp,) {0,1} → (W,)."""
    mask = jnp.uint32(0) - bits.astype(jnp.uint32)
    return _or_reduce(mask[:, None] & Q, axis=0)


def packed_matvec(Q: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``M v`` with packed M: {0,1} f32 v (ℓp,) → {0,1} f32 (ℓp,).

    out = OR of the packed target rows whose source bit is set in v — the
    masked OR-reduction form of ``boolean_matvec`` (module docstring).
    """
    return unpack_bits_jnp(_select_or(Q, v > 0.5), Q.shape[0])


def packed_matvec_words(Q: jnp.ndarray, vp: jnp.ndarray) -> jnp.ndarray:
    """``M v`` staying packed: words vp (W,) → words (W,)."""
    bits = ((vp[:, None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)).reshape(-1)
    return _select_or(Q, bits)


def packed_matvec_T(Q: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``Mᵀ v`` with packed M: out[col] = 1 iff v hits any target of col.

    One AND + OR-reduce per row: out[col] = any(Q[col] & pack(v)) — the
    transposed mat-vec is *free* on the packed layout (no transpose pass).
    """
    vp = pack_bits_jnp(v)
    hits = _or_reduce(Q & vp[None, :], axis=1) != 0      # (ℓp,) bool
    return hits.astype(jnp.float32)


def packed_matvec_T_words(Q: jnp.ndarray, vp: jnp.ndarray) -> jnp.ndarray:
    """``Mᵀ v`` staying packed: words vp (W,) → words (W,)."""
    hits = _or_reduce(Q & vp[None, :], axis=1) != 0      # (ℓp,) bool
    return pack_bits_jnp(hits)


# --------------------------------------------- sparse feasible-start products
#
# The speculation-width-reduced product representation of the "sparse"
# ParserBackend (core/backend.py).  A chunk product only has nonzero packed
# rows at the *feasible start states* — the states surviving the chunk's
# leading character(s) (PaREM §III) — so it is carried as an (S, 1+W) uint32
# array of gathered rows instead of the dense (ℓp, W) packed matrix:
#
#   P[j, 0]  = source-state index of listed row j, or SPARSE_EMPTY for an
#              unused slot (a zero row);
#   P[j, 1:] = that row's packed target-set words (the packed-semiring layout
#              above — bit b of word w ⇔ target 32·w + b reachable).
#
# S is a static power-of-two bucket ≥ the automaton's max per-class feasible
# width (chosen host-side at engine build; dense fallback S = ℓp when the
# bound does not shrink).  The monoid identity cannot list its ℓp nonzero
# rows inside S slots, so it is encoded by a flag: P[0, 0] == SPARSE_IDENT
# marks the whole product as the identity (every other slot ignored).  All
# ops below honour the flag with `where`, so identity pad slots in join
# stacks stay semantic no-ops exactly as in the dense representations.

SPARSE_EMPTY = np.uint32(0x7FFFFFFF)   # unused slot (zero row)
SPARSE_IDENT = np.uint32(0x7FFFFFFE)   # in slot [0, 0]: product = identity


def sparse_identity(rows: int, W: int) -> jnp.ndarray:
    """The identity product in the sparse layout: flag set, no listed rows."""
    P = jnp.full((rows, 1 + W), SPARSE_EMPTY, dtype=jnp.uint32)
    P = P.at[:, 1:].set(jnp.uint32(0))
    return P.at[0, 0].set(SPARSE_IDENT)


def sparse_is_identity(P: jnp.ndarray) -> jnp.ndarray:
    """Scalar (or batched) bool: is this sparse product the flagged identity?"""
    return P[..., 0, 0] == SPARSE_IDENT


def sparse_init_rows(idx: jnp.ndarray, ell_pad: int) -> jnp.ndarray:
    """Packed identity rows e_idx: (S,) indices → (S, W) words.

    Row j holds the single bit ``idx[j]``; sentinel indices (≥ ℓp) give zero
    rows — the reach fold's start state (partial product after 0 characters).
    """
    W = ell_pad // _WORD
    S = idx.shape[0]
    w = jax.lax.broadcasted_iota(jnp.uint32, (S, W), 1)
    i = idx.astype(jnp.uint32)[:, None]
    return jnp.where(
        (i < ell_pad) & (i // _WORD == w),
        jnp.uint32(1) << (i % _WORD),
        jnp.uint32(0),
    )


def sparse_to_packed(P: jnp.ndarray, ell_pad: int) -> jnp.ndarray:
    """Sparse (S, 1+W) → dense packed (ℓp, W): scatter listed rows, zeros
    elsewhere; the flagged identity densifies to ``packed_identity``."""
    idx = P[:, 0].astype(jnp.int32)
    W = P.shape[-1] - 1
    dense = (
        jnp.zeros((ell_pad, W), jnp.uint32).at[idx].set(P[:, 1:], mode="drop")
    )
    return jnp.where(sparse_is_identity(P), packed_identity(ell_pad), dense)


def _sparse_compose_one(later: jnp.ndarray, earlier: jnp.ndarray) -> jnp.ndarray:
    """``later ⊗ earlier`` of two (S, 1+W) sparse products.

    The composition's feasible rows are (a subset of) ``earlier``'s listed
    rows — a start state dead by ``earlier``'s leading characters stays dead —
    so the output keeps ``earlier``'s index column and rewrites each listed
    row through ``later``: out[s] = OR of ``later``'s rows selected by the
    target bits of ``earlier[s]`` (S·ℓp·W word ops vs the dense ℓp²·W).
    Identity flags short-circuit either side.
    """
    W = later.shape[-1] - 1
    ell_pad = W * _WORD
    D = sparse_to_packed(later, ell_pad)                     # (ℓp, W)
    out_words = jax.vmap(lambda vp: packed_matvec_words(D, vp))(earlier[:, 1:])
    composed = jnp.concatenate([earlier[:, :1], out_words], axis=1)
    out = jnp.where(sparse_is_identity(later), earlier, composed)
    return jnp.where(sparse_is_identity(earlier), later, out)


def sparse_compose(later: jnp.ndarray, earlier: jnp.ndarray) -> jnp.ndarray:
    """Batched-leading-dims ``later ⊗ earlier`` (``associative_scan`` calls
    its combine on stacked blocks, so leading dims must broadcast)."""
    return jnp.vectorize(
        _sparse_compose_one, signature="(s,v),(s,v)->(s,v)"
    )(later, earlier)


def sparse_matvec(P: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``M v`` with sparse M: {0,1} f32 v (ℓp,) → {0,1} f32 (ℓp,).

    Gathers v at the listed source indices, ORs the selected rows' words —
    S word-selects instead of ℓp.
    """
    W = P.shape[-1] - 1
    ell_pad = W * _WORD
    idx = P[:, 0].astype(jnp.int32)
    vi = jnp.where(idx < ell_pad, v[jnp.clip(idx, 0, ell_pad - 1)], 0.0)
    mask = jnp.uint32(0) - (vi > 0.5).astype(jnp.uint32)
    words = _or_reduce(mask[:, None] & P[:, 1:], axis=0)     # (W,)
    return jnp.where(sparse_is_identity(P), v, unpack_bits_jnp(words, ell_pad))


def sparse_matvec_T(P: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``Mᵀ v`` with sparse M: out is nonzero only at listed source states
    whose target set intersects v — one AND + OR-reduce per listed row."""
    W = P.shape[-1] - 1
    ell_pad = W * _WORD
    vp = pack_bits_jnp(v)
    hits = (_or_reduce(P[:, 1:] & vp[None, :], axis=1) != 0).astype(jnp.float32)
    idx = P[:, 0].astype(jnp.int32)
    out = jnp.zeros(ell_pad, jnp.float32).at[idx].set(hits, mode="drop")
    return jnp.where(sparse_is_identity(P), v, out)


def feasible_start_widths(
    N: np.ndarray, chunks: np.ndarray, depth: int = 1
) -> np.ndarray:
    """Host-side observed speculation widths: per-chunk feasible-set sizes.

    For each (k,) chunk row of ``chunks``, the number of start states whose
    column of ``N[y_d] ⊗ … ⊗ N[y_1]`` is nonzero — the states a chunk
    processor actually needs to speculate on, vs the paper's ℓp.  Chunks
    starting with the PAD class (all-PAD padding) report -1: their product is
    the identity and they carry no speculation.  Pure numpy (stats path).
    """
    N = np.asarray(N) > 0
    chunks = np.asarray(chunks).reshape(-1, np.asarray(chunks).shape[-1])
    pad = N.shape[0] - 1
    out = np.empty(chunks.shape[0], dtype=np.int64)
    for i, chunk in enumerate(chunks):
        if chunk[0] == pad:
            out[i] = -1
            continue
        u = np.ones(N.shape[-1], dtype=bool)
        for j in range(min(depth, len(chunk)) - 1, -1, -1):
            u = (N[chunk[j]] & u[:, None]).any(axis=0)
        out[i] = int(u.sum())
    return out
