"""Boolean connection matrices of the parser NFA (paper Sect. 2.4).

For each character class ``c`` (App. A alphabet partition) the matrix ``N_c`` has
``N_c[row, col] = 1`` iff the NFA has an arc labeled ``c`` from segment ``col`` to
segment ``row`` — i.e. ``row ∈ FolSeg(col)`` and ``col``'s end-letter reads ``c``.

Layout: ``N`` is a dense ``(n_classes + 1, ℓ, ℓ)`` array.  Index ``n_classes`` is the
synthetic PAD class whose matrix is the identity: padding a text with PAD characters
is a semantic no-op for both the column recurrence and chunk products, which lets the
parallel engine use statically-shaped equal chunks (the TPU replacement for the
paper's load-balancing fragments).

Bit-packing: segments are packed 32-per-lane into uint32 words.  ``N_packed`` has
shape ``(n_classes + 1, ℓ, W)`` with ``W = ceil(ℓ/32)``; row-major packing along the
*target* dimension so the Boolean mat-vec ``out = OR_col v[col] & N[col]`` becomes a
masked OR-reduction — the VPU-friendly form used by the bit-packed kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from .segments import SegmentTable


@dataclass
class ParserMatrices:
    table: SegmentTable
    N: np.ndarray          # (A+1, ℓ, ℓ) bool;  N[A] = I (PAD class)
    I: np.ndarray          # (ℓ,) bool — initial segments
    F: np.ndarray          # (ℓ,) bool — final segments
    byte_to_class: np.ndarray  # (256,) int32

    @property
    def n_segments(self) -> int:
        return self.N.shape[1]

    @property
    def n_classes(self) -> int:  # including DEAD, excluding PAD
        return self.N.shape[0] - 1

    @property
    def pad_class(self) -> int:
        return self.N.shape[0] - 1

    def classes_of_text(self, text: bytes | str) -> np.ndarray:
        if isinstance(text, str):
            text = text.encode("utf-8")
        return self.byte_to_class[np.frombuffer(text, dtype=np.uint8)]


def build_matrices(table: SegmentTable) -> ParserMatrices:
    ell = table.n
    A = table.numbered.n_classes
    N = np.zeros((A + 1, ell, ell), dtype=bool)
    for col in range(ell):
        succs = table.folseg[col]
        if not succs:
            continue
        for cls in table.seg_classes[col]:
            for row in succs:
                N[cls, row, col] = True
    N[A] = np.eye(ell, dtype=bool)  # PAD class = identity
    return ParserMatrices(
        table=table,
        N=N,
        I=table.initial.copy(),
        F=table.final.copy(),
        byte_to_class=np.asarray(table.numbered.byte_to_class, dtype=np.int32),
    )


def pack_bits(mat: np.ndarray, axis: int = -1) -> np.ndarray:
    """Pack a boolean array along ``axis`` into uint32 words (little-endian bits)."""
    mat = np.moveaxis(np.asarray(mat, dtype=bool), axis, -1)
    n = mat.shape[-1]
    W = (n + 31) // 32
    padded = np.zeros(mat.shape[:-1] + (W * 32,), dtype=bool)
    padded[..., :n] = mat
    r = padded.reshape(mat.shape[:-1] + (W, 32))
    weights = (np.uint64(1) << np.arange(32, dtype=np.uint64)).astype(np.uint64)
    packed = (r.astype(np.uint64) * weights).sum(axis=-1).astype(np.uint32)
    return np.moveaxis(packed, -1, axis if axis >= 0 else len(packed.shape) + axis)


def unpack_bits(packed: np.ndarray, n: int, axis: int = -1) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    packed = np.moveaxis(np.asarray(packed, dtype=np.uint32), axis, -1)
    bits = (packed[..., :, None] >> np.arange(32, dtype=np.uint32)) & np.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (-1,))[..., :n].astype(bool)
    return np.moveaxis(flat, -1, axis if axis >= 0 else len(flat.shape) + axis)


def pack_transition_table(N: np.ndarray) -> np.ndarray:
    """``(A, ℓ, ℓ)`` bool → ``(A, ℓ, W)`` uint32 packed along the *row* (target) dim.

    ``N_packed[c, col]`` is the packed target set of source segment ``col`` — the
    transposed orientation needed by the OR-AND mat-vec (out = OR of rows of packed
    selected by the source vector's set bits).
    """
    return pack_bits(np.swapaxes(N, -1, -2), axis=-1)


def boolean_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Boolean-semiring product of (…, m, k) @ (…, k, n) boolean arrays."""
    return np.matmul(a.astype(np.uint8), b.astype(np.uint8)) > 0


def boolean_matvec(mat: np.ndarray, vec: np.ndarray) -> np.ndarray:
    return (mat.astype(np.uint8) @ vec.astype(np.uint8)) > 0


# ------------------------------------------------- jnp-side packed semiring
#
# Device-side (jit-traceable) counterparts of pack_bits/unpack_bits plus the
# Boolean OR-AND semiring evaluated directly on uint32 words — the compute
# layer of the "packed" ParserBackend (core/backend.py).
#
# Packed-matrix representation (the pack_transition_table orientation): a
# {0,1} matrix M (ℓp, ℓp) is stored as Q (ℓp, W) uint32 with W = ℓp/32 and
# bit b of Q[col, w] equal to M[32·w + b, col] — row ``col`` of Q is the
# packed *target* set of source segment ``col`` (little-endian bits along
# the row/target dim).  Every op below is pure word arithmetic (AND / OR /
# shift): a packed matmul is ℓp²·W word ops vs ℓp³ f32 MACs, and a packed
# product moves ℓp·W·4 = ℓp²/8 bytes vs ℓp²·4 — the 32× bandwidth cut on
# the SLPF path.

import jax
import jax.numpy as jnp

_WORD = 32
_SHIFTS = np.arange(_WORD, dtype=np.uint32)


def _or_reduce(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Bitwise-OR reduction along ``axis`` (uint32)."""
    axis = axis % x.ndim
    return jax.lax.reduce(x, jnp.uint32(0), jax.lax.bitwise_or, (axis,))


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp) {0,1} numeric → (…, ℓp/32) uint32 along the last axis.

    Device-side twin of :func:`pack_bits` (last axis only, ℓp % 32 == 0);
    bit-identical to the numpy packer and to ``backend.pack_columns_u32``.
    """
    n = bits.shape[-1]
    assert n % _WORD == 0, f"packed dim {n} must be a multiple of 32"
    r = bits.reshape(bits.shape[:-1] + (n // _WORD, _WORD)).astype(jnp.uint32)
    return _or_reduce(r << jnp.asarray(_SHIFTS), axis=-1)


def unpack_bits_jnp(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """(…, W) uint32 → (…, n) f32 {0,1} along the last axis (inverse pack)."""
    bits = (packed[..., :, None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (-1,))
    return flat[..., :n].astype(jnp.float32)


def pack_transition_table_jnp(N: jnp.ndarray) -> jnp.ndarray:
    """(…, ℓp, ℓp) {0,1} → (…, ℓp, W) uint32 packed along the row (target) dim.

    Device-side twin of :func:`pack_transition_table`: ``out[…, col]`` is the
    packed target set of source ``col`` — the packed-matrix representation of
    each leading-dim matrix.
    """
    return pack_bits_jnp(jnp.swapaxes(N, -1, -2))


def packed_identity(ell_pad: int) -> jnp.ndarray:
    """Packed identity matrix (ℓp, W): bit ``j`` set in row ``j``."""
    assert ell_pad % _WORD == 0
    j = jax.lax.broadcasted_iota(jnp.uint32, (ell_pad, ell_pad // _WORD), 0)
    w = jax.lax.broadcasted_iota(jnp.uint32, (ell_pad, ell_pad // _WORD), 1)
    return jnp.where(j // _WORD == w, jnp.uint32(1) << (j % _WORD), jnp.uint32(0))


def packed_semiring_matmul(later: jnp.ndarray, earlier: jnp.ndarray) -> jnp.ndarray:
    """OR-AND product ``later ⊗ earlier`` of packed matrices (…, ℓp, W).

    Column j of the result is the OR of ``later``'s rows selected by the set
    bits of ``earlier``'s column j:  Qc[j] = OR_k bit_k(Qe[j]) · Ql[k].  The
    contraction runs as a scan over 32-bit word blocks of k, so the live
    intermediate is (…, ℓp, 32, W) words = one f32 matrix's worth, never ℓp³.
    Leading batch dims broadcast like ``matmul`` (``associative_scan`` calls
    its combine on stacked blocks).
    """
    lp, W = later.shape[-2:]
    later, earlier = jnp.broadcast_arrays(later, earlier)
    batch = later.shape[:-2]
    blocks = later.reshape(batch + (W, _WORD, W))     # rows, k-word-grouped
    # scan over the k word-blocks: put that axis first
    words_seq = jnp.moveaxis(earlier, -1, 0)          # (W, …, ℓp)
    blocks_seq = jnp.moveaxis(blocks, -3, 0)          # (W, …, 32, W)

    def body(acc, xs):
        words, block = xs                             # (…, ℓp) · (…, 32, W)
        bits = (words[..., None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)
        mask = jnp.uint32(0) - bits                   # {0, 0xFFFFFFFF}
        sel = mask[..., :, None] & block[..., None, :, :]   # (…, ℓp, 32, W)
        return acc | _or_reduce(sel, axis=-2), None

    acc0 = jnp.zeros(batch + (lp, W), jnp.uint32)
    acc, _ = jax.lax.scan(body, acc0, (words_seq, blocks_seq))
    return acc


def _select_or(Q: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """OR of ``Q``'s rows (ℓp, W) selected by ``bits`` (ℓp,) {0,1} → (W,)."""
    mask = jnp.uint32(0) - bits.astype(jnp.uint32)
    return _or_reduce(mask[:, None] & Q, axis=0)


def packed_matvec(Q: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``M v`` with packed M: {0,1} f32 v (ℓp,) → {0,1} f32 (ℓp,).

    out = OR of the packed target rows whose source bit is set in v — the
    masked OR-reduction form of ``boolean_matvec`` (module docstring).
    """
    return unpack_bits_jnp(_select_or(Q, v > 0.5), Q.shape[0])


def packed_matvec_words(Q: jnp.ndarray, vp: jnp.ndarray) -> jnp.ndarray:
    """``M v`` staying packed: words vp (W,) → words (W,)."""
    bits = ((vp[:, None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)).reshape(-1)
    return _select_or(Q, bits)


def packed_matvec_T(Q: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``Mᵀ v`` with packed M: out[col] = 1 iff v hits any target of col.

    One AND + OR-reduce per row: out[col] = any(Q[col] & pack(v)) — the
    transposed mat-vec is *free* on the packed layout (no transpose pass).
    """
    vp = pack_bits_jnp(v)
    hits = _or_reduce(Q & vp[None, :], axis=1) != 0      # (ℓp,) bool
    return hits.astype(jnp.float32)


def packed_matvec_T_words(Q: jnp.ndarray, vp: jnp.ndarray) -> jnp.ndarray:
    """``Mᵀ v`` staying packed: words vp (W,) → words (W,)."""
    hits = _or_reduce(Q & vp[None, :], axis=1) != 0      # (ℓp,) bool
    return pack_bits_jnp(hits)
