"""The public parse API: one facade, one declarative config, one result type.

The paper's tool exposes parsing as ONE operation — text in, shared parse
forest with match/children/tree accessors out (Sect. 4.2 / App. A).  This
module is that surface for the whole runtime grown in PRs 1-4:

  ``ParserConfig``   a frozen, validated, dict-round-trippable description of
                     a parser: the RE, the phase backend (jnp / pallas /
                     packed, with the Pallas-kernel toggle), the chunk-split
                     and bucket policy (PaREM's chunk model: serial is
                     ``n_chunks=1``, chunked is ``n_chunks>1``, distributed
                     is ``mesh=``), streaming seal policy, admission budgets,
                     and SLO targets (per-bucket p50/p99 latency goals +
                     default deadline).

  ``Parser``         the facade.  Owns engine and service construction —
                     callers never assemble ``ParserEngine`` /
                     ``ParseService`` / ``StreamService`` by hand (direct
                     construction is deprecated).  One synchronous surface
                     (``parse`` / ``parse_batch``), one asynchronous
                     submission surface (``submit`` → ``ParseTicket``), one
                     streaming surface (``open_stream`` → ``ParserStream``),
                     and ``stats()`` aggregating both services plus SLO
                     conformance.

  ``ParseResult``    first-class result wrapping the ``SLPF``: ``ok``,
                     ``matches(group)``, ``children(span)``, ``trees(limit)``,
                     timing/backend metadata, and ``forest`` (the SLPF
                     itself) for everything forest-level.

  ``ParseTicket``    deadline-aware asynchronous handle: ``done()`` /
                     ``result()`` / ``cancel()``.  ``submit(text,
                     deadline_s=...)`` runs deadline-aware admission — a
                     request whose shape bucket's observed p99 latency
                     already exceeds the remaining deadline is rejected with
                     ``repro.errors.AdmissionError`` before any device work
                     (the ROADMAP SLO item; cold buckets predict 0.0 and
                     admit).

Every error is typed (``repro/errors.py``); every route stays bit-identical
to the direct engine paths (enforced by ``tests/test_conformance.py``, where
the facade is a first-class conformance route).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from .core.backend import ParserBackend, get_backend, list_backends, register_backend
from .core.engine import ParserEngine
from .core.matrices import ParserMatrices, build_matrices
from .core.segments import SegmentTable, compute_segments
from .core.slpf import SLPF
from .errors import (
    AdmissionError,
    BudgetExceeded,
    ParseError,
    PathologicalPatternError,
    SessionNotFound,
)
from .obs import ObsConfig, ObsHandle
from .serve.parse_service import ParseRequest, ParseService
from .serve.stream_service import StreamService

# Mesh axes of the declarative ``mesh="host"`` spec (launch/mesh.py's
# make_parse_mesh): chunks shard over 'pod', batch slots over 'data'.
_HOST_MESH_AXES = ("pod", "data")


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


# ------------------------------------------------------------------ config


@dataclasses.dataclass(frozen=True)
class SLOTargets:
    """Latency objectives applied per device-program bucket.

    ``p50_s``/``p99_s`` are the per-bucket targets ``Parser.stats()`` grades
    observed latency against; ``default_deadline_s`` is the admission
    deadline ``submit``/``append`` use when the caller passes none (None ⇒
    no implicit deadline — everything admits).
    """

    p50_s: Optional[float] = None
    p99_s: Optional[float] = None
    default_deadline_s: Optional[float] = None

    def __post_init__(self):
        for name in ("p50_s", "p99_s", "default_deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0.0:
                raise ValueError(f"SLOTargets.{name} must be positive, got {v!r}")
        if self.p50_s is not None and self.p99_s is not None and self.p50_s > self.p99_s:
            raise ValueError(
                f"SLOTargets.p50_s ({self.p50_s}) must not exceed p99_s ({self.p99_s})"
            )


@dataclasses.dataclass(frozen=True)
class ParserConfig:
    """Declarative, validated, dict-round-trippable parser description.

    Validation happens at construction (``__post_init__``) so an invalid
    config never reaches device code: unknown backend names, a kernel toggle
    on a backend without kernels, non-power-of-two bucket policy, mesh rules
    without a mesh, and mesh axes that cannot resolve on the declared mesh
    all raise ``ValueError`` immediately.

    ``to_dict()``/``from_dict()`` round-trip exactly (plain JSON-able
    values), and two Parsers built from a config and its round-trip produce
    bit-identical SLPFs (tested).
    """

    # what to parse
    regex: str
    # phase backend: a registered name, or "auto" — the static analyzer
    # (repro.analyze) picks dense/packed/sparse from the pattern's modeled
    # roofline before any device code; kernel=True selects the backend's
    # Pallas-kernel reach path where one exists (pallas is always kernels)
    backend: str = "jnp"
    kernel: bool = False
    # static-analysis admission policy: "warn" (default) analyzes the
    # pattern at construction and warns on pathological ambiguity, "strict"
    # rejects it with repro.errors.PathologicalPatternError, "off" skips the
    # construction-time analysis (stats()["analysis"] still computes lazily)
    analyze: str = "warn"
    # sparse backend only: feasible-prefix depth — how many leading chunk
    # characters prune the speculative start-state set (PaREM boundary info);
    # deeper prunes harder at the cost of d sequential mat-vecs per chunk
    feasible_depth: int = 1
    # chunk-split policy (PaREM's model): 1 = serial, >1 = chunked; the
    # bucket policy rounds chunk lengths to pow2 with this floor
    n_chunks: int = 8
    min_chunk_len: int = 8
    # batched serving
    max_batch: int = 8
    max_pending: Optional[int] = None
    # weighted-fair share when this config serves as a fleet tenant (or for
    # this parser's streams): scheduling vtime advances by chars/weight
    weight: float = 1.0
    # streaming seal/bucket policy (pow2 geometric sealing)
    first_seal_len: int = 8
    max_seal_len: Optional[int] = None
    cache_budget_bytes: Optional[int] = None
    max_pending_chars: Optional[int] = None
    # distribution: None = single device; "host" = a ('pod','data') mesh over
    # every visible device (launch/mesh.py make_parse_mesh).  mesh_rules maps
    # logical axes ('chunk', 'batch') to mesh axes; values must resolve on
    # the declared mesh.
    mesh: Optional[str] = None
    mesh_rules: Optional[Tuple[Tuple[str, Tuple[str, ...]], ...]] = None
    # service-level objectives (admission + stats grading)
    slo: Optional[SLOTargets] = None
    # observability (repro/obs): None = metrics only (tracing off); an
    # ObsConfig (or its dict) switches on spans / JSONL logs / profiler
    # annotations / per-bucket hlo_stats static cost in ``stats()``
    obs: Optional[ObsConfig] = None

    def __post_init__(self):
        if not isinstance(self.regex, str) or not self.regex:
            raise ValueError("ParserConfig.regex must be a non-empty pattern string")
        known = list_backends()
        if self.backend != "auto" and self.backend not in known:
            raise ValueError(
                f"unknown parse backend {self.backend!r}; known: "
                f"{known + ['auto']}"
            )
        if self.analyze not in ("off", "warn", "strict"):
            raise ValueError(
                f"analyze must be 'off', 'warn', or 'strict', got "
                f"{self.analyze!r}"
            )
        if self.kernel and self.backend == "jnp":
            raise ValueError(
                "kernel=True selects a Pallas kernel path; the 'jnp' backend "
                "has none (use backend='pallas' or backend='packed')"
            )
        if self.kernel and self.backend == "auto":
            raise ValueError(
                "kernel=True is a per-backend toggle; backend='auto' lets "
                "the analyzer choose — pick an explicit backend to force "
                "its kernel path"
            )
        if self.feasible_depth < 1:
            raise ValueError(
                f"feasible_depth must be >= 1, got {self.feasible_depth}"
            )
        if self.feasible_depth != 1 and self.backend not in ("sparse", "auto"):
            raise ValueError(
                "feasible_depth tunes the sparse backend's start-state "
                f"pruning; backend {self.backend!r} has no speculation to "
                "prune (use backend='sparse')"
            )
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {self.n_chunks}")
        for name in ("min_chunk_len", "first_seal_len"):
            v = getattr(self, name)
            if not _is_pow2(v):
                raise ValueError(
                    f"{name} must be a power of two (the bucket policy "
                    f"compiles one program per pow2 shape), got {v}"
                )
        if self.max_seal_len is not None and not _is_pow2(self.max_seal_len):
            raise ValueError(
                f"max_seal_len must be a power of two, got {self.max_seal_len}"
            )
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        for name in ("max_pending", "cache_budget_bytes", "max_pending_chars"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} must be positive or None, got {v}")
        if self.mesh not in (None, "host"):
            raise ValueError(
                f"mesh must be None (single device) or 'host' (a "
                f"{_HOST_MESH_AXES} mesh over every device), got {self.mesh!r}"
            )
        # normalize mesh_rules: accept a mapping / iterable of pairs; store a
        # canonical hashable tuple-of-pairs with tuple axis values
        if self.mesh_rules is not None:
            if self.mesh is None:
                raise ValueError("mesh_rules requires mesh to be set")
            items = (
                self.mesh_rules.items()
                if isinstance(self.mesh_rules, Mapping)
                else self.mesh_rules
            )
            norm = []
            for name, axes in items:
                if axes is None:
                    axes_t: Tuple[str, ...] = ()
                elif isinstance(axes, str):
                    axes_t = (axes,)
                else:
                    axes_t = tuple(axes)
                for a in axes_t:
                    if a not in _HOST_MESH_AXES:
                        raise ValueError(
                            f"mesh_rules[{name!r}] names mesh axis {a!r} which "
                            f"does not resolve on the declared mesh (axes: "
                            f"{_HOST_MESH_AXES})"
                        )
                norm.append((str(name), axes_t))
            object.__setattr__(self, "mesh_rules", tuple(sorted(norm)))
        if self.slo is not None and isinstance(self.slo, Mapping):
            object.__setattr__(self, "slo", SLOTargets(**dict(self.slo)))
        if self.obs is not None and isinstance(self.obs, Mapping):
            object.__setattr__(self, "obs", ObsConfig(**dict(self.obs)))

    # ------------------------------------------------------- dict round-trip

    def to_dict(self) -> Dict[str, Any]:
        """Plain JSON-able dict; ``from_dict`` round-trips it exactly."""
        d = dataclasses.asdict(self)
        if self.mesh_rules is not None:
            d["mesh_rules"] = {name: list(axes) for name, axes in self.mesh_rules}
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ParserConfig":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown ParserConfig keys: {sorted(unknown)}")
        return cls(**d)

    def replace(self, **kw) -> "ParserConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- builders

    def build_backend(self, resolved: Optional[str] = None) -> ParserBackend:
        """Instantiate the configured phase backend (kernel toggle applied).

        ``resolved`` supplies the analyzer's choice when this config says
        ``backend="auto"`` (the facade passes it); "auto" itself is not
        instantiable."""
        from .core.backend import PackedBackend, SparseBackend

        name = resolved if resolved is not None else self.backend
        if name == "auto":
            raise ValueError(
                'backend="auto" resolves through the static analyzer; '
                "build_backend needs the resolved name (use repro.analyze."
                "resolve_auto_backend or construct a Parser)"
            )
        if name == "sparse":
            return SparseBackend(kernel=self.kernel, depth=self.feasible_depth)
        if name == "packed" and self.kernel:
            return PackedBackend(kernel=True)
        return get_backend(name)

    def build_mesh(self):
        """The declared device mesh, or None on a single-device config."""
        if self.mesh is None:
            return None
        from .launch.mesh import make_parse_mesh

        return make_parse_mesh()

    def build_mesh_rules(self):
        """``MeshRules`` with this config's overrides, or None for defaults."""
        if self.mesh_rules is None:
            return None
        from .parallel.sharding import MeshRules

        overrides = {
            name: (axes if len(axes) != 1 else axes[0]) or None
            for name, axes in self.mesh_rules
        }
        return MeshRules().with_overrides(**overrides)


# ------------------------------------------------------------------ results


@dataclasses.dataclass
class ParseResult:
    """First-class parse result: the forest plus accessors and metadata.

    The forest-level query API of the paper's tool (Sect. 4.2 / App. A)
    lives here; anything deeper (arcs, packing, compression) is reachable
    through ``forest`` — the ``SLPF`` itself.
    """

    forest: SLPF
    backend: str
    bucket: Optional[Tuple[int, int]] = None
    latency_s: Optional[float] = None
    n_chunks: Optional[int] = None
    # sparse backend only: the observed speculation width of this parse —
    # per-chunk feasible-start-set sizes vs the ℓp the paper speculates on
    # ({"width_mean", "width_max", "n_chunks_real", "product_rows",
    #   "ell_pad", "depth"}); None on dense backends
    speculation: Optional[Dict[str, Any]] = None
    # the request's trace ID when the parser's tracer is enabled — the key
    # into the span log (obs/export.py validate_span_tree); else None
    trace_id: Optional[str] = None

    # ------------------------------------------------------------- queries

    @property
    def ok(self) -> bool:
        """Did the text match the RE (non-empty clean forest)?"""
        return self.forest.accepted

    @property
    def slpf(self) -> SLPF:
        """Alias of ``forest`` (the shared linearized parse forest)."""
        return self.forest

    def count_trees(self) -> int:
        return self.forest.count_trees()

    def matches(self, group: int, limit: Optional[int] = 1000) -> List[Tuple[int, int]]:
        """(start, end) spans of a numbered group / operator pair (App. A)."""
        return self.forest.get_matches(group, limit=limit)

    def children(
        self, span: Tuple[int, int], limit: Optional[int] = 1000
    ) -> List[Tuple[int, int, int]]:
        """Direct child spans of a match span, from the tree structure.

        For each LST (up to ``limit``) containing a paren pair matching
        ``span`` exactly, collect the (group, start, end) pairs DIRECTLY
        nested under it (paper ``getChildren``).  The paren nesting stack is
        walked per tree, so only immediate children are reported — not every
        transitively contained span.
        """
        from .core.numbering import CLOSE, OPEN

        span = (int(span[0]), int(span[1]))
        syms = self.forest.table.numbered.symbols
        out: Dict[Tuple[int, int, int], None] = {}
        for path in self.forest.iter_trees(limit=limit):
            # stack entries: [group num, start boundary, collected children]
            stack: List[List[Any]] = []
            for r, q in enumerate(path):
                for sid in self.forest.table.segs[q][:-1]:
                    s = syms[sid]
                    if s.kind == OPEN:
                        stack.append([s.num, r, []])
                    elif s.kind == CLOSE:
                        num, st, kids = stack.pop()
                        if stack:
                            stack[-1][2].append((num, st, r))
                        if (st, r) == span:
                            for kid in kids:
                                out[kid] = None
        return sorted(out)

    def trees(self, limit: Optional[int] = None, *, paths: bool = False) -> List:
        """Up to ``limit`` LSTs — rendered parenthesized strings by default,
        raw segment-id paths with ``paths=True``."""
        if paths:
            return list(self.forest.iter_trees(limit=limit))
        return [
            self.forest.lst_string(p) for p in self.forest.iter_trees(limit=limit)
        ]


# ------------------------------------------------------------------ tickets


class ParseTicket:
    """Asynchronous handle for one submitted parse (``Parser.submit``).

    The underlying request is already past deadline-aware admission; the
    ticket resolves it: ``done()`` is a free check, ``result()`` drives the
    service until THIS request is served (batching with whatever else is
    queued) and returns the ``ParseResult``, ``cancel()`` drops it from the
    queue if no batch has picked it up yet.
    """

    def __init__(
        self,
        parser: "Parser",
        service: ParseService,
        request: ParseRequest,
        deadline_s: Optional[float] = None,
    ):
        self._parser = parser
        self._service = service
        self._request = request
        self._result: Optional[ParseResult] = None
        self._cancelled = False
        self.deadline_s = deadline_s   # the admitted remaining budget

    @property
    def rid(self) -> int:
        return self._request.rid

    def done(self) -> bool:
        return self._request.done

    def cancel(self) -> bool:
        """Drop the request if it has not been served; True on success."""
        if self._request.done:
            return False
        self._cancelled = self._service.cancel(self._request.rid)
        return self._cancelled

    def result(self) -> ParseResult:
        """Serve (if needed) and return the result; raises on a cancelled
        ticket."""
        if self._result is not None:
            return self._result
        if self._cancelled:
            raise ParseError(f"parse request {self._request.rid} was cancelled")
        while not self._request.done:
            if not self._service.step():
                raise ParseError(
                    f"parse request {self._request.rid} is no longer queued"
                )
        self._service.reap(self._request)
        req = self._request
        if req.trace_id is not None:
            # the root span closes here — collection ends the request's
            # lifetime; queue-wait/compute children were emitted at pickup
            # against the pre-minted root id
            self._parser.engine.obs.emit(
                "parse.request",
                t_start_s=req.submitted_at,
                duration_s=req.latency_s,
                trace_id=req.trace_id,
                span_id=req.root_span_id,
                bucket=list(req.bucket) if req.bucket else None,
                n_chars=len(req.classes) if req.classes is not None else 0,
            )
        self._result = self._parser._wrap(
            req.slpf,
            bucket=req.bucket,
            latency_s=req.latency_s,
            trace_id=req.trace_id,
            tenant=req.tenant,
        )
        return self._result


# ------------------------------------------------------------------ streams


class ParserStream:
    """One streaming session of ``Parser.open_stream`` (context manager).

    Appends go through the shared ``StreamService`` — concurrent sessions
    batch their tail pieces into one device reach — and carry the same
    deadline-aware admission as ``submit``.  ``result()`` materializes the
    current prefix's ``ParseResult``; ``accepted`` is the O(1) streaming
    acceptance state.
    """

    def __init__(self, parser: "Parser", service: StreamService, sid: int):
        self._parser = parser
        self._service = service
        self._sid = sid
        self._closed = False

    @property
    def sid(self) -> int:
        return self._sid

    @property
    def n(self) -> int:
        """Characters absorbed into the prefix so far (queued appends not
        yet drained are excluded)."""
        return self._service._session(self._sid).parser.n

    @property
    def n_sealed_chunks(self) -> int:
        """Sealed chunk products resident in this stream's prefix cache."""
        return self._service._session(self._sid).parser.n_sealed_chunks

    def append(self, text, *, deadline_s: Optional[float] = None) -> int:
        """Queue text onto this stream; returns chars queued (admission may
        raise ``AdmissionError``/``BudgetExceeded``)."""
        if deadline_s is None:
            deadline_s = self._parser._default_deadline_s()
        return self._service.append(self._sid, text, deadline_s=deadline_s)

    @property
    def accepted(self) -> bool:
        """Is the current prefix a valid text (drains this session only)?"""
        return self._service.accepted(self._sid)

    def edit(self, lo: int, hi: int, replacement) -> int:
        """Splice the prefix: replace characters ``[lo, hi)`` with
        ``replacement``; returns the new prefix length.

        O(log n) device work — the stream's product segment tree re-reaches
        only the spliced chunks and re-composes one leaf-to-root path; the
        result is bit-identical to a cold parse of the edited text.  Drains
        this session's queued appends first (the range addresses the
        post-append prefix).
        """
        return self._service.edit(self._sid, lo, hi, replacement)

    def delete(self, lo: int, hi: int) -> int:
        """Remove characters ``[lo, hi)`` — ``edit`` with an empty
        replacement."""
        return self._service.edit(self._sid, lo, hi, "")

    def insert(self, pos: int, text) -> int:
        """Insert ``text`` before position ``pos`` — a zero-width
        ``edit``."""
        return self._service.edit(self._sid, pos, pos, text)

    def result(self) -> ParseResult:
        """ParseResult of the full current prefix (drains this session)."""
        t0 = time.perf_counter()
        slpf = self._service.slpf(self._sid)
        return self._parser._wrap(slpf, latency_s=time.perf_counter() - t0)

    def close(self) -> None:
        if not self._closed:
            self._service.close(self._sid)
            self._closed = True

    def __enter__(self) -> "ParserStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------------------- facade


class Parser:
    """The one public parser: built from a ``ParserConfig`` (or a pattern).

        p = repro.Parser("(a|b|ab)+")             # defaults
        p = repro.Parser(ParserConfig(regex=..., backend="packed",
                                      mesh="host", slo=SLOTargets(...)))

    Owns every lower layer: the ``ParserEngine`` (backend, bucket policy,
    mesh placement), a lazy ``ParseService`` (batched one-shot requests) and
    a lazy ``StreamService`` (streaming sessions) — both over the SAME
    engine, so all routes share one compiled-program set.  ``stats()``
    aggregates both services plus SLO conformance.
    """

    def __init__(
        self,
        config: Union[ParserConfig, str, Mapping[str, Any]],
        *,
        matrices: Optional[ParserMatrices] = None,
    ):
        if isinstance(config, str):
            config = ParserConfig(regex=config)
        elif isinstance(config, Mapping):
            config = ParserConfig.from_dict(config)
        if not isinstance(config, ParserConfig):
            raise TypeError(
                f"Parser takes a ParserConfig, a pattern string, or a config "
                f"dict; got {type(config).__name__}"
            )
        self.config = config
        if matrices is None:
            matrices = build_matrices(compute_segments(config.regex))
        self.matrices = matrices
        # one ObsHandle for the whole parser: the engine carries it, every
        # layer (services, streams, distribution) records into it
        self.obs = ObsHandle.from_config(config.obs)
        # static analysis (repro.analyze leg 1): runs at construction when
        # the config wants a verdict (analyze != "off") or needs one
        # (backend == "auto"); otherwise stats()["analysis"] computes lazily
        self._analysis = None
        resolved = config.backend
        if config.backend == "auto" or config.analyze != "off":
            report = self._analyze()
            m = self.obs.metrics
            m.counter("analyzer_verdicts_total", verdict=report.verdict).inc()
            if report.verdict == "pathological":
                if config.analyze == "strict":
                    m.counter(
                        "admission_rejects_total",
                        service="analyze",
                        cause="pathological",
                    ).inc()
                    raise PathologicalPatternError(
                        f"pattern {config.regex!r} is pathologically "
                        "ambiguous (an iterator with a nullable body admits "
                        "unboundedly many parse trees per text); "
                        'analyze="strict" rejects it at construction',
                        pattern=config.regex,
                        ambiguity=report.ambiguity,
                    )
                if config.analyze == "warn":
                    warnings.warn(
                        f"repro: pattern {config.regex!r} is pathologically "
                        "ambiguous — forest size is unbounded per text "
                        '(analyze="strict" rejects such patterns)',
                        UserWarning,
                        stacklevel=2,
                    )
            if config.backend == "auto":
                resolved = report.recommended_backend
                m.counter("auto_backend_selected_total", backend=resolved).inc()
        self._resolved_backend = resolved
        self.engine = ParserEngine(
            matrices,
            backend=config.build_backend(resolved),
            min_chunk_len=config.min_chunk_len,
            mesh=config.build_mesh(),
            mesh_rules=config.build_mesh_rules(),
            obs=self.obs,
        )
        self._parse_service: Optional[ParseService] = None
        self._stream_service: Optional[StreamService] = None
        self._artifacts = None
        # per-bucket observed speculation widths (sparse backend only)
        self._spec_buckets: Dict[Tuple[int, int], Dict[str, Any]] = {}

    @classmethod
    def from_matrices(
        cls,
        matrices_or_table: Union[ParserMatrices, SegmentTable],
        config: Union[ParserConfig, str, Mapping[str, Any], None] = None,
    ) -> "Parser":
        """Build a Parser over pre-generated matrices / a segment table.

        The advanced entry point for parsers whose RE exists only as an AST
        or whose tables were generated elsewhere; ``config.regex`` is then
        informational.  ``config`` defaults to the given pattern-less
        defaults.
        """
        if isinstance(matrices_or_table, SegmentTable):
            matrices_or_table = build_matrices(matrices_or_table)
        if config is None:
            config = ParserConfig(regex="<prebuilt>")
        elif isinstance(config, str):
            config = ParserConfig(regex=config)
        elif isinstance(config, Mapping):
            config = ParserConfig.from_dict(config)
        return cls(config, matrices=matrices_or_table)

    # ------------------------------------------------------------- plumbing

    @property
    def backend_name(self) -> str:
        return self.engine.backend.name

    @property
    def compile_count(self) -> int:
        return self.engine.compile_count

    def _analyze(self):
        if self._analysis is None:
            from .analyze import analyze_matrices

            # from_matrices parsers carry a placeholder pattern: analyze the
            # automaton alone (the AST legs fall back to matrix facts)
            pattern = self.config.regex
            if pattern == "<prebuilt>":
                pattern = None
            self._analysis = analyze_matrices(
                self.matrices,
                pattern=pattern,
                depth=max(4, self.config.feasible_depth),
            )
        return self._analysis

    @property
    def analysis(self):
        """The static ``AnalysisReport`` (``repro.analyze`` leg 1), memoized:
        feasible-start width bounds, ambiguity verdict, product density, the
        per-backend cost model and the recommended backend."""
        return self._analyze()

    @property
    def table(self) -> SegmentTable:
        return self.engine.table

    @property
    def artifacts(self):
        """Full ``ParallelArtifacts`` (NFA/DFA/ME-DFA…) for introspection.

        Built lazily — parsing never needs the exponential DFA, only the
        matrices — and only constructible when the config carries a real
        pattern (not ``from_matrices``' placeholder)."""
        if self._artifacts is None:
            from .core.reference import ParallelArtifacts

            self._artifacts = ParallelArtifacts.generate(self.matrices.table)
        return self._artifacts

    @property
    def groups(self) -> List[int]:
        """Numbered group ids extractable via ``ParseResult.matches``."""
        from .core.numbering import OPEN, OP_GROUP

        return sorted(
            {
                s.num
                for s in self.table.numbered.symbols
                if s.kind == OPEN and s.op == OP_GROUP
            }
        )

    def _default_deadline_s(self) -> Optional[float]:
        slo = self.config.slo
        return slo.default_deadline_s if slo is not None else None

    def _speculation(
        self, slpf: SLPF, bucket: Optional[Tuple[int, int]]
    ) -> Optional[Dict[str, Any]]:
        """Observed speculation width of one parse (sparse backend only).

        Recomputes, host-side, the feasible-start-set size of each chunk the
        engine's bucket policy produced for this text — the states a chunk
        processor actually speculates on vs the paper's ℓp.  All-PAD padding
        chunks carry no speculation and are excluded.
        """
        if self.backend_name != "sparse":
            return None
        from .core.matrices import feasible_start_widths

        eng = self.engine
        classes = slpf.classes
        c, k = bucket if bucket is not None else eng.bucket_shape(
            len(classes), self.config.n_chunks
        )
        chunks = np.asarray(eng._pad_to(classes, c, k)).reshape(c, k)
        widths = feasible_start_widths(
            eng.tables.N, chunks, depth=self.config.feasible_depth
        )
        real = widths[widths >= 0]
        spec = {
            "width_mean": float(real.mean()) if real.size else 0.0,
            "width_max": int(real.max()) if real.size else 0,
            "n_chunks_real": int(real.size),
            "product_rows": int(eng.backend._width),
            "ell_pad": int(eng.tables.ell_pad),
            "depth": self.config.feasible_depth,
        }
        agg = self._spec_buckets.setdefault(
            (c, k), {"parses": 0, "width_mean": 0.0, "width_max": 0}
        )
        agg["parses"] += 1
        agg["width_mean"] += (spec["width_mean"] - agg["width_mean"]) / agg["parses"]
        agg["width_max"] = max(agg["width_max"], spec["width_max"])
        self.obs.metrics.histogram("speculation_width").observe(spec["width_max"])
        return spec

    def _wrap(
        self,
        slpf: SLPF,
        *,
        bucket: Optional[Tuple[int, int]] = None,
        latency_s: Optional[float] = None,
        trace_id: Optional[str] = None,
        tenant: Optional[str] = None,  # ticket plumbing; one-automaton
                                       # parsers have nothing per-tenant
    ) -> ParseResult:
        return ParseResult(
            forest=slpf,
            backend=self.backend_name,
            bucket=bucket,
            latency_s=latency_s,
            n_chunks=self.config.n_chunks,
            speculation=self._speculation(slpf, bucket),
            trace_id=trace_id,
        )

    @property
    def parse_service(self) -> ParseService:
        """The batched request service (built lazily, facade-owned)."""
        if self._parse_service is None:
            c = self.config
            self._parse_service = ParseService._internal(
                self.engine,
                max_batch=c.max_batch,
                n_chunks=c.n_chunks,
                max_pending=c.max_pending,
            )
            # the facade's traffic is one tenant; its weight only matters
            # when sharing a queue (tests / embedders may add more)
            self._parse_service.register_tenant("default", weight=c.weight)
            self._parse_service.set_pattern_guard(
                self._analysis.verdict if self._analysis is not None else "ok",
                c.analyze,
            )
        return self._parse_service

    @property
    def stream_service(self) -> StreamService:
        """The streaming session service (built lazily, facade-owned)."""
        if self._stream_service is None:
            c = self.config
            self._stream_service = StreamService._internal(
                self.engine,
                max_batch=c.max_batch,
                first_seal_len=c.first_seal_len,
                max_seal_len=c.max_seal_len,
                cache_budget_bytes=c.cache_budget_bytes,
                max_pending_chars=c.max_pending_chars,
            )
            self._stream_service.set_pattern_guard(
                self._analysis.verdict if self._analysis is not None else "ok",
                c.analyze,
            )
        return self._stream_service

    # ---------------------------------------------------------------- parse

    def submit(
        self, text, *, deadline_s: Optional[float] = None
    ) -> ParseTicket:
        """Deadline-aware asynchronous submission; returns a ``ParseTicket``.

        Admission runs NOW: a bucket whose observed p99 exceeds the
        remaining ``deadline_s`` raises ``AdmissionError`` (typed, before
        any queueing); ``max_pending`` overflow raises ``BudgetExceeded``.
        No deadline (and no config default) admits unconditionally.
        """
        if deadline_s is None:
            deadline_s = self._default_deadline_s()
        svc = self.parse_service
        req = svc.submit_request(text, deadline_s=deadline_s)
        return ParseTicket(self, svc, req, deadline_s=deadline_s)

    def parse(self, text, *, deadline_s: Optional[float] = None) -> ParseResult:
        """Parse one text synchronously through the same admission path as
        ``submit`` (stats/SLO observe it).

        On a mesh config this is the long-text route: the engine's
        single-text distributed program shards the chunk dim over EVERY
        chunk mesh axis ('pod' × 'data') — ``parse_batch`` instead keeps
        batch slots over 'data' and chunks over 'pod'.

        With tracing on (``ParserConfig(obs=ObsConfig(enabled=True))``)
        the call runs queue-free through the engine's phase-split route
        (bit-identical to the fused program) so the span log carries one
        ``parse.request`` root with real per-phase children.
        """
        if self.obs.enabled or self.engine.mesh is not None:
            from .serve.parse_service import BucketStats

            if deadline_s is None:
                deadline_s = self._default_deadline_s()
            svc = self.parse_service
            classes = self.engine.classes_of_text(text)
            bucket = self.engine.bucket_shape(len(classes), self.config.n_chunks)
            svc._admit(bucket, deadline_s)
            stats = svc._buckets.setdefault(bucket, BucketStats())
            obs = self.obs
            trace_id = obs.new_trace_id()
            t0 = time.perf_counter()
            if obs.enabled:
                with obs.span(
                    "parse.request",
                    trace_id=trace_id,
                    bucket=list(bucket),
                    backend=self.backend_name,
                    n_chars=len(classes),
                ):
                    slpf = self.engine.parse_traced(
                        classes, n_chunks=self.config.n_chunks
                    )
            else:
                slpf = self.engine.parse(classes, n_chunks=self.config.n_chunks)
            latency = time.perf_counter() - t0
            # admission/SLO learn this route too; it never queues, so the
            # whole latency is compute
            stats.record(latency, queue_s=0.0, compute_s=latency)
            m = obs.metrics
            m.counter("requests_total", service="parse").inc()
            m.counter("served_total", service="parse").inc()
            m.counter("chars_total", service="parse").inc(len(classes))
            return self._wrap(
                slpf, bucket=bucket, latency_s=latency, trace_id=trace_id
            )
        return self.submit(text, deadline_s=deadline_s).result()

    def parse_batch(
        self, texts: Sequence, *, deadline_s: Optional[float] = None
    ) -> List[ParseResult]:
        """Parse many texts through the bucket-batched service; results are
        returned in input order.

        Admission is all-or-nothing: if any text is rejected
        (``AdmissionError``/``BudgetExceeded``), the already-queued ones are
        cancelled before the error propagates — no orphaned requests are
        left consuming the queue budget.
        """
        tickets: List[ParseTicket] = []
        try:
            for t in texts:
                tickets.append(self.submit(t, deadline_s=deadline_s))
        except Exception:
            for ticket in tickets:
                ticket.cancel()
            raise
        return [t.result() for t in tickets]

    def open_stream(self, *, weight: Optional[float] = None) -> ParserStream:
        """Open a streaming session (incremental appends over the shared
        prefix-cache service); close it with ``.close()`` / ``with``.

        ``weight`` sets the session's weighted-fair share of the service's
        batched absorption (default: the config's ``weight``)."""
        w = self.config.weight if weight is None else weight
        return ParserStream(
            self, self.stream_service, self.stream_service.open(weight=w)
        )

    def count_accepting(self, text) -> int:
        return self.parse(text).count_trees()

    # ---------------------------------------------------------------- stats

    def _slo_grade(self, buckets: Mapping) -> Dict[Any, Dict[str, Any]]:
        slo = self.config.slo
        out: Dict[Any, Dict[str, Any]] = {}
        for bucket, b in buckets.items():
            grade: Dict[str, Any] = {
                "p50_s": b["p50_latency_s"],
                "p99_s": b["p99_latency_s"],
                "queue_depth": b["queue_depth"],
            }
            if slo is not None and slo.p50_s is not None:
                grade["p50_ok"] = b["p50_latency_s"] <= slo.p50_s
            if slo is not None and slo.p99_s is not None:
                grade["p99_ok"] = b["p99_latency_s"] <= slo.p99_s
            out[bucket] = grade
        return out

    def _hlo_static_cost(self, ps: Optional[Dict]) -> Optional[Dict[str, Any]]:
        """Per-bucket static modeled cost (``launch/hlo_stats.py``) of the
        compiled phase programs — attached only when tracing is on and the
        ObsConfig keeps ``hlo`` enabled (one extra lowering per bucket,
        memoized on the engine).  Mesh engines skip it: their phases fuse
        inside one shard_map program with no per-phase HLO to attribute."""
        cfg = self.obs.config
        if not (self.obs.enabled and cfg.hlo) or self.engine.mesh is not None:
            return None
        buckets = ps["buckets"] if ps else {}
        out: Dict[str, Any] = {}
        for bucket in buckets:
            c, k = bucket
            out[f"{c}x{k}"] = self.engine.phase_static_cost(c, k)
        return out

    def stats(self) -> Dict[str, Any]:
        """One aggregated view over both services + SLO conformance.

        ``parse``/``stream`` are the raw service stats (present once the
        corresponding service has been touched); ``metrics`` is the
        registry snapshot — the counter/gauge/histogram source of truth the
        service dicts are views over; ``hlo`` (tracing on, single-device)
        attaches each compiled bucket's static phase cost; ``slo.buckets``
        grades every observed bucket against the config targets
        (``p50_ok``/``p99_ok`` appear only when targets are set);
        ``speculation`` (sparse backend only, else None) reports the carried
        product rows S vs ℓp and the per-bucket observed feasible-start
        widths (mean/max over parses); ``analysis`` is the static analyzer's
        report (``repro.analyze``: width bounds, ambiguity verdict, density,
        per-backend cost model, recommended backend), computed lazily and
        memoized — the typed ``AnalysisReport`` is on ``Parser.analysis``.
        """
        slo = self.config.slo
        # evaluate each service's stats property ONCE: it rebuilds the full
        # dict (queue scan + percentile windows), and two reads could even
        # disagree if the queue moves between them
        ps = self._parse_service.stats if self._parse_service is not None else None
        ss = self._stream_service.stats if self._stream_service is not None else None
        if self.backend_name == "sparse":
            speculation: Optional[Dict[str, Any]] = {
                "product_rows": int(self.engine.backend._width),
                "ell_pad": int(self.engine.tables.ell_pad),
                "depth": self.config.feasible_depth,
                "buckets": {b: dict(v) for b, v in self._spec_buckets.items()},
            }
        else:
            speculation = None
        return {
            "backend": self.backend_name,
            "compile_count": self.compile_count,
            "pending": (ps["pending"] if ps else 0) + (ss["pending"] if ss else 0),
            "parse": ps,
            "stream": ss,
            "metrics": self.obs.metrics.snapshot(),
            "hlo": self._hlo_static_cost(ps),
            "analysis": self._analyze().to_dict(),
            "speculation": speculation,
            "slo": {
                "targets": dataclasses.asdict(slo) if slo is not None else None,
                "parse_buckets": self._slo_grade(ps["buckets"] if ps else {}),
                "stream_buckets": self._slo_grade(ss["buckets"] if ss else {}),
            },
        }

    def close(self) -> None:
        """Flush observability sinks (the JSONL span log, if configured)."""
        self.obs.close()

    def __enter__(self) -> "Parser":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# -------------------------------------------------------------------- fleet


class ParserFleet:
    """Many regexes, one engine pool: the multi-tenant facade.

        fleet = repro.ParserFleet({
            "errors":  "ERROR: .*",
            "api":     ParserConfig(regex="GET /[a-z]+", weight=2.0),
        })
        fleet.parse("errors", line).ok
        fleet.parse_batch([("errors", l1), ("api", l2), ...])

    Each tenant is a ``ParserConfig`` (or pattern string / config dict) —
    the same declarative surface as ``Parser`` — but instead of one engine
    per config, every tenant's transition tables are padded into a shared
    pow2 automaton bucket (``core/fleet.py``) and served by ONE
    tenant-batched device program per bucket: compile count and launch
    overhead scale with the number of (backend, ℓp-bucket) pairs, not
    tenants, while every result stays bit-identical to that tenant's solo
    ``Parser``.  Table builds go through a process-wide compile cache keyed
    on (normalized regex, backend, ℓp-bucket) — fleets, or re-added
    tenants, sharing a pattern never recompile it.

    Serving is the weighted-fair scheduler (``FleetParseService``): each
    tenant's ``ParserConfig.weight`` is its fair share, ``max_pending`` its
    private queue budget, ``slo`` its own grading targets in ``stats()``.
    """

    def __init__(
        self,
        tenants: Optional[Mapping[str, Union[ParserConfig, str, Mapping[str, Any]]]] = None,
        *,
        max_batch: int = 32,
        max_pending: Optional[int] = None,
        obs: Union[ObsConfig, Mapping[str, Any], None] = None,
    ):
        from .core.fleet import FleetEngine
        from .serve.parse_service import FleetParseService

        if obs is not None and isinstance(obs, Mapping):
            obs = ObsConfig(**dict(obs))
        self.obs = ObsHandle.from_config(obs)
        self.engine = FleetEngine(obs=self.obs)
        self._service = FleetParseService._internal(
            self.engine, max_batch=max_batch, max_pending=max_pending
        )
        self._configs: Dict[str, ParserConfig] = {}
        # tenant -> backend actually served (backend="auto" resolved)
        self._backends: Dict[str, str] = {}
        for name, cfg in (tenants or {}).items():
            self.add(name, cfg)

    # ---------------------------------------------------------------- tenants

    def add(
        self,
        name: str,
        config: Union[ParserConfig, str, Mapping[str, Any]],
        *,
        matrices: Optional[ParserMatrices] = None,
    ) -> "ParserFleet":
        """Register a tenant (chainable).  ``matrices`` bypasses the regex
        compile path for pre-built tables (``Parser.from_matrices`` analog)."""
        from .core.fleet import TenantSpec

        if isinstance(config, str):
            config = ParserConfig(regex=config)
        elif isinstance(config, Mapping):
            config = ParserConfig.from_dict(config)
        if not isinstance(config, ParserConfig):
            raise TypeError(
                f"fleet tenant config must be a ParserConfig, pattern string, "
                f"or config dict; got {type(config).__name__}"
            )
        if config.mesh is not None:
            raise ValueError(
                "fleet tenants run on the shared single-device engine pool; "
                "mesh configs are not supported (use a dedicated Parser)"
            )
        # static analysis at admission (repro.analyze leg 1): same policy as
        # Parser construction, but the reject is an ADMISSION event — the
        # fleet keeps serving its other tenants
        if config.analyze != "off" and matrices is None:
            from .analyze.pattern import cached_report

            report = cached_report(
                config.regex, max(4, config.feasible_depth)
            )
            m = self.obs.metrics
            m.counter("analyzer_verdicts_total", verdict=report.verdict).inc()
            if report.verdict == "pathological":
                if config.analyze == "strict":
                    m.counter(
                        "admission_rejects_total",
                        service="fleet",
                        cause="pathological",
                    ).inc()
                    raise PathologicalPatternError(
                        f"fleet tenant {name!r}: pattern {config.regex!r} is "
                        "pathologically ambiguous (an iterator with a "
                        "nullable body admits unboundedly many parse trees "
                        'per text); analyze="strict" rejects it at admission',
                        pattern=config.regex,
                        ambiguity=report.ambiguity,
                    )
                warnings.warn(
                    f"repro: fleet tenant {name!r} pattern {config.regex!r} "
                    "is pathologically ambiguous — forest size is unbounded "
                    'per text (analyze="strict" rejects such tenants)',
                    UserWarning,
                    stacklevel=2,
                )
        spec = TenantSpec(
            regex=config.regex,
            backend=config.backend,
            kernel=config.kernel,
            feasible_depth=config.feasible_depth,
            n_chunks=config.n_chunks,
            min_chunk_len=config.min_chunk_len,
            weight=config.weight,
            max_pending=config.max_pending,
        )
        self._service.add_tenant(name, spec, matrices=matrices)
        self._configs[name] = config
        # the engine resolves backend="auto" (core/fleet.py) — record what
        # this tenant actually runs on for stats()/results
        self._backends[name] = self.engine.tenant(name).spec.backend
        return self

    @property
    def tenants(self) -> Dict[str, ParserConfig]:
        return dict(self._configs)

    def config_of(self, tenant: str) -> ParserConfig:
        try:
            return self._configs[tenant]
        except KeyError:
            raise KeyError(f"unknown fleet tenant {tenant!r}") from None

    def groups_of(self, tenant: str) -> List[int]:
        """Numbered group ids of one tenant's pattern (``Parser.groups``
        analog), usable with ``ParseResult.matches``."""
        from .core.numbering import OPEN, OP_GROUP

        table = self.engine.tenant(tenant).tables.matrices.table
        return sorted(
            {
                s.num
                for s in table.numbered.symbols
                if s.kind == OPEN and s.op == OP_GROUP
            }
        )

    # ------------------------------------------------------------------ parse

    def _default_deadline_s(self, tenant: str) -> Optional[float]:
        slo = self.config_of(tenant).slo
        return slo.default_deadline_s if slo is not None else None

    def submit(
        self, tenant: str, text, *, deadline_s: Optional[float] = None
    ) -> ParseTicket:
        """Deadline-aware asynchronous submission for one tenant — the same
        admission contract as ``Parser.submit`` plus the tenant's own
        ``max_pending`` budget (``BudgetExceeded``)."""
        if deadline_s is None:
            deadline_s = self._default_deadline_s(tenant)
        req = self._service.submit_request(
            text, deadline_s=deadline_s, tenant=tenant
        )
        return ParseTicket(self, self._service, req, deadline_s=deadline_s)

    def parse(
        self, tenant: str, text, *, deadline_s: Optional[float] = None
    ) -> ParseResult:
        """Parse one text under one tenant's automaton (sync)."""
        return self.submit(tenant, text, deadline_s=deadline_s).result()

    def parse_batch(
        self,
        items: Sequence[Tuple[str, Any]],
        *,
        deadline_s: Optional[float] = None,
    ) -> List[ParseResult]:
        """Parse ``[(tenant, text), ...]``; results in input order.

        Same-bucket requests — across tenants — share one tenant-batched
        device program per step.  Admission is all-or-nothing, as in
        ``Parser.parse_batch``.
        """
        tickets: List[ParseTicket] = []
        try:
            for tenant, text in items:
                tickets.append(self.submit(tenant, text, deadline_s=deadline_s))
        except Exception:
            for ticket in tickets:
                ticket.cancel()
            raise
        return [t.result() for t in tickets]

    def _wrap(
        self,
        slpf: SLPF,
        *,
        bucket=None,
        latency_s: Optional[float] = None,
        trace_id: Optional[str] = None,
        tenant: Optional[str] = None,
    ) -> ParseResult:
        cfg = self._configs.get(tenant) if tenant is not None else None
        backend = self._backends.get(tenant) if tenant is not None else None
        return ParseResult(
            forest=slpf,
            backend=backend if backend is not None else "fleet",
            bucket=bucket,
            latency_s=latency_s,
            n_chunks=cfg.n_chunks if cfg is not None else None,
            speculation=None,
            trace_id=trace_id,
        )

    # ------------------------------------------------------------------ stats

    @property
    def compile_count(self) -> int:
        """Device programs compiled fleet-wide — O(#buckets × shapes),
        independent of the tenant count."""
        return self.engine.compile_count

    def stats(self) -> Dict[str, Any]:
        """The fleet-wide serving view.

        ``tenants`` carries each tenant's weighted-fair and latency state
        plus an SLO grade against ITS config targets; ``fleet`` reports the
        bucket economy (tenants per automaton bucket, compile count,
        process-wide table-cache state) — the number that should stay flat
        as tenants multiply.
        """
        from .core.fleet import table_cache_stats

        s = self._service.stats
        tenants: Dict[str, Any] = {}
        for name, d in s["tenants"].items():
            cfg = self._configs.get(name)
            grade: Dict[str, Any] = {
                "p50_s": d["p50_latency_s"],
                "p99_s": d["p99_latency_s"],
            }
            slo = cfg.slo if cfg is not None else None
            if slo is not None and slo.p50_s is not None:
                grade["p50_ok"] = d["p50_latency_s"] <= slo.p50_s
            if slo is not None and slo.p99_s is not None:
                grade["p99_ok"] = d["p99_latency_s"] <= slo.p99_s
            tenants[name] = {
                **d,
                "backend": self._backends.get(name),
                "slo": grade,
            }
        return {
            "backend": "fleet",
            "pending": s["pending"],
            "peak_queue_depth": s["peak_queue_depth"],
            "batches_run": s["batches_run"],
            "compile_count": self.compile_count,
            "buckets": s["buckets"],
            "tenants": tenants,
            "fleet": {
                "n_tenants": len(self._configs),
                "n_buckets": self.engine.n_buckets,
                "bucket_sizes": {
                    "|".join(map(str, k)): v
                    for k, v in sorted(self.engine.bucket_sizes().items())
                },
                "table_cache": table_cache_stats(),
            },
            "metrics": self.obs.metrics.snapshot(),
        }

    def close(self) -> None:
        """Flush observability sinks (the JSONL span log, if configured)."""
        self.obs.close()

    def __enter__(self) -> "ParserFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "AdmissionError",
    "BudgetExceeded",
    "ObsConfig",
    "ParseError",
    "ParseResult",
    "ParseTicket",
    "Parser",
    "ParserBackend",
    "ParserConfig",
    "ParserFleet",
    "ParserStream",
    "PathologicalPatternError",
    "SLOTargets",
    "SLPF",
    "SessionNotFound",
    "get_backend",
    "list_backends",
    "register_backend",
]
