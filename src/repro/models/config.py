"""Model configuration covering all ten assigned architectures.

One ``ModelConfig`` describes a decoder LM backbone; variants are expressed by
optional sub-configs:  ``moe`` (mixtral / llama4-scout), ``ssm`` (mamba2 and the
zamba2 hybrid), ``frontend`` (internvl2 vision stub, musicgen audio stub), and
``sliding_window`` (h2o-danube3, mixtral SWA).  The per-layer ``layout`` string
list drives hybrid stacking (zamba2's shared attention block).

``ShapeSpec`` encodes the assigned input shapes; ``input_specs`` (launch/dryrun)
materializes them as ShapeDtypeStructs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Literal, Optional, Tuple

LayerKind = Literal["attn", "ssm", "moe"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False         # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256                    # SSD chunk length (the paper's k)
    dt_min: float = 1e-3
    dt_max: float = 1e-1


@dataclass(frozen=True)
class FrontendConfig:
    kind: Literal["vision", "audio"]
    n_extra_tokens: int                 # stub embeddings prepended to the text
    feature_dim: int                    # raw stub feature dim (projected to d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // n_heads
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    # layout: per-layer kinds; "shared_attn_every" inserts ONE weight-shared
    # attention block after every k core layers (zamba2).
    layout: Optional[Tuple[str, ...]] = None
    shared_attn_every: Optional[int] = None
    shared_attn_heads: Optional[int] = None
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    attn_p_dtype: str = "bfloat16"   # attention probability buffers (§Perf H3)
    remat: bool = True
    # which shapes this arch skips, with the reason (recorded per DESIGN §5)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        if self.layout is not None:
            return self.layout
        if self.ssm is not None and self.moe is None and self.shared_attn_every is None:
            return ("ssm",) * self.n_layers
        if self.moe is not None:
            return ("moe",) * self.n_layers
        return ("attn",) * self.n_layers

    @property
    def is_attention_free(self) -> bool:
        return all(k == "ssm" for k in self.layer_kinds) and self.shared_attn_every is None

    @property
    def n_params(self) -> int:
        """Approximate parameter count (for 6·N·D roofline accounting)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        per_mlp = 3 * d * self.d_ff if self.d_ff else 0
        per_moe = 0
        if self.moe is not None:
            per_moe = (
                self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                + d * self.moe.n_experts
                + (3 * d * self.moe.d_ff_expert if self.moe.shared_expert else 0)
            )
        per_ssm = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            per_ssm = (
                d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh)
                + di * d + di * self.ssm.d_conv + 3 * nh
            )
        total = n
        for kind in self.layer_kinds:
            if kind == "attn":
                total += per_attn + per_mlp + 2 * d
            elif kind == "moe":
                total += per_attn + per_moe + 2 * d
            elif kind == "ssm":
                total += per_ssm + d
        if self.shared_attn_every:
            sh = self.shared_attn_heads or self.n_heads
            sd = sh * hd
            total += 2 * d * sd + 2 * d * sd + d  # q,k,v,o of the shared block
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE: routed top-k only)."""
        if self.moe is None:
            return self.n_params
        full = self.n_params
        d = self.d_model
        routed_all = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        routed_active = self.moe.top_k * 3 * d * self.moe.d_ff_expert
        n_moe = sum(1 for k in self.layer_kinds if k == "moe")
        return full - n_moe * (routed_all - routed_active)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (arch × shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    microbatch: Optional[int] = None    # per-device microbatch for grad accum


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}
