"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Static-shape, XLA-friendly dispatch (Megatron-style token permutation):
  1. router logits → top-k experts + gates per token;
  2. flatten (tokens·k) assignments, stable-sort by expert id;
  3. position-within-expert via cumulative one-hot counts; tokens beyond the
     per-expert capacity ``C = ceil(tokens·k/E · capacity_factor)`` are dropped
     (their gate contribution is zero — standard GShard behaviour);
  4. scatter into an (E, C, d) buffer, run all experts as one batched einsum,
     gather back, unsort, gate-weight and sum over k.

Sharding (DESIGN §6): when ``E % TP == 0`` (llama4-scout, 16e) the expert dim
shards over 'model' (expert parallelism — XLA inserts the all-to-all at the
buffer boundary); otherwise (mixtral, 8e on TP=16) experts replicate and each
expert's hidden dim shards over 'model' (expert-FFN tensor parallelism).

Aux losses: switch-style load-balance loss and router z-loss, returned to the
trainer for the total objective.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import MoEConfig
from .layers import ParamDecl


def declare_moe(d_model: int, cfg: MoEConfig) -> Dict[str, ParamDecl]:
    E, f = cfg.n_experts, cfg.d_ff_expert
    decls = {
        "router": ParamDecl((d_model, E), ("embed", None), init="scaled"),
        "w_gate": ParamDecl((E, d_model, f), ("experts", "fsdp", "expert_mlp"), init="scaled"),
        "w_up": ParamDecl((E, d_model, f), ("experts", "fsdp", "expert_mlp"), init="scaled"),
        "w_down": ParamDecl((E, f, d_model), ("experts", "expert_mlp", "fsdp"), init="scaled"),
    }
    if cfg.shared_expert:
        decls.update(
            {
                "shared_gate": ParamDecl((d_model, f), ("fsdp", "mlp"), init="scaled"),
                "shared_up": ParamDecl((d_model, f), ("fsdp", "mlp"), init="scaled"),
                "shared_down": ParamDecl((f, d_model), ("mlp", "fsdp"), init="scaled"),
            }
        )
    return decls


def moe_ffn(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                  # (tokens, d)
    cfg: MoEConfig,
    constrain=lambda t, logical: t,  # sharding-constraint hook (tensor, logical axes)
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int((T * k) / E * cfg.capacity_factor))

    logits = (x @ params["router"].astype(jnp.float32)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                                      # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- flatten + stable sort by expert --------------------------------
    flat_expert = idx.reshape(-1)                                             # (T·k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    token_of = order // k                                                     # source token
    oh = jax.nn.one_hot(sorted_expert, E, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1                           # within-expert slot

    # ---- dispatch --------------------------------------------------------
    # capacity slots shard over the batch axes ('pod','data'): the dispatch
    # buffers are the largest activations in MoE cells (173 GB/device
    # unsharded at 32k-prefill — §Dry-run); slot layout is free to choose.
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_expert, pos].set(x[token_of], mode="drop")
    buf = constrain(buf, ("experts", "batch", "embed"))

    # ---- expert compute (batched over E) ---------------------------------
    # NB: constraining expert weights to EP/TP-only layout here (gather-at-use)
    # was measured to REGRESS (compute +64%, §Perf H7 refuted — the partitioner
    # replicates dispatch rows); sharding propagation from the parameter decls
    # is the better schedule for the MoE einsums.
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    h = constrain(h, ("experts", "batch", "expert_mlp"))
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out = constrain(out, ("experts", "batch", "embed"))

    # ---- combine ----------------------------------------------------------
    y_sorted = out[sorted_expert, pos]                                        # (T·k, d)
    y_sorted = jnp.where((pos < C)[:, None], y_sorted, 0.0)
    inv = jnp.argsort(order, stable=True)
    y = y_sorted[inv].reshape(T, k, d)
    y = (y * gates[..., None].astype(y.dtype)).sum(axis=1)

    if cfg.shared_expert:
        sg = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_up"])
        y = y + sg @ params["shared_down"]

    # ---- aux losses --------------------------------------------------------
    # load balance: E · Σ_e (fraction of tokens to e) · (mean prob of e)
    frac = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1)) * k
    mean_prob = probs.mean(axis=0)
    lb = E * jnp.sum(frac * mean_prob) * cfg.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_loss
    return y.astype(x.dtype), {"moe_lb_loss": lb, "moe_z_loss": z}
