"""Transformer building blocks — pure-functional JAX, sharding-annotated.

Conventions:
  * activations: (batch, seq, d_model) in ``cfg.dtype`` (bf16 by default);
  * params: flat nested dicts, declared via ``ParamDecl`` so that shapes /
    logical sharding axes / initializers live in one place (``declare``-style);
  * attention is GQA with RoPE and optional sliding window; the training /
    prefill path uses a **blockwise (flash-style) attention** written in pure
    jnp — ``lax.scan`` over KV blocks with an online-softmax carry — so that
    32k-token prefill never materializes an (L, L) score matrix;
  * head padding: when head counts do not divide tensor-parallel degree, query
    heads are zero-padded to the next multiple (kv heads padded by the same
    group ratio) and the output-projection rows of padded heads are zero, so
    the math is exact (DESIGN §6).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# ------------------------------------------------------------ declarations


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical sharding axis per dim
    init: str = "normal"                 # normal | zeros | ones | scaled
    scale: float = 0.02

    def materialize(self, key, dtype) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = self.scale
        if self.init == "scaled":  # 1/sqrt(fan_in) on the penultimate dim
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            scale = 1.0 / math.sqrt(fan_in)
        return (scale * jax.random.normal(key, self.shape)).astype(dtype)


def tree_init(decls: Any, key, dtype) -> Any:
    """Materialize a pytree of ParamDecl with split keys (deterministic order)."""
    leaves, treedef = jax.tree.flatten(
        decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [d.materialize(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def tree_abstract(decls: Any, dtype) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def tree_logical(decls: Any) -> Any:
    return jax.tree.map(
        lambda d: d.logical, decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )


# ----------------------------------------------------------------- norms


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


# ------------------------------------------------------------------ RoPE


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(b, s, kv, hd) → (b, s, kv*groups, hd) by head repetition (GQA)."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd
    )


def blockwise_attention(
    q: jnp.ndarray,                # (b, Lq, h, hd)   h = query heads
    k: jnp.ndarray,                # (b, Lk, kv, hd)  kv heads (NOT repeated)
    v: jnp.ndarray,
    *,
    groups: int = 1,               # h = kv * groups (GQA); kv index = h // groups
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,             # absolute position of q[0] minus k[0]
    q_block: int = 512,
    k_block: int = 1024,
    softcap: Optional[float] = None,
    p_dtype=jnp.bfloat16,          # probability-buffer dtype (§Perf H3)
) -> jnp.ndarray:
    """Flash-style attention in pure jnp: scan over KV blocks with an
    online-softmax carry; never materializes the (Lq, Lk) score matrix.

    GQA is computed grouped — K/V are never repeated to the query head count
    (§Perf H1: repetition multiplied K/V bytes by ``groups`` and forced SPMD
    reshards).  Block masks are derived behind an ``optimization_barrier`` so
    XLA cannot hoist them into O(nq·nk·qb·kb) buffers (§Perf H2); each step
    recomputes a (qb, kb) predicate — trivial VPU work, no HBM traffic.

    Complexity O(Lq·Lk·hd·h); peak memory O(qb·kb) per (b, h).
    """
    b, Lq, h, hd = q.shape
    Lk, kv = k.shape[1], k.shape[2]
    assert h == kv * groups, (h, kv, groups)
    scale = 1.0 / math.sqrt(hd)

    qb = min(q_block, Lq)
    while Lq % qb:
        qb //= 2
    kb = min(k_block, Lk)
    while Lk % kb:
        kb //= 2
    nq, nk = Lq // qb, Lk // kb

    # (b, nq, qb, kv, g, hd) — group axis explicit, contraction stays on kv
    q = q.reshape(b, nq, qb, kv, groups, hd)
    k = k.reshape(b, nk, kb, kv, hd)
    v = v.reshape(b, nk, kb, kv, hd)

    q_pos_base = jnp.arange(qb, dtype=jnp.int32)
    k_pos_base = jnp.arange(kb, dtype=jnp.int32)

    def one_q_block(qi, q_blk):
        # carries: m (max), l (denominator), acc (weighted sum) — f32
        m0 = jnp.full((b, kv, groups, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, groups, qb), jnp.float32)
        a0 = jnp.zeros((b, kv, groups, qb, hd), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            s = jnp.einsum(
                "bqcgd,bkcd->bcgqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            # barrier: block indices are opaque to LICM → masks are computed
            # per step as a (qb, kb) predicate, never hoisted/stacked (§Perf H2)
            qi_b, ki_b = jax.lax.optimization_barrier((qi, ki))
            qpos = q_offset + qi_b * qb + q_pos_base          # (qb,)
            kpos = ki_b * kb + k_pos_base                     # (kb,)
            mask = jnp.ones((qb, kb), dtype=bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use 0
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(jnp.where(jnp.isfinite(s), s - safe_m[..., None], -jnp.inf))
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
            l_new = alpha * l + p.sum(axis=-1)
            acc_new = alpha[..., None] * acc + jnp.einsum(
                "bcgqk,bkcd->bcgqd", p.astype(p_dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        ks = jnp.arange(nk, dtype=jnp.int32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)          # (b, kv, g, qb, hd)
        return jnp.moveaxis(out, 3, 1)                        # (b, qb, kv, g, hd)

    outs = jax.lax.map(
        lambda args: one_q_block(*args),
        (jnp.arange(nq, dtype=jnp.int32), jnp.moveaxis(q, 1, 0)),
    )                                                         # (nq, b, qb, kv, g, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, Lq, h, hd)
    return out


def decode_attention(
    q: jnp.ndarray,                # (b, 1, h, hd)   h = padded query heads
    k_cache: jnp.ndarray,          # (b, S, kv, hd)  (ring-buffered slots)
    v_cache: jnp.ndarray,
    kpos: jnp.ndarray,             # (S,) int32 — absolute position per slot (-1 empty)
    pos: jnp.ndarray,              # () int32 — index of the new token
    *,
    groups: int,
    grouped: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    row_start: Optional[jnp.ndarray] = None,   # (b,) — continuous batching
) -> jnp.ndarray:
    """Single-step grouped attention against a (possibly ring-buffered) cache.

    When the head plan is exact (``grouped``), K/V are never head-repeated
    (§Perf H1 — repetition multiplied the cache read bytes by ``groups`` and
    forced SPMD reshards against the sequence-sharded cache: 5–16× decode
    wins).  Non-exact plans (internvl2) fall back to repetition.  The
    slot-position array makes sliding-window ring buffers exact: masks use
    absolute positions, so overwritten slots never leak.  ``row_start`` masks
    positions before each row's current request — slot reuse for continuous
    batching (serve/scheduler.py) never leaks a previous request's K/V."""
    b, S, kv, hd = k_cache.shape
    h = q.shape[2]
    if not grouped:
        k_cache = _repeat_kv(k_cache, groups)[:, :, :h]
        v_cache = _repeat_kv(v_cache, groups)[:, :, :h]
        kv = h
        groups = 1
    assert h == kv * groups, (h, kv, groups)
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, 1, kv, groups, hd)
    s = jnp.einsum(
        "bqcgd,bscd->bcgqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        mask &= kpos > pos - window
    mask = jnp.broadcast_to(mask[None, :], (b, S))
    if row_start is not None:
        mask &= kpos[None, :] >= row_start[:, None]
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum(
        "bcgqs,bscd->bqcgd", p, v_cache, preferred_element_type=jnp.float32
    ).reshape(b, 1, h, hd)
    return out.astype(q.dtype)


# ----------------------------------------------------------------- MLP


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


# ------------------------------------------------------- head accounting


@dataclasses.dataclass(frozen=True)
class HeadPlan:
    """Padded head counts for exact tensor-parallel grouped GQA (DESIGN §6).

    Invariant: ``pad_q == pad_kv * groups`` — attention is computed grouped
    (K/V never repeated, §Perf H1), so padding must preserve the group shape.
    Rule: smallest ``pad_kv ≥ n_kv`` with ``(pad_kv·groups) % tp == 0``,
    accepted only if it wastes ≤ 2× query heads; otherwise no padding (heads
    replicate across TP — exact, chosen only for small models like internvl2
    where 7:1 grouping vs tp=16 would force 8× padding)."""

    n_q: int          # real query heads
    n_kv: int         # real kv heads
    pad_q: int        # padded query heads
    pad_kv: int       # padded kv heads (ceil(pad_q / groups))
    groups: int       # q heads per kv head (unchanged by padding)
    grouped: bool     # pad_q == pad_kv * groups → grouped decode is exact

    @classmethod
    def plan(cls, n_q: int, n_kv: int, tp: int) -> "HeadPlan":
        groups = n_q // n_kv
        assert n_q == n_kv * groups, "q heads must be a multiple of kv heads"
        if tp <= 1 or n_q % tp == 0:
            return cls(n_q, n_kv, n_q, n_kv, groups, True)
        # 1) pad q heads to the TP multiple; exact grouping if it divides
        a = ((n_q + tp - 1) // tp) * tp
        kv_a = (a + groups - 1) // groups
        if kv_a * groups == a:
            return cls(n_q, n_kv, a, kv_a, groups, True)       # e.g. phi3 48/12
        # 2) try a TP-multiple kv count within the 2× query-waste bound
        b_kv = ((n_kv + tp - 1) // tp) * tp
        if b_kv * groups <= 2 * n_q:
            return cls(n_q, n_kv, b_kv * groups, b_kv, groups, True)  # llama4 80/16
        # 3) non-exact repeat plan (decode repeats KV; e.g. internvl2 16/3)
        return cls(n_q, n_kv, a, kv_a, groups, False)
