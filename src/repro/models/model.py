"""The decoder LM backbone covering all ten assigned architectures.

Structure (DESIGN §3/§6):
  * params are declared (shape + logical sharding axes + init) per layer kind,
    then *stacked* along a leading layer axis so the forward pass scans over
    layers (``lax.scan``) — one traced layer per kind, which keeps XLA compile
    times flat in depth (essential for the 40–64-layer dry-run matrix);
  * hybrid layouts (zamba2) run homogeneous SSM runs under scan with a single
    weight-shared attention block applied between runs;
  * three entry points: ``forward_train`` (causal LM loss, microbatched by the
    caller), ``prefill`` (builds decode caches), ``decode_step`` (one token);
  * attention decode caches are ring-buffered at ``min(seq, window)`` slots for
    sliding-window archs; full-attention caches are sequence-sharded over the
    'model' axis so 32k-token decode fits HBM (flash-decoding executed by the
    SPMD partitioner — the paper's split/reach/join pattern applied to
    softmax attention; DESIGN §2).
  * modality frontends (internvl2 vision, musicgen audio) are STUBS per the
    assignment: ``input_specs`` supplies precomputed patch/frame embeddings
    which are projected and prepended to the token sequence.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, ShapeSpec
from .layers import (
    HeadPlan,
    ParamDecl,
    apply_rope,
    blockwise_attention,
    decode_attention,
    rms_norm,
    swiglu,
    tree_abstract,
    tree_init,
    tree_logical,
)
from .mamba import declare_ssm, ssm_decode_step, ssm_dims, ssm_forward
from .moe import declare_moe, moe_ffn

Params = Dict[str, Any]


# ===================================================================== decls


def _attn_decls(cfg: ModelConfig, plan: HeadPlan, heads_prefix: str = "") -> Dict[str, ParamDecl]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "norm1": ParamDecl((d,), (None,), init="ones"),
        "wq": ParamDecl((d, plan.pad_q, hd), ("fsdp", "heads", None), init="scaled"),
        "wk": ParamDecl((d, plan.pad_kv, hd), ("fsdp", "kv_heads", None), init="scaled"),
        "wv": ParamDecl((d, plan.pad_kv, hd), ("fsdp", "kv_heads", None), init="scaled"),
        "wo": ParamDecl((plan.pad_q, hd, d), ("heads", None, "fsdp"), init="scaled"),
    }


def _mlp_decls(cfg: ModelConfig) -> Dict[str, ParamDecl]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm2": ParamDecl((d,), (None,), init="ones"),
        "w_gate": ParamDecl((d, f), ("fsdp", "mlp"), init="scaled"),
        "w_up": ParamDecl((d, f), ("fsdp", "mlp"), init="scaled"),
        "w_down": ParamDecl((f, d), ("mlp", "fsdp"), init="scaled"),
    }


def _layer_decls(cfg: ModelConfig, kind: str, plan: HeadPlan) -> Dict[str, ParamDecl]:
    if kind == "attn":
        return {**_attn_decls(cfg, plan), **_mlp_decls(cfg)}
    if kind == "moe":
        return {
            **_attn_decls(cfg, plan),
            "norm2": ParamDecl((cfg.d_model,), (None,), init="ones"),
            "moe": declare_moe(cfg.d_model, cfg.moe),
        }
    if kind == "ssm":
        return {
            "norm1": ParamDecl((cfg.d_model,), (None,), init="ones"),
            "ssm": declare_ssm(cfg.d_model, cfg.ssm),
        }
    raise ValueError(kind)


def _stack_decls(decls: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Prepend a layer axis of size n to every decl (scan-over-layers)."""
    return jax.tree.map(
        lambda d: ParamDecl((n,) + d.shape, ("stack",) + d.logical, d.init, d.scale),
        decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def head_plan(cfg: ModelConfig, tp: int) -> HeadPlan:
    return HeadPlan.plan(cfg.n_heads, cfg.n_kv_heads, tp)


def shared_attn_plan(cfg: ModelConfig, tp: int) -> HeadPlan:
    h = cfg.shared_attn_heads or cfg.n_heads
    return HeadPlan.plan(h, h, tp)  # shared block is MHA (zamba2)


def declare_params(cfg: ModelConfig, tp: int = 1) -> Dict[str, Any]:
    d = cfg.d_model
    plan = head_plan(cfg, tp)
    kinds = cfg.layer_kinds
    decls: Dict[str, Any] = {
        "embed": ParamDecl((cfg.vocab_size, d), ("vocab", None), init="normal"),
        "final_norm": ParamDecl((d,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        decls["lm_head"] = ParamDecl((d, cfg.vocab_size), (None, "vocab"), init="scaled")
    if cfg.frontend is not None:
        decls["frontend_proj"] = ParamDecl(
            (cfg.frontend.feature_dim, d), (None, None), init="scaled"
        )
    stacks: Dict[str, Any] = {}
    for kind in sorted(set(kinds)):
        n = sum(1 for k in kinds if k == kind)
        stacks[kind] = _stack_decls(_layer_decls(cfg, kind, plan), n)
    decls["stacks"] = stacks
    if cfg.shared_attn_every:
        decls["shared_attn"] = {
            **_attn_decls(cfg, shared_attn_plan(cfg, tp)),
            **_mlp_decls(cfg),
        }
    return decls


def init_params(cfg: ModelConfig, key, tp: int = 1) -> Params:
    return tree_init(declare_params(cfg, tp), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig, tp: int = 1) -> Params:
    return tree_abstract(declare_params(cfg, tp), jnp.dtype(cfg.param_dtype))


def param_logical_axes(cfg: ModelConfig, tp: int = 1) -> Params:
    return tree_logical(declare_params(cfg, tp))


# ================================================================ layer fwd


def _attention(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    plan: HeadPlan,
    positions: jnp.ndarray,
    window: Optional[int],
    shard: Callable,
) -> jnp.ndarray:
    b, l, d = x.shape
    hd = cfg.resolved_head_dim
    # FSDP gather-at-use (§Perf H5): constrain weights to TP-only sharding at
    # the matmul site so SPMD all-gathers the (small) weight shard rather than
    # partially contracting and all-reducing the (huge) activation.
    wq = shard(p["wq"], (None, "heads", None))
    wk = shard(p["wk"], (None, "kv_heads", None))
    wv = shard(p["wv"], (None, "kv_heads", None))
    wo = shard(p["wo"], ("heads", None, None))
    q = jnp.einsum("bld,dhk->blhk", x, wq)
    k = jnp.einsum("bld,dhk->blhk", x, wk)
    v = jnp.einsum("bld,dhk->blhk", x, wv)
    q = shard(q, ("batch", "seq", "heads", None))
    k = shard(k, ("batch", "seq", "kv_heads", None))
    v = shard(v, ("batch", "seq", "kv_heads", None))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # Train/prefill: REPEAT layout — measured better than grouped einsums here
    # (the 6D grouped form breaks SPMD head-sharding propagation for splits
    # like phi3's (12,4): −2.3×; see §Perf H8).  The repeated K/V stay
    # head-sharded exactly like the baseline; mask-barrier + bf16-p retained.
    kr = shard(jnp.repeat(k, plan.groups, axis=2)[:, :, : plan.pad_q],
               ("batch", "seq", "heads", None))
    vr = shard(jnp.repeat(v, plan.groups, axis=2)[:, :, : plan.pad_q],
               ("batch", "seq", "heads", None))
    o = blockwise_attention(
        q, kr, vr, groups=1, causal=True, window=window,
        softcap=cfg.attn_logit_softcap, p_dtype=jnp.dtype(cfg.attn_p_dtype),
    )
    o = shard(o, ("batch", "seq", "heads", None))
    return jnp.einsum("blhk,hkd->bld", o.astype(x.dtype), wo)


def _attn_block(p, x, cfg, plan, positions, window, shard):
    h = x + _attention(
        p, rms_norm(x, p["norm1"], cfg.rms_eps), cfg, plan, positions, window, shard
    )
    if "w_gate" in p:  # dense MLP (weights FSDP-gathered at use, §Perf H5)
        h = h + swiglu(
            rms_norm(h, p["norm2"], cfg.rms_eps),
            shard(p["w_gate"], (None, "mlp")),
            shard(p["w_up"], (None, "mlp")),
            shard(p["w_down"], ("mlp", None)),
        )
    return h


def _moe_block(p, x, cfg, plan, positions, window, shard):
    h = x + _attention(p, rms_norm(x, p["norm1"], cfg.rms_eps), cfg, plan, positions, window, shard)
    b, l, d = h.shape
    flat = rms_norm(h, p["norm2"], cfg.rms_eps).reshape(b * l, d)
    y, aux = moe_ffn(p["moe"], flat, cfg.moe, constrain=shard)
    return h + y.reshape(b, l, d), aux


def _ssm_block(p, x, cfg, shard):
    return x + ssm_forward(
        p["ssm"], rms_norm(x, p["norm1"], cfg.rms_eps), cfg.ssm, cfg.rms_eps,
        shard=shard,
    )


# ============================================================== full forward


def _scan_stack(body: Callable, x, stack: Params, remat: bool):
    fn = jax.checkpoint(body) if remat else body

    def step(carry, layer_params):
        h, aux = carry
        out = fn(layer_params, h)
        if isinstance(out, tuple):
            h2, a = out
            aux = jax.tree.map(lambda s, v: s + v, aux, a)
            return (h2, aux), None
        return (out, aux), None

    zero_aux = {"moe_lb_loss": jnp.zeros((), jnp.float32),
                "moe_z_loss": jnp.zeros((), jnp.float32)}
    (x, aux), _ = jax.lax.scan(step, (x, zero_aux), stack)
    return x, aux


def _layer_runs(cfg: ModelConfig):
    """Consecutive same-kind runs: [(kind, start_idx_in_stack, count), ...]."""
    kinds = cfg.layer_kinds
    runs = []
    seen: Dict[str, int] = {}
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        k = kinds[i]
        runs.append((k, seen.get(k, 0), j - i))
        seen[k] = seen.get(k, 0) + (j - i)
        i = j
    return runs


def backbone(
    params: Params,
    x: jnp.ndarray,                  # (b, L, d) embedded inputs
    cfg: ModelConfig,
    positions: jnp.ndarray,          # (b, L)
    tp: int,
    shard: Callable,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    plan = head_plan(cfg, tp)
    aux_total = {"moe_lb_loss": jnp.zeros((), jnp.float32),
                 "moe_z_loss": jnp.zeros((), jnp.float32)}

    def body_for(kind):
        if kind == "attn":
            return lambda p, h: _attn_block(p, h, cfg, plan, positions, cfg.sliding_window, shard)
        if kind == "moe":
            return lambda p, h: _moe_block(p, h, cfg, plan, positions, cfg.sliding_window, shard)
        if kind == "ssm":
            return lambda p, h: _ssm_block(p, h, cfg, shard)
        raise ValueError(kind)

    runs = _layer_runs(cfg)
    shared_every = cfg.shared_attn_every
    layers_done = 0
    for kind, start, count in runs:
        stack = jax.tree.map(lambda t: t[start : start + count], params["stacks"][kind])
        if shared_every:
            # interleave the weight-shared attention block every `shared_every`
            done_in_run = 0
            while done_in_run < count:
                step_n = min(shared_every - (layers_done % shared_every) or shared_every,
                             count - done_in_run)
                sub = jax.tree.map(
                    lambda t: t[done_in_run : done_in_run + step_n], stack
                )
                x, aux = _scan_stack(body_for(kind), x, sub, cfg.remat)
                aux_total = jax.tree.map(lambda s, v: s + v, aux_total, aux)
                done_in_run += step_n
                layers_done += step_n
                if layers_done % shared_every == 0:
                    splan = shared_attn_plan(cfg, tp)
                    x = _attn_block(
                        params["shared_attn"], x, cfg, splan, positions, None, shard
                    )
        else:
            x, aux = _scan_stack(body_for(kind), x, stack, cfg.remat)
            aux_total = jax.tree.map(lambda s, v: s + v, aux_total, aux)
            layers_done += count
        x = shard(x, ("batch", "seq", None))
    return x, aux_total


def embed_inputs(
    params: Params,
    tokens: jnp.ndarray,                       # (b, L)
    cfg: ModelConfig,
    extra: Optional[jnp.ndarray] = None,       # (b, n_extra, feat) frontend stub
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x (b, L_total, d), positions (b, L_total))."""
    emb = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.frontend is not None and extra is not None:
        fe = (extra.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]).astype(
            jnp.dtype(cfg.dtype)
        )
        emb = jnp.concatenate([fe, emb], axis=1)
    b, L = emb.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (b, L))
    return emb, positions


def logits_from(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bld,dv->blv", x, head)


def lm_loss(
    logits: jnp.ndarray,            # (b, L, V)
    labels: jnp.ndarray,            # (b, L) next-token targets; -1 = ignore
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    safe_labels = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lf, safe_labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0), mask.sum()


def forward_train(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    tp: int = 1,
    shard: Callable = lambda t, logical: t,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    tokens = batch["tokens"]
    extra = batch.get("extra")
    x, positions = embed_inputs(params, tokens, cfg, extra)
    x = shard(x, ("batch", "seq", None))
    x, aux = backbone(params, x, cfg, positions, tp, shard)
    n_extra = 0 if extra is None else extra.shape[1]
    x_text = x[:, n_extra:]
    logits = logits_from(params, x_text, cfg)
    logits = shard(logits, ("batch", "seq", "vocab"))
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1
    )
    loss, n_tok = lm_loss(logits, labels)
    total = loss + aux["moe_lb_loss"] + aux["moe_z_loss"]
    return total, {"loss": loss, "n_tokens": n_tok, **aux}


# ==================================================================== decode


@dataclasses.dataclass
class CacheSpec:
    """Static description of the decode cache for one config/shape."""

    cache_len: int                   # attention slots (min(seq, window))
    full_len: int                    # logical sequence length


def make_cache(cfg: ModelConfig, batch: int, seq_len: int, tp: int = 1) -> Dict[str, Any]:
    """Zero-initialized decode caches (used by prefill and by input_specs)."""
    dt = jnp.dtype(cfg.dtype)
    plan = head_plan(cfg, tp)
    hd = cfg.resolved_head_dim
    cache_len = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    kinds = cfg.layer_kinds
    caches: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    n_attn = sum(1 for k in kinds if k in ("attn", "moe"))
    if n_attn or cfg.shared_attn_every:
        caches["row_start"] = jnp.zeros((batch,), jnp.int32)
    if n_attn:
        caches["attn"] = {
            "k": jnp.zeros((n_attn, batch, cache_len, plan.pad_kv, hd), dt),
            "v": jnp.zeros((n_attn, batch, cache_len, plan.pad_kv, hd), dt),
            "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
        }
    n_ssm = sum(1 for k in kinds if k == "ssm")
    if n_ssm:
        dims = ssm_dims(cfg.d_model, cfg.ssm)
        caches["ssm"] = {
            "state": jnp.zeros(
                (n_ssm, batch, dims["n_heads"], cfg.ssm.head_dim, cfg.ssm.d_state),
                jnp.float32,
            ),
            "conv": jnp.zeros((n_ssm, batch, cfg.ssm.d_conv - 1, dims["conv_dim"]), dt),
        }
    if cfg.shared_attn_every:
        splan = shared_attn_plan(cfg, tp)
        n_shared = len(kinds) // cfg.shared_attn_every
        caches["shared_attn"] = {
            "k": jnp.zeros((n_shared, batch, cache_len, splan.pad_kv, hd), dt),
            "v": jnp.zeros((n_shared, batch, cache_len, splan.pad_kv, hd), dt),
        }
    return caches


def _decode_attn_block(p, x, cfg, plan, cache_k, cache_v, slot_pos, pos, window, shard,
                       row_start=None):
    """One attention (or attn+mlp / attn+moe) decode step against the cache."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    xn = rms_norm(x, p["norm1"], cfg.rms_eps)
    q = jnp.einsum("bld,dhk->blhk", xn, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", xn, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", xn, p["wv"])
    posb = jnp.broadcast_to(pos[None], (b, 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    cache_len = cache_k.shape[1]
    slot = pos % cache_len
    new_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    new_k = shard(new_k, ("batch", "cache_seq", "kv_heads", None))
    new_v = shard(new_v, ("batch", "cache_seq", "kv_heads", None))
    kpos = slot_pos  # absolute positions per slot (updated by caller)
    o = decode_attention(
        q, new_k, new_v, kpos, pos,
        groups=plan.groups, grouped=plan.grouped,
        window=window, softcap=cfg.attn_logit_softcap, row_start=row_start,
    )
    h = x + jnp.einsum("blhk,hkd->bld", o.astype(x.dtype), p["wo"])
    if "w_gate" in p:
        h = h + swiglu(rms_norm(h, p["norm2"], cfg.rms_eps), p["w_gate"], p["w_up"], p["w_down"])
    elif "moe" in p:
        b2, l2, d2 = h.shape
        flat = rms_norm(h, p["norm2"], cfg.rms_eps).reshape(b2 * l2, d2)
        y, _ = moe_ffn(p["moe"], flat, cfg.moe, constrain=lambda t, a: t)
        h = h + y.reshape(b2, l2, d2)
    return h, new_k, new_v


def decode_step(
    params: Params,
    caches: Dict[str, Any],
    token: jnp.ndarray,             # (b, 1) int32
    cfg: ModelConfig,
    tp: int = 1,
    shard: Callable = lambda t, logical: t,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One serving step: next-token logits + updated caches."""
    pos = caches["pos"]
    plan = head_plan(cfg, tp)
    x = jnp.take(params["embed"], token, axis=0).astype(jnp.dtype(cfg.dtype))
    new_caches = dict(caches)

    if "attn" in caches:
        cache_len = caches["attn"]["k"].shape[2]
        slot = pos % cache_len
        new_caches["attn"] = dict(caches["attn"])
        new_caches["attn"]["slot_pos"] = caches["attn"]["slot_pos"].at[slot].set(pos)
    kinds = cfg.layer_kinds
    runs = _layer_runs(cfg)
    shared_every = cfg.shared_attn_every
    layers_done = 0
    attn_used = 0
    ssm_used = 0
    shared_used = 0

    row_start = caches.get("row_start")

    def attn_body(p, h, ck, cv):
        return _decode_attn_block(
            p, h, cfg, plan, ck, cv,
            new_caches["attn"]["slot_pos"], pos, cfg.sliding_window, shard,
            row_start=row_start,
        )

    def ssm_body(p, h, state, conv):
        y, ns, nc = ssm_decode_step(
            p["ssm"], rms_norm(h, p["norm1"], cfg.rms_eps), cfg.ssm, cfg.rms_eps,
            state, conv,
        )
        return h + y, ns, nc

    for kind, start, count in runs:
        stack = jax.tree.map(lambda t: t[start : start + count], params["stacks"][kind])
        sub_ranges = [(0, count)]
        if shared_every:
            sub_ranges = []
            done = 0
            while done < count:
                step_n = min(shared_every - (layers_done + done) % shared_every or shared_every,
                             count - done)
                sub_ranges.append((done, step_n))
                done += step_n
        for (off, cnt) in sub_ranges:
            sub = jax.tree.map(lambda t: t[off : off + cnt], stack)
            if kind in ("attn", "moe"):
                ck = jax.lax.dynamic_slice_in_dim(caches["attn"]["k"], attn_used, cnt, 0)
                cv = jax.lax.dynamic_slice_in_dim(caches["attn"]["v"], attn_used, cnt, 0)

                def step(h, xs):
                    p, k_, v_ = xs
                    h2, nk, nv = attn_body(p, h, k_, v_)
                    return h2, (nk, nv)

                x, (nk, nv) = jax.lax.scan(step, x, (sub, ck, cv))
                new_caches["attn"]["k"] = jax.lax.dynamic_update_slice_in_dim(
                    new_caches["attn"]["k"], nk, attn_used, 0
                )
                new_caches["attn"]["v"] = jax.lax.dynamic_update_slice_in_dim(
                    new_caches["attn"]["v"], nv, attn_used, 0
                )
                attn_used += cnt
            else:  # ssm
                st = jax.lax.dynamic_slice_in_dim(caches["ssm"]["state"], ssm_used, cnt, 0)
                cc = jax.lax.dynamic_slice_in_dim(caches["ssm"]["conv"], ssm_used, cnt, 0)

                def sstep(h, xs):
                    p, s_, c_ = xs
                    h2, ns, nc = ssm_body(p, h, s_, c_)
                    return h2, (ns, nc)

                x, (ns, nc) = jax.lax.scan(sstep, x, (sub, st, cc))
                new_caches.setdefault("ssm", dict(caches["ssm"]))
                new_caches["ssm"] = dict(new_caches["ssm"])
                new_caches["ssm"]["state"] = jax.lax.dynamic_update_slice_in_dim(
                    new_caches["ssm"]["state"], ns, ssm_used, 0
                )
                new_caches["ssm"]["conv"] = jax.lax.dynamic_update_slice_in_dim(
                    new_caches["ssm"]["conv"], nc, ssm_used, 0
                )
                ssm_used += cnt
            layers_done += cnt
            if shared_every and layers_done % shared_every == 0 and layers_done <= len(kinds):
                splan = shared_attn_plan(cfg, tp)
                sk = caches["shared_attn"]["k"][shared_used]
                sv = caches["shared_attn"]["v"][shared_used]
                x, nk, nv = _decode_attn_block(
                    params["shared_attn"], x, cfg, splan, sk, sv,
                    new_caches["attn"]["slot_pos"] if "attn" in new_caches
                    else jnp.arange(sk.shape[1], dtype=jnp.int32),
                    pos, None, shard, row_start=row_start,
                )
                new_caches.setdefault("shared_attn", dict(caches["shared_attn"]))
                new_caches["shared_attn"] = dict(new_caches["shared_attn"])
                new_caches["shared_attn"]["k"] = new_caches["shared_attn"]["k"].at[shared_used].set(nk)
                new_caches["shared_attn"]["v"] = new_caches["shared_attn"]["v"].at[shared_used].set(nv)
                shared_used += 1

    logits = logits_from(params, x, cfg)
    new_caches["pos"] = pos + 1
    return logits, new_caches


def prefill(
    params: Params,
    tokens: jnp.ndarray,            # (b, L)
    cfg: ModelConfig,
    tp: int = 1,
    shard: Callable = lambda t, logical: t,
    extra: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence forward producing last-position logits.

    (Cache *population* during prefill is exercised in the serving loop via
    step-wise decode; the dry-run prefill cell lowers this full forward, which
    is the compute-bound phase of serving.)
    """
    x, positions = embed_inputs(params, tokens, cfg, extra)
    x = shard(x, ("batch", "seq", None))
    x, _ = backbone(params, x, cfg, positions, tp, shard)
    logits = logits_from(params, x[:, -1:], cfg)
    return logits, {"pos": jnp.asarray(x.shape[1], jnp.int32)}
