"""Mamba-2 (SSD — state-space duality) layer on the paper's chunked scan.

The SSD recurrence per head is  ``s_t = a_t · s_{t-1} + dt_t · x_t ⊗ B_t``,
``y_t = C_t · s_t + D · x_t`` — an *associative affine* recurrence, i.e. exactly
the structure the paper parallelizes for FA runs.  The chunked algorithm here is
the three-phase schema of ``core/scan.py`` (DESIGN §4):

  reach  per chunk: the within-chunk quadratic form (decay-masked C·Bᵀ
         "attention") plus the chunk's state contribution and total decay;
  join   exclusive scan of (decay, state) pairs across chunks — implemented
         with ``core.scan.exclusive_entries`` (single-device) or
         ``sharded_exclusive_entries`` (context-parallel long sequences,
         the same one-collective join the parser uses);
  build  per chunk: add the inter-chunk contribution ``C_t · (decay · S_prev)``.

Decode is the O(1) stepwise recurrence against an (heads, head_dim, d_state)
state cache plus a (d_conv-1)-deep convolution cache.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.scan import exclusive_entries
from .config import SSMConfig
from .layers import ParamDecl, rms_norm


def ssm_dims(d_model: int, cfg: SSMConfig) -> Dict[str, int]:
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.n_groups * cfg.d_state
    return dict(d_inner=d_inner, n_heads=n_heads, conv_dim=conv_dim)


def declare_ssm(d_model: int, cfg: SSMConfig) -> Dict[str, ParamDecl]:
    dims = ssm_dims(d_model, cfg)
    di, nh, cd = dims["d_inner"], dims["n_heads"], dims["conv_dim"]
    in_dim = 2 * di + 2 * cfg.n_groups * cfg.d_state + nh
    return {
        # §Perf H5c: SSM projections are pure-TP (no FSDP on the contracting
        # d_model dim) — FSDP there made the partitioner either all-reduce
        # activation-sized partials (baseline) or replicate the batch (H5);
        # replicating the modest weight shards over 'data' removes both.
        "w_in": ParamDecl((d_model, in_dim), (None, "mlp"), init="scaled"),
        "conv_w": ParamDecl((cfg.d_conv, cd), (None, "mlp"), init="scaled", scale=0.1),
        "conv_b": ParamDecl((cd,), ("mlp",), init="zeros"),
        "A_log": ParamDecl((nh,), ("heads",), init="ones"),
        "D": ParamDecl((nh,), ("heads",), init="ones"),
        "dt_bias": ParamDecl((nh,), ("heads",), init="zeros"),
        "norm_w": ParamDecl((di,), ("mlp",), init="ones"),
        "w_out": ParamDecl((di, d_model), ("mlp", None), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq via shifted adds (d_conv is tiny)."""
    d_conv = w.shape[0]
    out = x * w[-1]
    for i in range(1, d_conv):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + b


def _split_zxbcdt(zxbcdt, d_inner, g, n, nh):
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * g * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * g * n :]
    return z, xBC, dt


def ssd_chunked(
    xdt: jnp.ndarray,   # (b, l, nh, hp)  — dt-weighted inputs
    dA: jnp.ndarray,    # (b, l, nh)      — negative decay log-increments dt·A
    B: jnp.ndarray,     # (b, l, g, n)
    C: jnp.ndarray,     # (b, l, g, n)
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,   # (b, nh, hp, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD: returns (y (b,l,nh,hp), final_state (b,nh,hp,n)).

    Reach/join/build structure; memory peak is one chunk's (nh, q, q) decay
    mask per batch — chunks are processed under ``lax.map``.
    """
    b, l, nh, hp = xdt.shape
    g, n = B.shape[-2], B.shape[-1]
    hpg = nh // g
    q = min(chunk, l)
    while l % q:
        q //= 2
    nc = l // q

    xdt_c = xdt.reshape(b, nc, q, nh, hp)
    dA_c = dA.reshape(b, nc, q, nh)
    B_c = B.reshape(b, nc, q, g, n)
    C_c = C.reshape(b, nc, q, g, n)

    dA_cs = jnp.cumsum(dA_c, axis=2)                        # (b, nc, q, nh)
    chunk_decay = jnp.exp(dA_cs[:, :, -1])                  # (b, nc, nh)

    # ---- reach: per-chunk state contribution -----------------------------
    # S_c = Σ_j exp(dA_cs[last] - dA_cs[j]) · B_j ⊗ xdt_j
    w_state = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)          # (b, nc, q, nh)
    Bh = jnp.repeat(B_c, hpg, axis=3)                       # (b, nc, q, nh=g*hpg, n)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w_state, Bh, xdt_c)

    # ---- join: exclusive scan of (decay, state) across chunks ------------
    def combine(later, earlier):
        a2, s2 = later
        a1, s1 = earlier
        return a2 * a1, a2[..., None, None] * s1 + s2

    def act(m, s):
        a, inc = m
        return a[..., None, None] * s + inc

    init = (
        jnp.zeros((b, nh, hp, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    # stack chunk axis first for the scan
    summaries = (
        jnp.moveaxis(chunk_decay, 1, 0),                    # (nc, b, nh)
        jnp.moveaxis(S, 1, 0),                              # (nc, b, nh, hp, n)
    )
    entries = exclusive_entries(combine, act, summaries, init)  # (nc, b, nh, hp, n)
    final_state = act(jax.tree.map(lambda x: x[-1], summaries), entries[-1])

    # ---- build: intra-chunk quadratic + inter-chunk contribution ---------
    Ch = jnp.repeat(C_c, hpg, axis=3)                       # (b, nc, q, nh, n)

    def one_chunk(args):
        xdt_k, dA_cs_k, Bh_k, Ch_k, S_prev = args           # per-chunk slices
        # intra: L[i,j] = exp(cs_i - cs_j) for i ≥ j
        Lm = dA_cs_k[:, :, None, :] - dA_cs_k[:, None, :, :]     # (b, q, q, nh)
        iota = jnp.arange(q)
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        Lmask = jnp.where(causal, jnp.exp(Lm), 0.0)
        CB = jnp.einsum("bihn,bjhn->bijh", Ch_k, Bh_k)           # (b, q, q, nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", Lmask * CB, xdt_k)
        # inter: C_i · (exp(cs_i) · S_prev)
        w_in = jnp.exp(dA_cs_k)                                   # (b, q, nh)
        y_inter = jnp.einsum("bihn,bih,bhpn->bihp", Ch_k, w_in, S_prev)
        return y_intra + y_inter

    ys = jax.lax.map(
        one_chunk,
        (
            jnp.moveaxis(xdt_c, 1, 0),
            jnp.moveaxis(dA_cs, 1, 0),
            jnp.moveaxis(Bh, 1, 0),
            jnp.moveaxis(Ch, 1, 0),
            entries,
        ),
    )                                                        # (nc, b, q, nh, hp)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, nh, hp)
    return y, final_state


def ssm_forward(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                    # (b, l, d)
    cfg: SSMConfig,
    rms_eps: float,
    initial_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
    shard=lambda t, logical: t,
):
    """Full Mamba-2 block: in-proj → conv → SSD → gated norm → out-proj."""
    b, l, d = x.shape
    dims = ssm_dims(d, cfg)
    di, nh = dims["d_inner"], dims["n_heads"]
    g, n, hp = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = shard(x @ params["w_in"], ("batch", "seq", "mlp"))
    z, xBC, dt = _split_zxbcdt(zxbcdt, di, g, n, nh)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :di].reshape(b, l, nh, hp)
    B = xBC[..., di : di + g * n].reshape(b, l, g, n)
    C = xBC[..., di + g * n :].reshape(b, l, g, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # (nh,) negative
    dA = (dt * A).astype(jnp.float32)                         # (b, l, nh)
    xdt = xs * dt.astype(xs.dtype)[..., None]

    y, state = ssd_chunked(xdt, dA, B, C, cfg.chunk, initial_state)
    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(b, l, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], rms_eps)
    out = shard(y @ params["w_out"], ("batch", "seq", None))
    if return_state:
        return out, state
    return out


def ssm_decode_step(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                    # (b, 1, d)
    cfg: SSMConfig,
    rms_eps: float,
    state: jnp.ndarray,                # (b, nh, hp, n)
    conv_cache: jnp.ndarray,           # (b, d_conv-1, conv_dim)
):
    """O(1) single-token step.  Returns (out, new_state, new_conv_cache)."""
    b, _, d = x.shape
    dims = ssm_dims(d, cfg)
    di, nh, cd = dims["d_inner"], dims["n_heads"], dims["conv_dim"]
    g, n, hp = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = x @ params["w_in"]
    z, xBC, dt = _split_zxbcdt(zxbcdt, di, g, n, nh)
    window = jnp.concatenate([conv_cache, xBC], axis=1)       # (b, d_conv, cd)
    new_conv_cache = window[:, 1:]
    conv_out = jnp.einsum("btc,tc->bc", window, params["conv_w"]) + params["conv_b"]
    xBC1 = jax.nn.silu(conv_out)[:, None, :]

    xs = xBC1[..., :di].reshape(b, nh, hp)
    B = xBC1[..., di : di + g * n].reshape(b, g, n)
    C = xBC1[..., di + g * n :].reshape(b, g, n)
    hpg = nh // g
    Bh = jnp.repeat(B, hpg, axis=1)                           # (b, nh, n)
    Ch = jnp.repeat(C, hpg, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (b, nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                       # (b, nh)
    xdt = xs * dt.astype(xs.dtype)[..., None]                 # (b, nh, hp)

    new_state = (
        a[..., None, None] * state
        + jnp.einsum("bhp,bhn->bhpn", xdt, Bh).astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + params["D"][None, :, None] * xs
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], rms_eps)
    return y @ params["w_out"], new_state, new_conv_cache
