"""AdamW with fp32 master weights, cosine schedule, global-norm clipping.

Mixed-precision discipline (DESIGN §6):
  * live params are bf16 (matmul inputs);
  * the optimizer state holds an fp32 master copy plus fp32 (m, v);
  * gradients arrive bf16 (the "gradient compression" reduction dtype — DP
    all-reduces move half the bytes), are accumulated/updated in fp32;
  * optimizer state shards exactly like the parameters (FSDP rules make this
    ZeRO-3; with pure DP the ``fsdp`` logical axis still shards the state —
    ZeRO-1 — because the state decls reuse the parameter logical axes).

Pure-pytree implementation (no optax dependency).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray          # () int32
    master: Any                # fp32 copy of params
    m: Any
    v: Any


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to lr_min."""
    step_f = step.astype(jnp.float32)
    warm = cfg.lr_peak * step_f / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step_f - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step_f < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> OptState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def abstract_opt_state(abstract_params: Any) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        master=jax.tree.map(f32, abstract_params),
        m=jax.tree.map(f32, abstract_params),
        v=jax.tree.map(f32, abstract_params),
    )


def global_norm(grads: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))


def _decay_mask(path: Tuple, leaf) -> bool:
    """No weight decay on norms / biases / scalars (1-D leaves)."""
    return leaf.ndim >= 2


def apply_updates(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: OptState,
    param_dtype=jnp.bfloat16,
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if master.ndim >= 2:
            delta = delta + cfg.weight_decay * master
        new_master = master - lr * delta
        return m2, v2, new_master

    out = jax.tree.map(upd, grads, state.m, state.v, state.master)
    m2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp: mp.astype(param_dtype), master2)
    new_state = OptState(step=step, master=master2, m=m2, v=v2)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
