"""Deterministic, seekable data pipeline (fault tolerance requirement).

Every batch is a pure function of (seed, step) — ``batch_at(step)`` — so a
restarted worker resumes mid-epoch with zero coordination state beyond the
step counter in the checkpoint.  No iterator state is ever persisted.

Three sources:
  * ``SyntheticLM``      — fast hash-derived token streams (smoke/e2e tests);
  * ``CorpusLM``         — tokenized byte corpus, strided windows over a
                           document ring (deterministic shuffling by step);
  * ``RegexStructured``  — the paper's `regrep` use-case as a *pipeline
                           stage*: synthesizes structured records from an RE,
                           and (via the parallel parser) extracts group spans
                           to build supervised extraction examples — the RE
                           parser as a first-class data-plane feature.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


def _philox(seed: int, step: int, n: int, lo: int, hi: int) -> np.ndarray:
    """Deterministic ints from (seed, step) — numpy Philox counter RNG."""
    rng = np.random.Generator(np.random.Philox(key=seed, counter=[0, 0, 0, step]))
    return rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        toks = _philox(
            self.seed, step, self.global_batch * self.seq_len, 0, self.vocab_size
        ).reshape(self.global_batch, self.seq_len)
        return {"tokens": toks}


@dataclasses.dataclass(frozen=True)
class CorpusLM:
    """Byte-level LM windows over an in-memory corpus, seekable by step."""

    corpus: bytes
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        n = len(self.corpus) - self.seq_len - 1
        assert n > 0, "corpus shorter than seq_len"
        starts = _philox(self.seed, step, self.global_batch, 0, n)
        buf = np.frombuffer(self.corpus, dtype=np.uint8)
        rows = np.stack([buf[s : s + self.seq_len] for s in starts])
        return {"tokens": rows.astype(np.int32)}


# ------------------------------------------------------- regex-structured


@dataclasses.dataclass
class RegexStructured:
    """Structured-record source driven by an RE (paper Sect. 1 `regrep` case).

    ``pattern`` describes one record (groups mark fields).  Records are
    *generated* by sampling the RE's AST (REgen-style, App. A of the paper)
    and *parsed back* with the parallel parser; the group spans from the SLPF
    become extraction labels.  This closes the loop: the same automaton
    artifacts serve the data plane and the serving plane.
    """

    pattern: str
    seq_len: int
    global_batch: int
    seed: int = 0
    n_chunks: int = 8

    def __post_init__(self):
        from ..core.engine import ParserEngine
        from ..core.reference import ParallelArtifacts
        from .regen import sample_string

        self._art = ParallelArtifacts.generate(self.pattern)
        self._engine = ParserEngine(self._art.matrices)
        self._sample = sample_string

    def record_at(self, seed: int) -> bytes:
        from ..core import regex as rx

        ast = self._art.table.numbered.ast
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=[1, 0, 0, seed]))
        return self._sample(ast, rng)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rows = np.zeros((self.global_batch, self.seq_len), dtype=np.int32)
        spans: List[List[Tuple[int, int, int]]] = []
        for i in range(self.global_batch):
            rec = self.record_at(step * self.global_batch + i)[: self.seq_len]
            arr = np.frombuffer(rec, dtype=np.uint8).astype(np.int32)
            rows[i, : len(arr)] = arr
            slpf = self._engine.parse(rec, n_chunks=self.n_chunks)
            tree = next(slpf.iter_trees(limit=1), None)
            spans.append(slpf.get_children(tree) if tree is not None else [])
        max_spans = max(1, max(len(s) for s in spans))
        span_arr = np.full((self.global_batch, max_spans, 3), -1, dtype=np.int32)
        for i, s in enumerate(spans):
            for j, (num, a, b) in enumerate(s[:max_spans]):
                span_arr[i, j] = (num, a, b)
        return {"tokens": rows, "spans": span_arr}
