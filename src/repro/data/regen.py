"""REgen-style random RE and valid-text generation (paper Sect. 5.1, ref. 20).

Used by the REGEN benchmark (segment-count scatter, Fig. 20 analogue; speed-up
sweeps) and by the RegexStructured pipeline.  Two functions:

  * ``random_regex(size, rng)``  — a random RE AST of ~``size`` symbols drawn
    from concatenation / union / star / cross / optional over a small terminal
    alphabet (the distribution mirrors REgen's: leaf-heavy, shallow operators);
  * ``sample_string(ast, rng)``  — a random valid string of the RE (uniform
    local choices; iterators sample geometric repeat counts).
"""

from __future__ import annotations

from typing import List

import numpy as np

from ..core import regex as rx

_ALPHABET = [ord(c) for c in "abcdxyz01"]


def random_regex(size: int, rng: np.random.Generator) -> rx.Node:
    """Random RE AST with roughly ``size`` symbols (terminals + operators)."""

    def gen(budget: int) -> rx.Node:
        if budget <= 1:
            return rx.Lit(int(rng.choice(_ALPHABET)))
        r = rng.random()
        if r < 0.40:  # concatenation
            k = int(rng.integers(2, min(4, budget) + 1))
            parts = _split_budget(budget - 1, k, rng)
            return rx.Cat(tuple(gen(b) for b in parts))
        if r < 0.70:  # union
            k = int(rng.integers(2, min(3, budget) + 1))
            parts = _split_budget(budget - 1, k, rng)
            return rx.Alt(tuple(gen(b) for b in parts))
        if r < 0.80:
            return rx.Star(_non_nullable(gen(budget - 1), rng))
        if r < 0.90:
            return rx.Plus(_non_nullable(gen(budget - 1), rng))
        if r < 0.95:
            return rx.Opt(_non_nullable(gen(budget - 1), rng))
        return rx.Group(gen(budget - 1))

    return gen(max(1, size))


def _non_nullable(node: rx.Node, rng: np.random.Generator) -> rx.Node:
    """Avoid infinitely-ambiguous REs (iterator over nullable body)."""
    if rx.nullable(node):
        return rx.Cat((rx.Lit(int(rng.choice(_ALPHABET))), node))
    return node


def _split_budget(budget: int, k: int, rng: np.random.Generator) -> List[int]:
    cuts = sorted(rng.integers(1, max(budget, 2), size=k - 1).tolist())
    parts = []
    prev = 0
    for c in cuts + [budget]:
        parts.append(max(1, c - prev))
        prev = c
    return parts


def sample_string(node: rx.Node, rng: np.random.Generator, max_rep: int = 4) -> bytes:
    if isinstance(node, rx.Lit):
        return bytes([node.char])
    if isinstance(node, rx.CharClass):
        members = [c for lo, hi in node.ranges for c in range(lo, min(hi, 255) + 1)]
        return bytes([int(rng.choice(members))])
    if isinstance(node, rx.Eps):
        return b""
    if isinstance(node, rx.Cat):
        return b"".join(sample_string(i, rng, max_rep) for i in node.items)
    if isinstance(node, rx.Alt):
        return sample_string(node.items[int(rng.integers(len(node.items)))], rng, max_rep)
    if isinstance(node, rx.Star):
        n = int(rng.integers(0, max_rep + 1))
        return b"".join(sample_string(node.item, rng, max_rep) for _ in range(n))
    if isinstance(node, rx.Plus):
        n = int(rng.integers(1, max_rep + 1))
        return b"".join(sample_string(node.item, rng, max_rep) for _ in range(n))
    if isinstance(node, rx.Opt):
        return sample_string(node.item, rng, max_rep) if rng.random() < 0.5 else b""
    if isinstance(node, rx.Repeat):
        hi = node.hi if node.hi is not None else node.lo + max_rep
        n = int(rng.integers(node.lo, hi + 1))
        return b"".join(sample_string(node.item, rng, max_rep) for _ in range(n))
    if isinstance(node, rx.Group):
        return sample_string(node.item, rng, max_rep)
    raise TypeError(node)
