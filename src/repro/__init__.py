"""repro — a parallel parser for regular expressions (JAX/Pallas).

Public surface (``repro/api.py`` is the one supported entry point):

    import repro

    p = repro.Parser("(a|b|ab)+")                 # or repro.ParserConfig(...)
    r = p.parse("abab")                           # ParseResult
    r.ok, r.count_trees(), r.matches(1), r.trees(limit=4)

    ticket = p.submit(text, deadline_s=0.050)     # deadline-aware admission
    stream = p.open_stream(); stream.append("ab") # incremental parsing
    p.stats()                                     # both services + SLO grades

Exports resolve lazily: ``import repro`` is free (no jax import); the cost
is paid on first attribute access, and only for the layer you touch —
``repro.errors`` / ``repro.ParseError`` never import jax at all.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

# attribute name → (module, attribute) — resolved on first access
_EXPORTS = {
    # facade (repro/api.py)
    "Parser": ("repro.api", "Parser"),
    "ParserFleet": ("repro.api", "ParserFleet"),
    "ParserConfig": ("repro.api", "ParserConfig"),
    "SLOTargets": ("repro.api", "SLOTargets"),
    "ObsConfig": ("repro.obs", "ObsConfig"),
    "ParseResult": ("repro.api", "ParseResult"),
    "ParseTicket": ("repro.api", "ParseTicket"),
    "ParserStream": ("repro.api", "ParserStream"),
    # forest + backend registry helpers
    "SLPF": ("repro.core.slpf", "SLPF"),
    "compress": ("repro.core.slpf", "compress"),
    "ParserBackend": ("repro.core.backend", "ParserBackend"),
    "register_backend": ("repro.core.backend", "register_backend"),
    "get_backend": ("repro.core.backend", "get_backend"),
    "list_backends": ("repro.core.backend", "list_backends"),
    # typed errors (jax-free module)
    "ParseError": ("repro.errors", "ParseError"),
    "AdmissionError": ("repro.errors", "AdmissionError"),
    "SessionNotFound": ("repro.errors", "SessionNotFound"),
    "BudgetExceeded": ("repro.errors", "BudgetExceeded"),
    "PathologicalPatternError": ("repro.errors", "PathologicalPatternError"),
}

__all__ = sorted(_EXPORTS) + ["analyze", "api", "errors", "obs"]

if TYPE_CHECKING:  # static importers see the real types
    from .api import (  # noqa: F401
        ParseResult,
        ParseTicket,
        Parser,
        ParserConfig,
        ParserFleet,
        ParserStream,
        SLOTargets,
    )
    from .core.backend import (  # noqa: F401
        ParserBackend,
        get_backend,
        list_backends,
        register_backend,
    )
    from .core.slpf import SLPF, compress  # noqa: F401
    from .errors import (  # noqa: F401
        AdmissionError,
        BudgetExceeded,
        ParseError,
        PathologicalPatternError,
        SessionNotFound,
    )
    from .obs import ObsConfig  # noqa: F401


def __getattr__(name: str):
    import importlib

    if name in ("analyze", "api", "errors", "obs"):   # advertised submodules
        value = importlib.import_module(f"repro.{name}")
        globals()[name] = value
        return value
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
