"""yi-6b [dense] — 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
— llama-arch GQA [arXiv:2403.04652; hf].  RoPE theta 5e6 per the model card.
long_500k skipped: pure full attention (DESIGN §5).
"""

from ..models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        skip_shapes=(
            ("long_500k", "pure full attention; 500k-token decode requires sub-quadratic attention"),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=1,   # same 8:1 GQA grouping
        d_ff=176,
        vocab_size=128,
        rope_theta=5_000_000.0,
    )
