"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-arch small [arXiv:2401.02385; hf].
long_500k skipped: pure full attention (DESIGN §5).
"""

from ..models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        n_layers=22,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        skip_shapes=(
            ("long_500k", "pure full attention; 500k-token decode requires sub-quadratic attention"),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=1,
        d_ff=176,
        vocab_size=128,
    )
