"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24 → MHA) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec/T5 conditioning frontend is a STUB per the assignment:
``input_specs`` provides 64 precomputed conditioning frame embeddings
prepended to the EnCodec token sequence.  The published model interleaves 4
codebooks with a delay pattern; shape-wise that is a plain token stream over
vocab 2048, which is what we model (DESIGN §8).
long_500k skipped: pure full attention (DESIGN §5).
"""

from ..models.config import FrontendConfig, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        frontend=FrontendConfig(kind="audio", n_extra_tokens=64, feature_dim=768),
        skip_shapes=(
            ("long_500k", "pure full attention; 500k-token decode requires sub-quadratic attention"),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab_size=128,
        frontend=FrontendConfig(kind="audio", n_extra_tokens=4, feature_dim=32),
    )
