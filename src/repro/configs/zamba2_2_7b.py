"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32 → MHA shared block)
d_ff=10240 vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention
blocks [arXiv:2411.15242; hf].

Simplification recorded in DESIGN §8: the published model concatenates the
original embedding into the shared block input and applies per-invocation
LoRA; we apply one weight-shared attention+MLP block every 6 Mamba2 layers
(9 applications) on the hidden stream — same compute/communication shape.

long_500k RUNS: the backbone is SSM (constant-size state); the shared
attention block uses the sequence-sharded cache (DESIGN §5).
"""

from ..models.config import ModelConfig, SSMConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(d_state=64),
        layout=("ssm",) * 54,
        shared_attn_every=6,
        shared_attn_heads=32,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab_size=128,
        ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
        layout=("ssm",) * 4,
        shared_attn_every=2,
        shared_attn_heads=4,
    )
