"""mamba2-2.7b [ssm] — 64L d_model=2560 (attention-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].

The SSD layer runs on the paper's chunked reach/join/build runtime
(``core/scan.py``; DESIGN §4) — the honest integration point between the
paper's parallel-FA technique and the assigned architectures.
long_500k RUNS: constant-size recurrent state (DESIGN §5).
"""

from ..models.config import ModelConfig, SSMConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        n_layers=64,
        d_model=2560,
        n_heads=1,       # no attention layers; placeholder for config plumbing
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(d_state=128),
        layout=("ssm",) * 64,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=128,
        ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
        layout=("ssm",) * 2,
        tie_embeddings=True,
    )
