"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

Note: phi-3-medium-128k uses LongRoPE scaling; we use plain RoPE (theta=1e4)
— positional-embedding scaling does not change shapes/FLOPs (DESIGN §8).
long_500k skipped: pure full attention (DESIGN §5).
"""

from ..models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=10_000.0,
        skip_shapes=(
            ("long_500k", "pure full attention; 500k-token decode requires sub-quadratic attention"),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,   # same GQA family (4:1 grouping)
        d_ff=224,
        vocab_size=128,
    )
