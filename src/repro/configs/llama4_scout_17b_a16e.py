"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

16 experts divide TP=16 → expert parallelism over 'model' (DESIGN §6).
"Early fusion" multimodality is a frontend concern; the backbone here is the
text decoder (the assignment stubs modality frontends).
long_500k skipped: the spec'd global-attention layers make it full attention.
"""

from ..models.config import ModelConfig, MoEConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, shared_expert=True),
        skip_shapes=(
            ("long_500k", "global-attention layers; 500k-token decode requires sub-quadratic attention"),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=128,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, shared_expert=True),
    )
