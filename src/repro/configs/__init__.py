"""Architecture registry: one module per assigned architecture (``--arch <id>``).

Each module exposes ``build()`` (the exact published config) and ``smoke()``
(a reduced same-family config for CPU tests).  ``get_config`` / ``get_smoke``
resolve canonical dash-separated ids.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

_ARCH_MODULES: Dict[str, str] = {
    "phi3-medium-14b": "phi3_medium_14b",
    "yi-6b": "yi_6b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-1b": "internvl2_1b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-2.7b": "mamba2_2_7b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    return importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).build()


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()
