"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].  SWA window 4096 (mistral default).

long_500k RUNS: sliding-window attention is sub-quadratic — decode keeps a
window-sized ring-buffer cache (DESIGN §5).
"""

from ..models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        sliding_window=4096,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=160,
        vocab_size=128,
        sliding_window=16,
    )
