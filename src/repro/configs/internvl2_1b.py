"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
— InternViT + InternLM2(Qwen2-0.5B) backbone [arXiv:2404.16821; hf].

The InternViT vision frontend is a STUB per the assignment: ``input_specs``
provides 256 precomputed patch embeddings (1024-d InternViT features) that are
projected and prepended to the text sequence.
long_500k skipped: pure full attention (DESIGN §5).
"""

from ..models.config import FrontendConfig, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        rope_theta=1_000_000.0,
        frontend=FrontendConfig(kind="vision", n_extra_tokens=256, feature_dim=1024),
        skip_shapes=(
            ("long_500k", "pure full attention; 500k-token decode requires sub-quadratic attention"),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=7,      # same 7:1 grouping family as 14H/kv2
        n_kv_heads=1,
        d_ff=152,
        vocab_size=128,
        head_dim=16,
        frontend=FrontendConfig(kind="vision", n_extra_tokens=8, feature_dim=32),
    )
