"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088; hf].

Expert-FFN tensor parallelism (8 experts do not divide TP=16 → experts
replicate; each expert's hidden dim shards over 'model'; DESIGN §6).
long_500k RUNS: sliding-window attention is sub-quadratic (DESIGN §5).
"""

from ..models.config import ModelConfig, MoEConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        sliding_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=128,
        sliding_window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
    )
