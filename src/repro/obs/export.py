"""Exporters: JSONL span logs, Prometheus text snapshots, BENCH_*.json.

Three machine-readable formats, one module, all jax-free:

  span JSONL        one span dict per line (``trace.SPAN_SCHEMA_KEYS``) —
                    ``SpanJsonlWriter`` is a tracer sink that appends+flushes
                    per span, so a crashed process still leaves a valid log.

  Prometheus text   ``prometheus_text(snapshot)`` renders a registry
                    snapshot in the exposition format (``repro_``-prefixed,
                    HELP/TYPE headers, label escaping, histogram ``_bucket``/
                    ``_sum``/``_count`` expansion) — scrapeable as-is.

  BENCH_<name>.json the perf trajectory: every ``benchmarks/run.py`` gate
                    writes one report with the shared schema
                    ``{name, timestamp, config, metrics}`` through
                    ``write_bench_json``; ``validate_bench_report`` is the
                    schema the CI obs gate enforces over every
                    ``BENCH_*.json`` at the repo root.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List, Mapping, Optional, Union

from .metrics import METRIC_CATALOG, MetricsRegistry
from .trace import SPAN_SCHEMA_KEYS, Span

BENCH_SCHEMA_KEYS = ("name", "timestamp", "config", "metrics")


# ----------------------------------------------------------- span JSONL


class SpanJsonlWriter:
    """Tracer sink appending one JSON line per finished span (flushed)."""

    def __init__(self, path: Union[str, Path]):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = self.path.open("a", encoding="utf-8")
        self._lock = threading.Lock()

    def record(self, span: Span) -> None:
        line = json.dumps(span.to_dict(), sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()

    # the object itself is a valid sink callable
    __call__ = record

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


def write_spans_jsonl(spans: Iterable[Span], path: Union[str, Path]) -> Path:
    """One-shot dump of a span collection (e.g. ``tracer.drain()``)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w", encoding="utf-8") as fh:
        for sp in spans:
            fh.write(json.dumps(sp.to_dict(), sort_keys=True) + "\n")
    return path


def read_spans_jsonl(path: Union[str, Path]) -> List[Dict[str, Any]]:
    out = []
    for line in Path(path).read_text(encoding="utf-8").splitlines():
        if line.strip():
            out.append(json.loads(line))
    return out


def validate_span_dict(d: Mapping[str, Any]) -> None:
    """Schema check for one exported span line (raises ValueError)."""
    missing = set(SPAN_SCHEMA_KEYS) - set(d)
    if missing:
        raise ValueError(f"span missing keys {sorted(missing)}: {dict(d)!r}")
    if not isinstance(d["name"], str) or not d["name"]:
        raise ValueError(f"span name must be a non-empty string: {d['name']!r}")
    for key in ("t_start_s", "duration_s"):
        if not isinstance(d[key], (int, float)):
            raise ValueError(f"span {key} must be numeric: {d[key]!r}")
    if d["duration_s"] < 0:
        raise ValueError(f"span duration_s must be >= 0: {d['duration_s']!r}")
    if not isinstance(d["attrs"], dict):
        raise ValueError(f"span attrs must be a dict: {d['attrs']!r}")


def validate_span_tree(spans: List[Mapping[str, Any]], trace_id: str) -> Dict[str, Any]:
    """Structural check of one trace: exactly one root, every parent
    resolves, child durations fit inside the root span.  Returns
    ``{"root": ..., "children": [...]}`` for further assertions."""
    mine = [s for s in spans if s.get("trace_id") == trace_id]
    if not mine:
        raise ValueError(f"no spans for trace {trace_id!r}")
    ids = {s["span_id"] for s in mine}
    roots = [s for s in mine if s["parent_id"] is None]
    if len(roots) != 1:
        raise ValueError(
            f"trace {trace_id!r} has {len(roots)} roots (want exactly 1): "
            f"{[s['name'] for s in roots]}"
        )
    root = roots[0]
    children = [s for s in mine if s is not root]
    for s in children:
        if s["parent_id"] not in ids:
            raise ValueError(
                f"span {s['name']!r} parent {s['parent_id']!r} not in trace"
            )
    direct = [s for s in children if s["parent_id"] == root["span_id"]]
    # sequential direct children must fit inside the root wall-clock (small
    # tolerance: span exit bookkeeping happens after the clock read)
    total = sum(s["duration_s"] for s in direct)
    if total > root["duration_s"] * 1.05 + 1e-3:
        raise ValueError(
            f"trace {trace_id!r}: child durations {total:.6f}s exceed root "
            f"span {root['duration_s']:.6f}s"
        )
    return {"root": root, "children": children}


# ------------------------------------------------------- Prometheus text


def _prom_labels(labels: Mapping[str, str], extra: Optional[Dict[str, str]] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(items.items())
    )
    return "{" + body + "}"


def prometheus_text(
    snapshot: Union[MetricsRegistry, Mapping[str, List[Dict[str, Any]]]],
    *,
    prefix: str = "repro_",
) -> str:
    """Render a registry (or its ``snapshot()``) in Prometheus exposition
    format.  Accepts the aggregated process-wide snapshot too."""
    if isinstance(snapshot, MetricsRegistry):
        snapshot = snapshot.snapshot()
    lines: List[str] = []
    for name in sorted(snapshot):
        series = snapshot[name]
        kind, help_text = METRIC_CATALOG[name][0], METRIC_CATALOG[name][1]
        pname = prefix + name
        lines.append(f"# HELP {pname} {help_text}")
        lines.append(f"# TYPE {pname} {kind}")
        for s in series:
            labels, value = s["labels"], s["value"]
            if kind == "histogram":
                cum = 0
                for bound, cum in value["buckets"]:
                    lines.append(
                        f"{pname}_bucket{_prom_labels(labels, {'le': repr(bound)})} {cum}"
                    )
                lines.append(
                    f"{pname}_bucket{_prom_labels(labels, {'le': '+Inf'})} "
                    f"{value['count']}"
                )
                lines.append(f"{pname}_sum{_prom_labels(labels)} {value['sum']}")
                lines.append(f"{pname}_count{_prom_labels(labels)} {value['count']}")
            else:
                lines.append(f"{pname}{_prom_labels(labels)} {value}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------- BENCH_*.json


def write_bench_json(
    name: str,
    *,
    config: Mapping[str, Any],
    metrics: Mapping[str, Any],
    out_dir: Union[str, Path],
    timestamp: Optional[float] = None,
) -> Path:
    """Write one perf-trajectory entry ``BENCH_<name>.json``.

    Shared schema across every benchmark gate: ``name`` (the gate),
    ``timestamp`` (unix seconds, host clock), ``config`` (the run's knobs —
    quick/smoke sizes, backends), ``metrics`` (the measurements; the CSV rows
    live under ``metrics["rows"]``, richer structures under their own keys).
    """
    report = {
        "name": name,
        "timestamp": float(timestamp if timestamp is not None else time.time()),
        "config": dict(config),
        "metrics": dict(metrics),
    }
    validate_bench_report(report)
    out = Path(out_dir) / f"BENCH_{name}.json"
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return out


def validate_bench_report(d: Mapping[str, Any]) -> None:
    """Schema check for one BENCH_*.json report (raises ValueError)."""
    missing = set(BENCH_SCHEMA_KEYS) - set(d)
    if missing:
        raise ValueError(f"bench report missing keys {sorted(missing)}")
    extra = set(d) - set(BENCH_SCHEMA_KEYS)
    if extra:
        raise ValueError(f"bench report has unknown keys {sorted(extra)}")
    if not isinstance(d["name"], str) or not d["name"]:
        raise ValueError("bench report name must be a non-empty string")
    if not isinstance(d["timestamp"], (int, float)) or d["timestamp"] <= 0:
        raise ValueError(f"bench report timestamp invalid: {d['timestamp']!r}")
    for key in ("config", "metrics"):
        if not isinstance(d[key], dict):
            raise ValueError(f"bench report {key} must be a dict")
    json.dumps(d)  # must be round-trippable as-is
