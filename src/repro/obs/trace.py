"""Tracing: per-request trace IDs, monotonic-clock spans, a narrow record seam.

The paper's quantitative claims are *phase* claims — reach vs join vs
build&merge cost, chunk-processing vs joining (PaREM's attribution) — yet
until this PR the repo could only observe them through scattered
``time.perf_counter()`` deltas.  This module is the one tracing layer every
runtime layer records into:

  ``Span``     one timed operation: name, trace/span/parent IDs, a
               monotonic-clock start, a duration, and a small attribute
               dict.  Spans are plain host-side records — they never enter
               a jitted program (jax-safe by construction: timing wraps the
               *call* of a compiled program, with ``block_until_ready`` at
               the boundary, never the traced body).

  ``Tracer``   mints trace IDs (one per ``Parser.parse``/``submit``/
               ``append``), opens spans as context managers (parenting via a
               ``contextvars`` stack, so nested phase spans attach to the
               request span automatically), and ``emit``\\ s retroactive
               spans (queue-wait is only known when a batch picks the
               request up).  Finished spans go to a bounded ring buffer and
               to every registered sink — ``obs/export.py``'s
               ``SpanJsonlWriter`` is the standard one.

  profiler     with ``profiler=True`` every span also enters a
               ``jax.profiler.TraceAnnotation``, so the same phase names
               show up on real profiler timelines (TPU trace viewer) next
               to the device ops they wrap.  jax is imported lazily and only
               on that path — the module stays importable jax-free.

A disabled tracer (``Tracer(enabled=False)`` — the default every engine
carries) makes ``span``/``emit`` near-free no-ops: instrumentation stays in
place permanently and costs one predicate when off.

Span taxonomy (documented in ROADMAP "Observability"):

  parse.request            root — one submit/parse lifetime (queue + device + host)
  parse.queue_wait         submit → batch pickup (service queue residency)
  parse.batch_compute      the batched device program serving the bucket
  stream.append            root — one append lifetime
  stream.append_queue_wait append → piece-batch pickup
  stream.append_compute    the batched tail reach + compose
  stream.query             SLPF / acceptance materialization of a prefix
  stream.edit              one mid-text splice (segment-tree recompose path)
  phase.reach              chunk-product reach (device)
  phase.join               exclusive scan over stacked products (device)
  phase.build_merge        builder&merger over join entries (device)
  phase.host_build         host-side SLPF assembly (unpack + wrap)
  phase.device_parse       one fused/mesh device program (phases not split)
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import threading
import time
import uuid
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

# Span dict schema — the JSONL contract ``scripts/obs_smoke.py`` validates.
SPAN_SCHEMA_KEYS = (
    "name", "trace_id", "span_id", "parent_id", "t_start_s", "duration_s",
    "attrs",
)


def new_trace_id() -> str:
    """A fresh 16-hex-char trace ID (random — process-unique is enough)."""
    return uuid.uuid4().hex[:16]


@dataclass
class Span:
    """One finished (or in-flight) timed operation."""

    name: str
    trace_id: Optional[str]
    span_id: str
    parent_id: Optional[str]
    t_start_s: float              # monotonic (time.perf_counter) origin
    duration_s: float = 0.0
    attrs: Dict[str, Any] = field(default_factory=dict)

    def set_attr(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "t_start_s": self.t_start_s,
            "duration_s": self.duration_s,
            "attrs": self.attrs,
        }


class _NullSpan:
    """Attribute sink for disabled tracers (``set_attr`` is a no-op)."""

    __slots__ = ()

    def set_attr(self, key: str, value: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + ring buffer + sink fan-out (thread-safe on record)."""

    def __init__(
        self,
        *,
        enabled: bool = True,
        max_spans: int = 4096,
        profiler: bool = False,
    ):
        self.enabled = enabled
        self.profiler = profiler
        self.spans: Deque[Span] = deque(maxlen=max(1, max_spans))
        self._sinks: List[Callable[[Span], None]] = []
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        # the innermost open span of the current context — nested ``span()``
        # calls parent to it without explicit plumbing
        self._current: contextvars.ContextVar[Optional[Span]] = (
            contextvars.ContextVar("repro_obs_current_span", default=None)
        )

    # ------------------------------------------------------------------ ids

    def new_trace_id(self) -> Optional[str]:
        """Trace ID for one request — None when tracing is disabled, so
        callers can propagate the field unconditionally."""
        return new_trace_id() if self.enabled else None

    def _new_span_id(self) -> str:
        return f"{next(self._ids):08x}"

    def current_span(self) -> Optional[Span]:
        return self._current.get()

    # ---------------------------------------------------------------- spans

    @contextlib.contextmanager
    def span(
        self,
        name: str,
        *,
        trace_id: Optional[str] = None,
        parent_id: Optional[str] = None,
        **attrs: Any,
    ):
        """Open a timed span around a block; parents to the context span.

        The yielded object supports ``set_attr``.  Timing is monotonic
        (``time.perf_counter``); callers wrapping device work must block on
        the result inside the span (``jax.block_until_ready``) or the span
        measures only dispatch.
        """
        if not self.enabled:
            yield _NULL_SPAN
            return
        parent = self._current.get()
        sp = Span(
            name=name,
            trace_id=trace_id or (parent.trace_id if parent else None),
            span_id=self._new_span_id(),
            parent_id=parent_id or (parent.span_id if parent else None),
            t_start_s=time.perf_counter(),
            attrs=dict(attrs),
        )
        token = self._current.set(sp)
        try:
            if self.profiler:
                import jax.profiler  # lazy: only the profiler path pays jax

                with jax.profiler.TraceAnnotation(name):
                    yield sp
            else:
                yield sp
        finally:
            sp.duration_s = time.perf_counter() - sp.t_start_s
            self._current.reset(token)
            self._record(sp)

    def emit(
        self,
        name: str,
        *,
        t_start_s: float,
        duration_s: float,
        trace_id: Optional[str] = None,
        parent_id: Optional[str] = None,
        span_id: Optional[str] = None,
        **attrs: Any,
    ) -> Optional[Span]:
        """Record a retroactive span from already-measured times.

        The queue-wait seam: a request's wait is only known when a batch
        picks it up, so the service emits the span after the fact with the
        original enqueue time as ``t_start_s``.  ``span_id`` may be a
        pre-minted id (services mint the root id at submit so mid-flight
        children can parent to a root written later).
        """
        if not self.enabled:
            return None
        sp = Span(
            name=name,
            trace_id=trace_id,
            span_id=span_id if span_id is not None else self._new_span_id(),
            parent_id=parent_id,
            t_start_s=t_start_s,
            duration_s=duration_s,
            attrs=dict(attrs),
        )
        self._record(sp)
        return sp

    # ---------------------------------------------------------------- sinks

    def add_sink(self, sink: Callable[[Span], None]) -> None:
        """Register a sink called with every finished span (e.g.
        ``SpanJsonlWriter.record``)."""
        self._sinks.append(sink)

    def _record(self, sp: Span) -> None:
        with self._lock:
            self.spans.append(sp)
        for sink in self._sinks:
            sink(sp)

    def drain(self) -> List[Span]:
        """Return and clear the buffered spans (ring-buffer snapshot)."""
        with self._lock:
            out = list(self.spans)
            self.spans.clear()
        return out


#: Shared disabled tracer for layers constructed without observability.
NULL_TRACER = Tracer(enabled=False, max_spans=1)
