"""Unified observability: tracing spans + metrics registry + exporters.

``repro.obs`` is the one instrumentation layer of the parse runtime
(ISSUE 7 / ROADMAP "Observability").  Every ``ParserEngine`` carries an
``ObsHandle`` — a (tracer, metrics registry) pair — and every layer built
over that engine (phase programs, ``StreamingParser``, ``DistributedEngine``,
both services, the ``Parser`` facade) records into it through the same two
narrow seams:

    with engine.obs.span("phase.reach", bucket=(c, k)):
        ...device call + block_until_ready...
    engine.obs.metrics.counter("stream_evictions_total").inc()

The handle is always present (a disabled tracer + live registry by default),
so instrumentation is unconditional in the code and near-free when tracing
is off; ``ParserConfig(obs=ObsConfig(enabled=True, span_log=...))`` switches
a parser's handle to a recording tracer with a JSONL sink and optional
``jax.profiler`` trace annotations.

Submodules:

  trace.py     ``Span``/``Tracer`` — monotonic spans, trace IDs, the span
               taxonomy (request / queue-wait / compute / phase spans).
  metrics.py   ``MetricsRegistry`` — cataloged counters/gauges/bounded
               histograms; process-wide ``aggregate_snapshot``.
  export.py    JSONL span logs, Prometheus text, and the shared
               ``BENCH_<name>.json`` perf-trajectory schema.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .export import (
    BENCH_SCHEMA_KEYS,
    SpanJsonlWriter,
    prometheus_text,
    read_spans_jsonl,
    validate_bench_report,
    validate_span_dict,
    validate_span_tree,
    write_bench_json,
    write_spans_jsonl,
)
from .metrics import (
    METRIC_CATALOG,
    MetricsRegistry,
    aggregate_snapshot,
    validate_metric_names,
)
from .trace import NULL_TRACER, SPAN_SCHEMA_KEYS, Span, Tracer, new_trace_id


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Declarative observability knobs (a ``ParserConfig`` field).

    ``enabled`` switches tracing on (metrics are ALWAYS collected — they are
    O(1) host mutations); ``span_log`` adds a JSONL sink for finished spans;
    ``profiler`` wraps every span in a ``jax.profiler.TraceAnnotation`` so
    phase names appear on real profiler timelines; ``hlo`` attaches
    ``launch/hlo_stats.py`` static cost to each compiled bucket in
    ``Parser.stats()`` (one extra lowering per bucket, memoized);
    ``max_spans`` bounds the tracer's in-memory ring buffer.
    """

    enabled: bool = False
    span_log: Optional[str] = None
    profiler: bool = False
    hlo: bool = True
    max_spans: int = 4096

    def __post_init__(self):
        if self.max_spans < 1:
            raise ValueError(f"max_spans must be >= 1, got {self.max_spans}")
        if self.span_log is not None and not isinstance(self.span_log, str):
            raise ValueError("span_log must be a path string or None")


class ObsHandle:
    """The (tracer, registry) pair every engine carries.

    Construction is cheap and jax-free; the default handle has a disabled
    tracer, so un-configured engines pay one predicate per would-be span.
    """

    def __init__(
        self,
        *,
        tracer: Optional[Tracer] = None,
        registry: Optional[MetricsRegistry] = None,
        config: Optional[ObsConfig] = None,
    ):
        self.config = config if config is not None else ObsConfig()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.registry = registry if registry is not None else MetricsRegistry()
        self._span_sink: Optional[SpanJsonlWriter] = None

    @classmethod
    def from_config(cls, cfg: Optional[ObsConfig]) -> "ObsHandle":
        if cfg is None:
            cfg = ObsConfig()
        tracer = Tracer(
            enabled=cfg.enabled, max_spans=cfg.max_spans, profiler=cfg.profiler
        )
        handle = cls(tracer=tracer, config=cfg)
        if cfg.enabled and cfg.span_log:
            handle._span_sink = SpanJsonlWriter(cfg.span_log)
            tracer.add_sink(handle._span_sink)
        if cfg.enabled:
            spans = handle.registry.counter("spans_recorded_total")
            tracer.add_sink(lambda _sp: spans.inc())
        return handle

    # ---------------------------------------------------------- delegation

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled

    @property
    def metrics(self) -> MetricsRegistry:
        return self.registry

    def span(self, name: str, **kw):
        return self.tracer.span(name, **kw)

    def emit(self, name: str, **kw):
        return self.tracer.emit(name, **kw)

    def new_trace_id(self) -> Optional[str]:
        return self.tracer.new_trace_id()

    def close(self) -> None:
        """Flush and close the JSONL sink, if any."""
        if self._span_sink is not None:
            self._span_sink.close()


__all__ = [
    "BENCH_SCHEMA_KEYS",
    "METRIC_CATALOG",
    "MetricsRegistry",
    "NULL_TRACER",
    "ObsConfig",
    "ObsHandle",
    "SPAN_SCHEMA_KEYS",
    "Span",
    "SpanJsonlWriter",
    "Tracer",
    "aggregate_snapshot",
    "new_trace_id",
    "prometheus_text",
    "read_spans_jsonl",
    "validate_bench_report",
    "validate_metric_names",
    "validate_span_dict",
    "validate_span_tree",
    "write_bench_json",
    "write_spans_jsonl",
]
