"""Process-wide metrics registry: counters, gauges, bounded histograms.

One registry per engine (every layer over that engine — both services, the
streaming parsers, the distributed runtime — records into it), plus a
process-wide aggregation over every live registry for export.  This replaces
the per-service hand-rolled ``stats()`` dicts as the source of truth for
counter-like observables; ``Parser.stats()`` is a *view* over it.

Design constraints:

  jax-free      importing this module never touches jax; every update is a
                tiny host-side mutation, safe to call from trace-time Python
                side effects (the engine's compile counter pattern).
  bounded       histograms hold fixed bucket counts + count/sum — O(1)
                memory per metric regardless of traffic (the per-bucket
                p50/p99 *windows* stay in ``serve/parse_service.py``'s
                ``BucketStats``, which is a deliberate sliding-window
                estimator, not a metric).
  cataloged     every metric name must be declared in ``METRIC_CATALOG``;
                creating an unknown name raises immediately and the CI obs
                gate re-validates exported snapshots — silent
                instrumentation rot (a renamed metric nobody notices) fails
                loudly instead.

Metric identity is (name, frozen label set); the same name may carry many
label sets (e.g. ``admission_rejects_total{service="parse", cause="deadline"}``).
"""

from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

# --------------------------------------------------------------- catalog

#: Default histogram bounds (seconds-ish / count-ish; per-metric overrides
#: below).  Upper-open last bucket is implicit (+Inf).
_DEFAULT_BOUNDS = (0.001, 0.01, 0.1, 1.0, 10.0)

#: name -> (kind, help text[, histogram bounds])
METRIC_CATALOG: Dict[str, Tuple] = {
    # request / append flow
    "requests_total": ("counter", "parse requests submitted"),
    "appends_total": ("counter", "stream appends queued"),
    "served_total": ("counter", "requests/appends fully served"),
    "batches_total": ("counter", "batched device programs dispatched"),
    "cancelled_total": ("counter", "queued requests cancelled"),
    "chars_total": ("counter", "input characters accepted into the queue"),
    "queue_depth": ("gauge", "live queued requests/appends"),
    "peak_queue_depth": ("gauge", "high-water queue depth"),
    # admission / SLO
    "admission_rejects_total": (
        "counter",
        "admission rejections by cause (deadline|budget|tenant_budget|pathological)",
    ),
    # static analysis (repro.analyze, leg 1)
    "analyzer_verdicts_total": (
        "counter", "static pattern analyses by verdict (ok|pathological)",
    ),
    "auto_backend_selected_total": (
        "counter", 'backend="auto" resolutions by chosen backend',
    ),
    # engine program cache
    "compiled_programs_total": ("counter", "device programs traced (re-jit events)"),
    "bucket_cache_hits_total": ("counter", "parses served by an already-compiled bucket"),
    "bucket_cache_misses_total": ("counter", "parses that compiled a new bucket shape"),
    # fleet transition-table compile cache (core/fleet.py)
    "table_cache_hits_total": (
        "counter", "tenant table compiles served from the process-wide cache",
    ),
    "table_cache_misses_total": (
        "counter", "tenant table compiles that built matrices from the regex",
    ),
    "fleet_tenants": ("gauge", "tenants registered on a FleetEngine"),
    "fleet_buckets": ("gauge", "distinct (backend, class, ℓp) automaton buckets"),
    # streaming cache
    "stream_sessions": ("gauge", "open streaming sessions"),
    "stream_bytes_cached": ("gauge", "device bytes resident in prefix caches"),
    "stream_evictions_total": ("counter", "sealed products / caches evicted"),
    "stream_bytes_reclaimed_total": ("counter", "device bytes freed by eviction"),
    "stream_rebuilds_total": (
        "counter", "evicted chunk products re-reached (counted per chunk)",
    ),
    # streaming edits (product segment tree)
    "stream_edits_total": ("counter", "mid-text splices served by streams"),
    "stream_edit_recompose_depth": (
        "histogram", "internal products re-composed per edit (tree spine depth)",
        (0, 1, 2, 4, 8, 16, 32, 64),
    ),
    # distribution
    "allgather_payload_bytes_total": (
        "counter", "product-stack bytes moved through the mesh all-gather",
    ),
    # speculation (sparse backend)
    "speculation_width": (
        "histogram", "observed feasible-start width per parse",
        (1, 2, 4, 8, 16, 32, 64, 128, 256, 512),
    ),
    # static modeled cost (launch/hlo_stats.py, per compiled bucket + phase)
    "hlo_flops": ("gauge", "static flops of one compiled phase program"),
    "hlo_bytes": ("gauge", "static HBM-model bytes of one compiled phase program"),
    "hlo_collective_bytes": (
        "gauge", "static collective bytes of one compiled phase program",
    ),
    # tracing plumbing
    "spans_recorded_total": ("counter", "finished spans recorded by the tracer"),
}


def _label_key(labels: Mapping[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


# --------------------------------------------------------------- metrics


@dataclass
class Counter:
    """Monotonic counter — ``inc`` only, never decremented (tested)."""

    name: str
    labels: Tuple[Tuple[str, str], ...]
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v


@dataclass
class Gauge:
    name: str
    labels: Tuple[Tuple[str, str], ...]
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v


class Histogram:
    """Fixed-bound cumulative histogram (Prometheus semantics, +Inf implicit)."""

    def __init__(self, name: str, labels, bounds: Iterable[float]):
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram {name} bounds must be sorted")
        self.bucket_counts = [0] * (len(self.bounds) + 1)   # last = +Inf
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def value(self) -> Dict[str, Any]:
        cum, out = 0, []
        for b, c in zip(self.bounds, self.bucket_counts[:-1]):
            cum += c
            out.append([b, cum])
        return {"count": self.count, "sum": self.sum, "buckets": out}


_KIND = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


# -------------------------------------------------------------- registry

#: Every live registry, for the process-wide aggregated export.
_REGISTRIES: "weakref.WeakSet[MetricsRegistry]" = weakref.WeakSet()


class MetricsRegistry:
    """Get-or-create metric store validated against ``METRIC_CATALOG``."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], Any] = {}
        self._lock = threading.Lock()
        _REGISTRIES.add(self)

    def _get(self, kind: str, name: str, labels: Mapping[str, str], **kw):
        spec = METRIC_CATALOG.get(name)
        if spec is None:
            raise KeyError(
                f"unknown metric {name!r} — declare it in "
                f"repro.obs.metrics.METRIC_CATALOG (instrumentation-rot guard)"
            )
        if spec[0] != kind:
            raise TypeError(f"metric {name!r} is a {spec[0]}, not a {kind}")
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                if kind == "histogram":
                    bounds = kw.get("bounds") or (
                        spec[2] if len(spec) > 2 else _DEFAULT_BOUNDS
                    )
                    m = Histogram(name, key[1], bounds)
                else:
                    m = _KIND[kind](name, key[1])
                self._metrics[key] = m
        return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(
        self, name: str, *, bounds: Optional[Iterable[float]] = None, **labels: str
    ) -> Histogram:
        return self._get("histogram", name, labels, bounds=bounds)

    # ------------------------------------------------------------ reading

    def names(self) -> List[str]:
        with self._lock:
            return sorted({name for name, _ in self._metrics})

    def snapshot(self) -> Dict[str, List[Dict[str, Any]]]:
        """{name: [{"labels": {...}, "kind": ..., "value": ...}, ...]} —
        plain JSON-able values (histograms expand to count/sum/buckets)."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, List[Dict[str, Any]]] = {}
        for (name, labels), m in sorted(items, key=lambda kv: kv[0]):
            out.setdefault(name, []).append(
                {
                    "labels": dict(labels),
                    "kind": METRIC_CATALOG[name][0],
                    "value": m.value,
                }
            )
        return out


def aggregate_snapshot() -> Dict[str, List[Dict[str, Any]]]:
    """Process-wide view: merged snapshots of every live registry."""
    out: Dict[str, List[Dict[str, Any]]] = {}
    for reg in list(_REGISTRIES):
        for name, series in reg.snapshot().items():
            out.setdefault(name, []).extend(series)
    return out


def validate_metric_names(names: Iterable[str]) -> None:
    """Raise on any metric name missing from the catalog (CI obs gate)."""
    unknown = sorted(set(names) - set(METRIC_CATALOG))
    if unknown:
        raise KeyError(f"unknown metric names in snapshot: {unknown}")
