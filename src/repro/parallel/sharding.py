"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) over the production mesh.

Every parameter and activation in the model stack is annotated with *logical*
axis names; ``MeshRules`` maps them to physical mesh axes.  The production mesh
is ``('data', 'model')`` single-pod and ``('pod', 'data', 'model')`` multi-pod
(``launch/mesh.py``); the rules below scale to any pod count because the
``pod`` axis only ever carries batch (pure DP) and the parser's chunk axis.

Default mapping (MaxText-style fsdp+tp):
  batch   → ('pod', 'data')     data parallel over pods × data
  fsdp    → 'data'              parameter/optimizer sharding (ZeRO-3 style)
  heads   → 'model'             tensor parallel attention
  kv_heads→ 'model' when divisible, else replicated (GQA, exact)
  mlp     → 'model'             tensor parallel FFN
  vocab   → 'model'             tensor parallel embedding / logits
  experts → 'model' when E % TP == 0 (expert parallel), else replicated
            (expert-FFN hidden dim then carries 'model' instead)
  seq     → None (replicated); 'chunk' → ('pod','data') for the parser/SSM
            context-parallel long-sequence path.

A logical axis resolving to a mesh axis already used by another dim of the same
tensor is dropped (replicated) — PartitionSpec axes must be disjoint.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class MeshRules:
    """Logical-axis → mesh-axis mapping."""

    rules: Dict[str, Axis] = field(
        default_factory=lambda: {
            "batch": ("pod", "data"),
            "fsdp": "data",
            "heads": "model",
            "kv_heads": "model",
            "mlp": "model",
            "vocab": "model",
            "experts": "model",
            "expert_mlp": "model",
            "d_state": None,
            "embed": None,
            "seq": None,
            "cache_seq": "model",  # decode-cache slots: flash-decode sharding
            "chunk": ("pod", "data"),
            "stack": None,  # scan-over-layers leading dim
        }
    )

    def resolve(self, logical: Sequence[Axis], mesh: Optional[Mesh] = None) -> PartitionSpec:
        """Map per-dim logical names to a PartitionSpec, dropping mesh axes that
        are absent from ``mesh`` or already used by an earlier dim."""
        used: set = set()
        out = []
        avail = set(mesh.axis_names) if mesh is not None else None
        for name in logical:
            ax = self.rules.get(name, None) if isinstance(name, str) else name
            if ax is None:
                out.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            axes = tuple(
                a for a in axes
                if a not in used and (avail is None or a in avail)
            )
            used.update(axes)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        while out and out[-1] is None:
            out.pop()
        return PartitionSpec(*out)

    def resolve_axes(
        self, name: str, mesh: Optional[Mesh] = None
    ) -> Tuple[str, ...]:
        """Flat mesh axes ONE logical axis maps to on ``mesh`` (() = replicated).

        The tuple form of a single-dim ``resolve`` — what collective code
        (``core/distributed.py``) needs: the axis names to all-gather over and
        to feed ``linear_index``."""
        return spec_axes(self.resolve((name,), mesh), 0)

    def with_overrides(self, **kw: Axis) -> "MeshRules":
        d = dict(self.rules)
        d.update(kw)
        return MeshRules(rules=d)


def spec_axes(spec: PartitionSpec, dim: int) -> Tuple[str, ...]:
    """Flat mesh axes assigned to one dim of a PartitionSpec.

    Returns () for a replicated dim — including dims past the spec's trimmed
    trailing Nones, so callers may ask about any tensor dim safely."""
    entries = tuple(spec)
    if dim >= len(entries) or entries[dim] is None:
        return ()
    e = entries[dim]
    return (e,) if isinstance(e, str) else tuple(e)


def logical_sharding(
    mesh: Mesh, rules: MeshRules, logical: Sequence[Axis]
) -> NamedSharding:
    return NamedSharding(mesh, rules.resolve(logical, mesh))


def constrain(x, mesh: Mesh, rules: MeshRules, logical: Sequence[Axis]):
    """with_sharding_constraint by logical axes (no-op outside jit)."""
    return jax.lax.with_sharding_constraint(x, logical_sharding(mesh, rules, logical))


def divisible(n: int, mesh: Mesh, axis: Axis) -> bool:
    """Is dimension n divisible by the product of the given mesh axes?"""
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    size = 1
    for a in axes:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return n % size == 0


def adapt_rules_for(cfg, mesh: Mesh, rules: MeshRules) -> MeshRules:
    """Drop shardings that do not divide this model's dimensions (GQA kv heads,
    expert counts, vocab remainders) — replication is the exact fallback.

    Head counts are checked AFTER zero-padding (HeadPlan): query heads pad to
    the TP multiple, so 'heads' stays sharded for e.g. 14→16 or 40→48."""
    from ..models.layers import HeadPlan  # local import to avoid cycles

    overrides: Dict[str, Axis] = {}
    tp = mesh.shape.get("model", 1)
    plan = HeadPlan.plan(cfg.n_heads, cfg.n_kv_heads, tp)
    if not divisible(plan.pad_kv, mesh, rules.rules.get("kv_heads")):
        overrides["kv_heads"] = None
    if not divisible(plan.pad_q, mesh, rules.rules.get("heads")):
        overrides["heads"] = None
    if cfg.moe is not None:
        if not divisible(cfg.moe.n_experts, mesh, rules.rules.get("experts")):
            # expert dim replicated; shard each expert's hidden dim instead
            overrides["experts"] = None
        else:
            # expert-parallel: the expert hidden dim must then stay unsharded
            overrides["expert_mlp"] = None
    if not divisible(cfg.vocab_size, mesh, rules.rules.get("vocab")):
        overrides["vocab"] = None
    # the 'mlp' rule shards FFN hidden dims AND the SSM projection dims; it
    # must survive for attention-free archs (d_ff == 0) — test what it shards.
    mlp_dims = [cfg.d_ff] if cfg.d_ff else []
    if cfg.ssm is not None:
        from ..models.mamba import ssm_dims

        dims = ssm_dims(cfg.d_model, cfg.ssm)
        in_dim = 2 * dims["d_inner"] + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + dims["n_heads"]
        mlp_dims += [dims["d_inner"], dims["conv_dim"], in_dim]
    if any(not divisible(d, mesh, rules.rules.get("mlp")) for d in mlp_dims):
        overrides["mlp"] = None
    return rules.with_overrides(**overrides) if overrides else rules
