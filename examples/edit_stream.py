"""Mid-text edits via the facade: splice a live stream in O(log n).

    PYTHONPATH=src python examples/edit_stream.py [--backend jnp|pallas|packed]

Demonstrates the editing surface of ``repro.Parser`` streams:

  1. ``ParserStream.edit(lo, hi, replacement)`` — replace ``text[lo:hi]``
     in-place; the product segment tree re-reaches only the spliced leaves
     and re-composes one leaf-to-root path, so the cost is O(cap + log n)
     instead of a full re-parse, and every post-edit state is bit-identical
     to a cold parse of the edited text;
  2. ``delete`` / ``insert`` sugar — zero-width and pure-delete splices;
  3. an editor session — repeated random splices against a cold-parse
     referee, with the ``stream_edits_total`` counter and recompose-depth
     histogram from the metrics snapshot as the wrap-up.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

import repro


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=repro.list_backends())
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run (default sizes already are)")
    args = ap.parse_args()

    pattern = "(a|b|ab)+"
    parser = repro.Parser(repro.ParserConfig(
        regex=pattern, backend=args.backend, first_seal_len=4, max_seal_len=16,
        obs={"enabled": True},
    ))
    cold = repro.Parser(repro.ParserConfig(regex=pattern, backend=args.backend))

    def check(stream, text, what):
        res = stream.result()
        ref = cold.parse(text)
        same = np.array_equal(res.forest.pack(), ref.forest.pack())
        print(f"  {what:24s} n={res.forest.n:3d}  ok={res.ok!s:5} "
              f"trees={res.count_trees():4d}  bit-identical={same}")
        assert same

    # 1. one stream, spliced every which way --------------------------------
    print(f"RE {pattern!r}, backend={args.backend}: mid-text edits")
    text = "ab" * 12
    with parser.open_stream() as stream:
        stream.append(text)
        check(stream, text, f"append {len(text)} chars")

        text = text[:6] + "ba" + text[10:]          # replace, shrinking
        stream.edit(6, 10, "ba")
        check(stream, text, "edit [6:10) -> 'ba'")

        text = text[:0] + text[2:]                  # pure delete at the front
        stream.delete(0, 2)
        check(stream, text, "delete [0:2)")

        text = text[:8] + "abab" + text[8:]         # zero-width insert
        stream.insert(8, "abab")
        check(stream, text, "insert 'abab' @8")

    # 2. an editor session: random splices vs the cold referee --------------
    rng = np.random.Generator(np.random.Philox(7))
    text = "ab" * 20
    with parser.open_stream() as stream:
        stream.append(text)
        n_edits = 4 if args.smoke else 10
        for _ in range(n_edits):
            lo = int(rng.integers(0, len(text)))
            hi = int(rng.integers(lo, min(len(text), lo + 6) + 1))
            repl = "".join(rng.choice(list("ab"), rng.integers(0, 5)))
            text = text[:lo] + repl + text[hi:]
            stream.edit(lo, hi, repl)
            check(stream, text, f"splice [{lo}:{hi}) -> {repl!r}")

    snap = parser.stats()["metrics"]
    edits = snap["stream_edits_total"][0]["value"]
    depth = snap["stream_edit_recompose_depth"][0]["value"]
    print(f"{int(edits)} splices, recompose-depth histogram: "
          f"count={depth['count']} sum={depth['sum']:.0f} "
          f"(mean {depth['sum'] / max(depth['count'], 1):.1f} "
          f"internal products per edit)")
    parser.close()
    cold.close()


if __name__ == "__main__":
    main()
