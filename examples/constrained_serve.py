"""Structured-output serving: RE-constrained decoding with batched requests.

    PYTHONPATH=src python examples/constrained_serve.py
    PYTHONPATH=src python examples/constrained_serve.py --pattern '(GET|POST) /[a-z]+'

The paper's parser automaton as a serving feature: the DFA built for parsing
is lifted to the token vocabulary and masks the logits each step, so every
generated sequence is guaranteed to lie in L(e) — even from an untrained
model (which is the demo here: random weights, valid outputs).
"""

import argparse
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import jax
import numpy as np

import repro
from repro.configs import get_smoke
from repro.models.model import init_params
from repro.serve.engine import ServeEngine, TokenDFA, byte_vocab


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="(ab|a)*c")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="tiny CI run")
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.max_new = 2, 6

    cfg = get_smoke("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name} (random weights) — constraint: {args.pattern!r}")

    parser = repro.Parser(args.pattern)   # the public parser facade owns
    # generation; its matrices feed the token-DFA logit mask
    tdfa = TokenDFA.from_matrices(parser.matrices, byte_vocab(cfg.vocab_size))
    print(f"token DFA: {tdfa.delta.shape[0]} states over vocab {tdfa.delta.shape[1]}")

    engine = ServeEngine(cfg, params, max_seq=args.max_new + 8,
                         batch=args.batch, eos_id=0)
    prompts = np.zeros((args.batch, 1), np.int32)  # BOS-ish dummy prompt
    res = engine.generate(prompts, max_new=args.max_new, temperature=1.0,
                          seed=args.seed, constraint=tdfa)
    ok = 0
    for row in res.tokens:
        s = ""
        for c in row:
            if c == 0:
                break
            s += chr(int(c)) if 32 <= int(c) < 127 else "?"
        match = re.fullmatch(args.pattern, s) is not None
        ok += match
        print(f"  {s!r:24s} fullmatch={match}")
    print(f"{ok}/{args.batch} outputs in L(e) — guaranteed by construction")


if __name__ == "__main__":
    main()
