"""Quickstart: the public API — one Parser, one config, one result type.

    PYTHONPATH=src python examples/quickstart.py [--smoke]

Walks the paper's complete pipeline on the running example e3 = (a|b|ab)+
through the SUPPORTED surface (``repro.Parser`` / ``repro.ParserConfig`` —
see ROADMAP.md "Public API"): parser generation (segments → NFA → DFA/ME-DFA
→ matrices), chunked parallel parsing, and SLPF inspection via
``ParseResult`` (ok / count / enumerate / render / group matches).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

import repro


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI run (default sizes already are)")
    ap.parse_args()

    pattern = "(a|b|ab)+"
    text = "abab"

    print(f"RE e = {pattern!r}")
    parser = repro.Parser(repro.ParserConfig(regex=pattern, n_chunks=2))
    art = parser.artifacts                 # NFA/DFA/ME-DFA introspection
    t = parser.table
    print(f"parser generated: {t.n} segments, "
          f"DFA {art.dfa.n_states} states, ME-DFA {art.medfa.n_states} states "
          f"({len(art.medfa.initial)} entries — one per segment)")
    print("segments:")
    for i in range(t.n):
        flags = ("I" if t.initial[i] else " ") + ("F" if t.final[i] else " ")
        print(f"  {i + 1:3d} {flags}  {t.display(i)}")

    result = parser.parse(text)
    print(f"\nparse {text!r}: ok={result.ok}, "
          f"{result.count_trees()} syntax trees (paper Fig. 9: 4), "
          f"backend={result.backend}, bucket={result.bucket}")
    for tree in result.trees():
        print("  LST:", tree)
    print(f"group spans: " + ", ".join(
        f"g{g}={result.matches(g)}" for g in parser.groups))

    print("\nclean SLPF columns (segment ids, 1-based):")
    for r, col in enumerate(result.forest.columns):
        print(f"  C_{r}: {sorted((np.flatnonzero(col) + 1).tolist())}")

    # the same config as a plain dict — declarative, file-able, exact
    print(f"\nconfig round-trip: "
          f"{repro.ParserConfig.from_dict(parser.config.to_dict()) == parser.config}")


if __name__ == "__main__":
    main()
