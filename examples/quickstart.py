"""Quickstart: generate a parallel parser from an RE and parse a text.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's complete pipeline on the running example e3 = (a|b|ab)+:
parser generation (segments → NFA → DFA/ME-DFA → matrices), chunked parallel
parsing on the JAX engine, and SLPF inspection (count / enumerate / render).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts


def main() -> None:
    pattern = "(a|b|ab)+"
    text = "abab"

    print(f"RE e = {pattern!r}")
    art = ParallelArtifacts.generate(pattern)
    t = art.table
    print(f"parser generated: {t.n} segments, "
          f"DFA {art.dfa.n_states} states, ME-DFA {art.medfa.n_states} states "
          f"({len(art.medfa.initial)} entries — one per segment)")
    print("segments:")
    for i in range(t.n):
        flags = ("I" if t.initial[i] else " ") + ("F" if t.final[i] else " ")
        print(f"  {i + 1:3d} {flags}  {t.display(i)}")

    engine = ParserEngine(art.matrices)
    slpf = engine.parse(text, n_chunks=2)
    print(f"\nparse {text!r}: accepted={slpf.accepted}, "
          f"{slpf.count_trees()} syntax trees (paper Fig. 9: 4)")
    for path in slpf.iter_trees():
        print("  LST:", slpf.lst_string(path))

    print("\nclean SLPF columns (segment ids, 1-based):")
    for r, col in enumerate(slpf.columns):
        print(f"  C_{r}: {sorted((np.flatnonzero(col) + 1).tolist())}")


if __name__ == "__main__":
    main()
