"""Streaming incremental parse via the facade: append, re-pay only the tail.

    PYTHONPATH=src python examples/stream_parse.py [--backend jnp|pallas|packed]

Demonstrates the streaming surface of ``repro.Parser``:

  1. ``open_stream``   — each stream owns a persistent chunk-product prefix
     cache; ``append`` re-runs only the appended piece's reach + an O(log n)
     join, and every state is bit-identical to a cold parse of the prefix;
  2. deadline-aware appends — the same typed admission as ``submit``;
  3. many sessions     — concurrent streams batch their tail pieces into one
     device reach over ONE engine, under a bytes-budget eviction policy
     (``ParserConfig.cache_budget_bytes``).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

import repro


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=repro.list_backends())
    ap.add_argument("--smoke", action="store_true", help="tiny CI run (default sizes already are)")
    args = ap.parse_args()

    pattern = "(a|b|ab)+"
    parser = repro.Parser(repro.ParserConfig(
        regex=pattern, backend=args.backend, first_seal_len=4,
        cache_budget_bytes=256 * 1024,
    ))
    cold = repro.Parser(repro.ParserConfig(regex=pattern, backend=args.backend))

    # 1. one live stream, incremental states vs cold re-parse ---------------
    print(f"RE {pattern!r}, backend={args.backend}: streaming appends")
    with parser.open_stream() as stream:
        prefix = ""
        for piece in ["ab", "ab", "x", "", "abab"]:
            stream.append(piece, deadline_s=30.0)
            prefix += piece
            res = stream.result()
            ref = cold.parse(prefix)
            print(f"  +{piece!r:8} n={res.forest.n:3d}  ok={res.ok!s:5} "
                  f"trees={res.count_trees():4d}  "
                  f"bit-identical={np.array_equal(res.forest.pack(), ref.forest.pack())}")

    # 2. many sessions, one engine ------------------------------------------
    streams = [parser.open_stream() for _ in range(4)]
    feeds = ["ab" * 8, "abab" * 5, "b" + "ab" * 6, "ba" * 4]
    for rnd in range(4):                # interleaved round-robin appends
        for stream, feed in zip(streams, feeds):
            q = len(feed) // 4
            stream.append(feed[rnd * q : (rnd + 1) * q])
    parser.stream_service.drain()       # batched absorption across sessions
    for stream, feed in zip(streams, feeds):
        res = stream.result()
        print(f"  session {stream.sid}: n={res.forest.n:3d} trees={res.count_trees()}")
        stream.close()
    st = parser.stats()["stream"]
    print(f"{st['batches_run']} reach batches for "
          f"{sum(v['served'] for v in st['buckets'].values())} appends, "
          f"{st['bytes_cached']} bytes cached, {st['evictions']} evictions, "
          f"{st['compile_count']} compiled programs")


if __name__ == "__main__":
    main()
