"""Streaming incremental parse: append text, re-pay only the tail + join.

    PYTHONPATH=src python examples/stream_parse.py [--backend jnp|pallas]

Demonstrates the streaming subsystem layered on the phase-split runtime:

  1. prefix cache      — ``StreamingParser`` seals geometric chunks with
     their reach products P_i; ``append`` re-runs only the appended piece's
     reach + an O(log n) join over the cached summaries, and every state is
     bit-identical to a cold ``ParserEngine.parse`` of the full prefix;
  2. snapshot/restore  — O(1) capture of the whole stream (speculative
     parses, editor undo);
  3. session serving   — ``StreamService`` runs many concurrent streams over
     ONE engine, batching same-bucket tail pieces into one device reach and
     evicting cold sessions' caches under a bytes budget.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.stream import StreamingParser
from repro.serve.stream_service import StreamService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    args = ap.parse_args()

    pattern = "(a|b|ab)+"
    art = ParallelArtifacts.generate(pattern)
    engine = ParserEngine(art.matrices, backend=args.backend)

    # 1. one live stream, incremental states vs cold re-parse ---------------
    sp = StreamingParser(engine, first_seal_len=4)
    prefix = ""
    print(f"RE {pattern!r}, backend={args.backend}: streaming appends")
    for piece in ["ab", "ab", "x", "", "abab"]:
        sp.append(piece)
        prefix += piece
        slpf = sp.current_slpf()
        cold = engine.parse(prefix)
        print(f"  +{piece!r:8} n={sp.n:3d}  accepted={sp.accepted!s:5} "
              f"trees={slpf.count_trees():4d}  sealed={sp.n_sealed_chunks}  "
              f"bit-identical={np.array_equal(slpf.pack(), cold.pack())}")

    # 2. snapshot / restore --------------------------------------------------
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("abab")
    snap = sp.snapshot()
    sp.append("x")                      # speculative append kills the forest
    dead = sp.accepted
    sp.restore(snap)
    sp.append("ab")                     # …rewound and continued
    print(f"snapshot/restore: speculative 'x' accepted={dead}, "
          f"restored+'ab' accepted={sp.accepted} trees={sp.count_trees()}")

    # 3. many sessions, one engine ------------------------------------------
    svc = StreamService(engine, max_batch=8, first_seal_len=4,
                        cache_budget_bytes=256 * 1024)
    sids = [svc.open() for _ in range(4)]
    feeds = ["ab" * 8, "abab" * 5, "b" + "ab" * 6, "ba" * 4]
    for rnd in range(4):                # interleaved round-robin appends
        for sid, feed in zip(sids, feeds):
            q = len(feed) // 4
            svc.append(sid, feed[rnd * q : (rnd + 1) * q])
    svc.drain()                         # batched absorption across sessions
    for sid, feed in zip(sids, feeds):
        slpf = svc.slpf(sid)
        print(f"  session {sid}: n={slpf.n:3d} trees={slpf.count_trees()}")
    st = svc.stats
    print(f"{st['batches_run']} reach batches for "
          f"{sum(v['served'] for v in st['buckets'].values())} appends, "
          f"{st['bytes_cached']} bytes cached, {st['evictions']} evictions, "
          f"{st['compile_count']} compiled programs")


if __name__ == "__main__":
    main()
