"""regrep — the paper's proof-of-concept query utility (Sect. 1).

    PYTHONPATH=src python examples/regrep.py -e '<pattern>' [-e ...] <file>
    PYTHONPATH=src python examples/regrep.py --demo

Parses the WHOLE file against each RE and extracts group matches from the
``ParseResult`` — no false positives from free-text regions, unlike a grep
for the delimiter (the paper's e-mail example).

Multiple ``-e`` patterns run as tenants of ONE ``repro.ParserFleet``:
patterns whose padded automata share a (backend, ℓp) bucket are served by a
single tenant-batched device program, so querying a file with a stack of REs
costs one compile per bucket — not one per pattern.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import repro


DEMO_TEXT = b"F:ab;T:a,ba,C:ab;,b.F:b;T:ab,C:."
DEMO_PATTERNS = [
    # the paper's e-mail example: the full multi-record query
    r"(F:(a|b)+;T:((a|b)+,)+C:(a|b|;|,)*\.)+",
    # a single-record query: does NOT match the two-record demo text
    r"F:(a|b)+;T:((a|b)+,)+C:(a|b|;|,)*\.",
    # an unambiguous catch-all over the demo alphabet: always matches
    r"(F|T|C|a|b|;|,|:|\.)*",
]


def regrep(
    patterns: list[str], data: bytes, group: int | None, n_chunks: int = 8
) -> int:
    with repro.ParserFleet(
        {
            f"p{i}": repro.ParserConfig(regex=pat, n_chunks=n_chunks)
            for i, pat in enumerate(patterns)
        }
    ) as fleet:
        results = fleet.parse_batch(
            [(f"p{i}", data) for i in range(len(patterns))]
        )
        st = fleet.stats()["fleet"]
        print(
            f"# fleet: {st['n_tenants']} pattern(s) -> "
            f"{st['n_buckets']} automaton bucket(s), "
            f"{fleet.compile_count} compiled program(s)"
        )
        any_ok = False
        for i, (pat, result) in enumerate(zip(patterns, results)):
            if not result.ok:
                print(f"[p{i}] {pat!r}: text does not match")
                continue
            any_ok = True
            groups = fleet.groups_of(f"p{i}")
            targets = [group] if group is not None else groups
            print(
                f"[p{i}] {pat!r}: {result.count_trees()} parse tree(s); "
                f"groups: {groups}"
            )
            for g in targets:
                for a, b in result.matches(g):
                    print(
                        f"  group {g} [{a}:{b}] "
                        f"{data[a:b].decode(errors='replace')!r}"
                    )
    return 0 if any_ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("pattern", nargs="?",
                    help="single query RE (or use -e, repeatable)")
    ap.add_argument("file", nargs="?")
    ap.add_argument("-e", "--regexp", action="append", default=[],
                    help="add a query pattern (fleet tenant); repeatable")
    ap.add_argument("--group", type=int, default=None)
    ap.add_argument("--chunks", type=int, default=8)
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run (implies --demo)")
    args = ap.parse_args()
    patterns = list(args.regexp)
    if args.pattern is not None:
        # with -e present the positional slot is actually the file
        if patterns and args.file is None:
            args.file = args.pattern
        else:
            patterns.insert(0, args.pattern)
    if args.demo or args.smoke or not patterns:
        print(f"demo: text = {DEMO_TEXT!r}")
        sys.exit(regrep(DEMO_PATTERNS, DEMO_TEXT, None, args.chunks))
    data = Path(args.file).read_bytes()
    sys.exit(regrep(patterns, data, args.group, args.chunks))


if __name__ == "__main__":
    main()
