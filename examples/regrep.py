"""regrep — the paper's proof-of-concept query utility (Sect. 1).

    PYTHONPATH=src python examples/regrep.py '<pattern>' <file> [--group N]
    PYTHONPATH=src python examples/regrep.py --demo

Parses the WHOLE file against the RE with the public ``repro.Parser`` API
and extracts group matches from the ``ParseResult`` — no false positives
from free-text regions, unlike a grep for the delimiter (the paper's e-mail
example).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import repro


DEMO_RE = r"(F:(a|b)+;T:((a|b)+,)+C:(a|b|;|,)*\.)+"
DEMO_TEXT = b"F:ab;T:a,ba,C:ab;,b.F:b;T:ab,C:."


def regrep(pattern: str, data: bytes, group: int | None, n_chunks: int = 8) -> int:
    parser = repro.Parser(repro.ParserConfig(regex=pattern, n_chunks=n_chunks))
    result = parser.parse(data)
    if not result.ok:
        print("text does not match the RE", file=sys.stderr)
        return 1
    groups = parser.groups
    targets = [group] if group is not None else groups
    print(f"# {result.count_trees()} parse tree(s); groups: {groups}")
    for g in targets:
        for a, b in result.matches(g):
            print(f"group {g} [{a}:{b}] {data[a:b].decode(errors='replace')!r}")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("pattern", nargs="?")
    ap.add_argument("file", nargs="?")
    ap.add_argument("--group", type=int, default=None)
    ap.add_argument("--chunks", type=int, default=8)
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run (implies --demo)")
    args = ap.parse_args()
    if args.demo or args.smoke or args.pattern is None:
        print(f"demo: pattern={DEMO_RE!r}")
        print(f"      text   ={DEMO_TEXT!r}")
        sys.exit(regrep(DEMO_RE, DEMO_TEXT, None, args.chunks))
    data = Path(args.file).read_bytes()
    sys.exit(regrep(args.pattern, data, args.group, args.chunks))


if __name__ == "__main__":
    main()
