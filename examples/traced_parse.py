"""Traced parsing: where does a parse spend its time?

    PYTHONPATH=src python examples/traced_parse.py [--smoke]

The paper's cost model attributes parallel parse time to phases — chunk
reach, the associative join, build&merge — and the serving stack adds two
more buckets: queue wait and batched device compute.  This example turns on
the observability layer (ROADMAP "Observability") and shows all of it
through the supported surface only:

  * ``ParserConfig(obs=ObsConfig(enabled=True, span_log=...))`` — tracing
    on, spans mirrored to a JSONL file;
  * a direct ``parse`` (phase-split spans: reach / join / build&merge /
    host build) and a ``submit`` → ticket round trip (queue-wait +
    batch-compute spans), both carrying a ``trace_id`` on the result;
  * the span tree, validated and pretty-printed from the JSONL log;
  * ``Parser.stats()`` as a metrics view: cataloged counters/gauges, the
    per-bucket queue/compute p50/p99 split, and the static HLO cost of
    each compiled phase program;
  * the Prometheus rendering of the same registry.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import repro
from repro.obs import prometheus_text, read_spans_jsonl, validate_span_tree


def print_tree(spans, trace_id):
    tree = validate_span_tree(spans, trace_id)
    root = tree["root"]
    print(f"  trace {trace_id}  root={root['name']}  "
          f"{root['duration_s'] * 1e3:8.2f} ms  attrs={root['attrs']}")
    for c in sorted(tree["children"], key=lambda s: s["t_start_s"]):
        print(f"    └─ {c['name']:<24s} {c['duration_s'] * 1e3:8.2f} ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run (default sizes already are)")
    ap.parse_args()

    span_log = Path("spans.jsonl")
    span_log.unlink(missing_ok=True)

    cfg = repro.ParserConfig(
        regex="(a|b|ab)+",
        n_chunks=4,
        obs=repro.ObsConfig(enabled=True, span_log=str(span_log)),
    )
    with repro.Parser(cfg) as parser:
        # direct route: phase-split spans around each jitted program
        direct = parser.parse("abab" * 64)
        print(f"parse ok={direct.ok} backend={direct.backend} "
              f"bucket={direct.bucket} trace_id={direct.trace_id}")

        # service route: queue-wait vs batch-compute attribution
        tickets = [parser.submit("ab" * n) for n in (8, 16, 24)]
        served = [t.result() for t in tickets]
        print(f"served {len(served)} tickets "
              f"(trace_ids {[r.trace_id for r in served]})")

        spans = read_spans_jsonl(span_log)
        print(f"\nspan log: {len(spans)} spans in {span_log}")
        print("\ndirect route (phase attribution):")
        print_tree(spans, direct.trace_id)
        print("\nticket route (queue vs compute):")
        print_tree(spans, served[0].trace_id)

        stats = parser.stats()
        print("\nper-bucket latency split (queue wait vs device compute):")
        for bucket, d in stats["parse"]["buckets"].items():
            print(f"  bucket {bucket}: served={d['served']} "
                  f"p99_queue={d['p99_queue_s'] * 1e3:.2f} ms "
                  f"p99_compute={d['p99_compute_s'] * 1e3:.2f} ms")

        print("\nstatic HLO cost per compiled bucket (flops / bytes):")
        for bucket, phases in (stats["hlo"] or {}).items():
            t = phases["total"]
            print(f"  bucket {bucket}: {t['flops']:.3g} flops, "
                  f"{t['bytes']:.3g} bytes "
                  f"(reach {phases['reach']['flops']:.3g}, "
                  f"join {phases['join']['flops']:.3g}, "
                  f"build&merge {phases['build_merge']['flops']:.3g})")

        print("\nprometheus exposition (first 12 lines):")
        for line in prometheus_text(stats["metrics"]).splitlines()[:12]:
            print(f"  {line}")

    span_log.unlink(missing_ok=True)   # keep example runs tidy


if __name__ == "__main__":
    main()
