"""End-to-end training driver: train a causal LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py                 # tiny, CPU-sized
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-2.7b --smoke

Demonstrates the full substrate: seekable data pipeline → sharded train step
(grad accumulation, bf16 grads, fp32 masters) → atomic checkpointing →
crash-resume (kill it mid-run and re-invoke: the trajectory continues
bit-exactly).  ``--preset 100m`` is the deliverable-scale configuration for a
real accelerator host; the default runs in seconds on CPU.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

from repro.configs import ARCH_IDS, get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig, ShapeSpec
from repro.optim.adamw import AdamWConfig
from repro.train.loop import Trainer, TrainerConfig


def preset_100m() -> ModelConfig:
    """~100M-parameter llama-family config (deliverable (b) scale)."""
    return ModelConfig(
        name="llama-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", help=f"{ARCH_IDS}")
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: smoke preset, 3 steps")
    args = ap.parse_args()
    if args.smoke:
        args.preset, args.steps = "smoke", min(args.steps, 3)
        args.seq, args.batch = min(args.seq, 32), min(args.batch, 2)

    cfg = preset_100m() if args.preset == "100m" else get_smoke(args.arch)
    mesh = make_host_mesh()
    shape = ShapeSpec("train", seq_len=args.seq, global_batch=args.batch, kind="train")
    trainer = Trainer(
        cfg, shape, mesh, args.workdir,
        TrainerConfig(total_steps=args.steps, checkpoint_every=max(args.steps // 5, 1),
                      log_every=max(args.steps // 10, 1)),
        opt=AdamWConfig(lr_peak=3e-3, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
    )
    print(f"training {cfg.name} ({cfg.n_params/1e6:.1f}M params) on mesh "
          f"{dict(mesh.shape)} for {args.steps} steps "
          f"(resumes from {args.workdir} if checkpoints exist)")
    result = trainer.run()
    for h in result["history"][:: max(len(result["history"]) // 10, 1)]:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f} lr {h['lr']:.2e} {h['dt']*1e3:.0f}ms")
    print(f"final loss: {result['final_loss']:.4f}")


if __name__ == "__main__":
    main()
