"""Mesh-native distributed parsing via the facade: ``ParserConfig(mesh=...)``.

    PYTHONPATH=src python examples/sharded_parse.py [--smoke]

Forces 8 host devices (CPU) unless XLA_FLAGS is already set.  Distribution
is DECLARATIVE on the public API — ``mesh="host"`` selects a ('pod', 'data')
mesh over every visible device, PaREM-style chunk splitting over it:

  1. chunk-sharded parse  — ONE long text, chunk dim split over every
     'chunk' mesh axis; reach/build&merge run shard-local, one all-gather of
     the product stack feeds the replicated join;
  2. sharded-batched parse — batch slots shard over 'data' while chunks keep
     'pod', one program serves many texts across the mesh;
  3. sharded streaming     — a facade stream on the mesh engine ships its
     sealed-product stack as the all-gather payload.

Every output is bit-identical to the single-device parser.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import numpy as np

import repro


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI run (default sizes already are)")
    ap.parse_args()

    pattern = "(a|b|ab)+"
    ref = repro.Parser(repro.ParserConfig(regex=pattern))
    eng = repro.Parser(repro.ParserConfig(regex=pattern, mesh="host"))
    d = eng.engine.dist
    print(f"RE {pattern!r} on mesh {dict(eng.engine.mesh.shape)}")
    print(f"  chunk axes {d.chunk_axes} (single text) | "
          f"batch over {d.batch_axes} x chunks over {d.batch_chunk_axes}")

    # 1. one long text, chunks over the whole mesh
    long_text = "ab" * 4000
    s = eng.parse(long_text)
    print(f"single long text n={len(long_text)}: ok={s.ok} "
          f"trees(log2)~{s.count_trees().bit_length()} "
          f"bit-identical="
          f"{np.array_equal(s.forest.pack(), ref.parse(long_text).forest.pack())}")

    # 2. mixed-length batch, batch x chunk sharding
    texts = ["ab", "", "abab", "ba" * 3, "a" * 23, "ab" * 40, "x", "aabb" * 5]
    got = eng.parse_batch(texts)
    base = ref.parse_batch(texts)
    same = all(
        np.array_equal(g.forest.pack(), b.forest.pack())
        for g, b in zip(got, base)
    )
    print(f"batch of {len(texts)} mixed-length texts: "
          f"ok={[g.ok for g in got]} bit-identical={same}")

    # 3. sharded streaming: sealed products are the all-gather payload
    with eng.open_stream() as stream:
        prefix = ""
        for piece in ["ab", "abab", "ba", "ab" * 10]:
            stream.append(piece)
            prefix += piece
            res = stream.result()
            cold = ref.parse(prefix)
            print(f"  +{piece!r:12} n={res.forest.n:3d} ok={res.ok!s:5} "
                  f"bit-identical="
                  f"{np.array_equal(res.forest.pack(), cold.forest.pack())}")


if __name__ == "__main__":
    main()
