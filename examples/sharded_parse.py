"""Mesh-native distributed parsing: batch × chunk sharding on one engine.

    PYTHONPATH=src python examples/sharded_parse.py

Forces 8 host devices (CPU) unless XLA_FLAGS is already set, then
demonstrates the distribution layer (``core/distributed.py``):

  1. chunk-sharded parse  — ONE long text, chunk dim split over every
     'chunk' mesh axis ('pod' × 'data'); reach/build&merge run shard-local,
     one all-gather of the (c, ℓp, ℓp) product stack feeds the replicated
     join;
  2. sharded-batched parse — ``parse_batch`` slots shard over 'data' while
     chunks keep 'pod' (the MeshRules composition), so one program serves
     many texts across the mesh;
  3. sharded streaming     — a ``StreamingParser`` on the mesh engine ships
     its sealed-product stack as the all-gather payload.

Every output is bit-identical to the single-device engine.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import jax
import numpy as np

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.stream import StreamingParser
from repro.launch.mesh import make_parse_mesh


def main() -> None:
    pattern = "(a|b|ab)+"
    art = ParallelArtifacts.generate(pattern)
    mesh = make_parse_mesh()
    print(f"RE {pattern!r} on {len(jax.devices())} devices, "
          f"mesh {dict(mesh.shape)}")

    ref = ParserEngine(art.matrices)
    eng = ParserEngine(art.matrices, mesh=mesh)
    d = eng.dist
    print(f"  chunk axes {d.chunk_axes} (single text) | "
          f"batch over {d.batch_axes} x chunks over {d.batch_chunk_axes}")

    # 1. one long text, chunks over the whole mesh
    long_text = "ab" * 4000
    s = eng.parse(long_text)
    print(f"single long text n={len(long_text)}: accepted={s.accepted} "
          f"trees(log2)~{s.count_trees().bit_length()} "
          f"bit-identical={np.array_equal(s.pack(), ref.parse(long_text).pack())}")

    # 2. mixed-length batch, batch x chunk sharding
    texts = ["ab", "", "abab", "ba" * 3, "a" * 23, "ab" * 40, "x", "aabb" * 5]
    got = eng.parse_batch(texts)
    base = ref.parse_batch(texts)
    same = all(np.array_equal(g.pack(), b.pack()) for g, b in zip(got, base))
    print(f"batch of {len(texts)} mixed-length texts: "
          f"accepted={[g.accepted for g in got]} bit-identical={same}")

    # 3. sharded streaming: sealed products are the all-gather payload
    sp = StreamingParser(eng, first_seal_len=4)
    prefix = ""
    for piece in ["ab", "abab", "ba", "ab" * 10]:
        sp.append(piece)
        prefix += piece
        cold = ref.parse(prefix)
        print(f"  +{piece!r:12} n={sp.n:3d} accepted={sp.accepted!s:5} "
              f"sealed={sp.n_sealed_chunks} "
              f"bit-identical={np.array_equal(sp.current_slpf().pack(), cold.pack())}")


if __name__ == "__main__":
    main()
