"""Batched parsing via the facade: many mixed-length texts, few programs.

    PYTHONPATH=src python examples/batch_parse.py [--backend jnp|pallas|packed]

Demonstrates the serving stack behind ``repro.Parser``:

  1. backend switch    — ``ParserConfig(backend=...)``: the same reach / join /
     build&merge program runs on pure jnp, the Pallas Mosaic kernels
     (interpret mode off-TPU), or the bit-packed uint32 word ops —
     bit-identical outputs;
  2. shape bucketing   — mixed text lengths collapse onto a handful of static
     (c, k) chunk shapes (``compile_count`` proves it);
  3. ticketed serving  — ``submit`` returns a ``ParseTicket`` past
     deadline-aware admission; ``parse_batch`` drives the bucket-batched
     service and returns results in order.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import repro


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=repro.list_backends())
    ap.add_argument("--smoke", action="store_true", help="tiny CI run (default sizes already are)")
    args = ap.parse_args()

    pattern = "(a|b|ab)+"
    parser = repro.Parser(repro.ParserConfig(
        regex=pattern, backend=args.backend, max_batch=8, n_chunks=4,
        slo=repro.SLOTargets(p99_s=5.0),
    ))

    texts = ["ab", "", "abab", "ba" * 3, "a" * 23, "b", "ab" * 40, "aabb" * 5]
    print(f"RE {pattern!r}, backend={args.backend}: "
          f"submitting {len(texts)} texts, lengths {[len(t) for t in texts]}")
    results = parser.parse_batch(texts, deadline_s=30.0)

    for text, res in zip(texts, results):
        print(f"  len={len(text):3d}  ok={res.ok!s:5}  trees={res.count_trees()}  "
              f"bucket={res.bucket}")
    st = parser.stats()
    print(f"{st['parse']['batches_run']} device batches, "
          f"{st['compile_count']} compiled programs "
          f"(buckets, not per-length re-jits); "
          f"p99 targets met: "
          f"{all(g.get('p99_ok', True) for g in st['slo']['parse_buckets'].values())}")


if __name__ == "__main__":
    main()
