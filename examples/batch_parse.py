"""Batched parsing service: many mixed-length texts, one parser, few programs.

    PYTHONPATH=src python examples/batch_parse.py [--backend jnp|pallas]

Demonstrates the three-layer runtime added for request-level serving:

  1. backend switch    — ``ParserEngine(backend=...)``: the same reach / join /
     build&merge program runs on pure jnp or on the Pallas Mosaic kernels
     (interpret mode off-TPU), bit-identical outputs;
  2. shape bucketing   — mixed text lengths collapse onto a handful of static
     (c, k) chunk shapes, so the engine compiles a handful of programs, not
     one per length (``compile_count`` proves it);
  3. request scheduling — ``ParseService`` packs queued requests bucket-by-
     bucket into batched device programs (the LM scheduler's slot pattern).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

from repro.core.reference import ParallelArtifacts
from repro.serve.parse_service import ParseService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    args = ap.parse_args()

    pattern = "(a|b|ab)+"
    art = ParallelArtifacts.generate(pattern)
    svc = ParseService(art.matrices, backend=args.backend, max_batch=8, n_chunks=4)

    texts = ["ab", "", "abab", "ba" * 3, "a" * 23, "b", "ab" * 40, "aabb" * 5]
    print(f"RE {pattern!r}, backend={args.backend}: "
          f"submitting {len(texts)} texts, lengths {[len(t) for t in texts]}")
    rids = [svc.submit(t) for t in texts]
    done = {r.rid: r for r in svc.run()}

    for rid, text in zip(rids, texts):
        slpf = done[rid].slpf
        print(f"  len={len(text):3d}  accepted={slpf.accepted!s:5}  "
              f"trees={slpf.count_trees()}")
    print(f"{svc.batches_run} device batches, "
          f"{svc.compile_count} compiled programs "
          f"(buckets, not per-length re-jits)")


if __name__ == "__main__":
    main()
