"""Per-architecture smoke tests (deliverable (f)) + layer-level oracles.

Each assigned architecture instantiates a REDUCED same-family config and runs
one forward/train step and one decode step on CPU, asserting output shapes and
finiteness.  Deeper checks: decode ≡ full forward (teacher forcing), SSD
chunked ≡ naive recurrence, MoE routing exactness, blockwise ≡ naive attention.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models.layers import HeadPlan, blockwise_attention
from repro.models.model import (
    decode_step,
    embed_inputs,
    backbone,
    forward_train,
    init_params,
    logits_from,
    make_cache,
)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, L = 2, 16
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (b, L), 0, cfg.vocab_size)}
    if cfg.frontend is not None:
        batch["extra"] = jax.random.normal(
            key, (b, cfg.frontend.n_extra_tokens, cfg.frontend.feature_dim), jnp.bfloat16
        )
    loss, metrics = jax.jit(lambda p, bt: forward_train(p, bt, cfg))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["n_tokens"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    caches = make_cache(cfg, b, 32)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, caches2 = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))(params, caches, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(caches2["pos"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_equals_forward(arch):
    """Teacher-forced step-wise decode reproduces the full forward logits."""
    cfg = get_smoke(arch)
    if cfg.moe is not None:  # disable token dropping for exactness
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32",
                              attn_p_dtype="float32", frontend=None, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(7))
    b, L = 1, 10
    tokens = jax.random.randint(jax.random.PRNGKey(8), (b, L), 0, cfg.vocab_size)
    x, pos = embed_inputs(params, tokens, cfg)
    xx, _ = backbone(params, x, cfg, pos, 1, lambda t, a: t)
    full = np.asarray(logits_from(params, xx, cfg), np.float32)
    caches = make_cache(cfg, b, 16)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    outs = []
    for t in range(L):
        lg, caches = step(params, caches, tokens[:, t : t + 1])
        outs.append(np.asarray(lg, np.float32)[:, 0])
    err = np.abs(np.stack(outs, 1) - full).max()
    assert err < 2e-3, (arch, err)


def test_exact_configs_match_assignment():
    """The full configs carry the exact published dimensions."""
    spec = {
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
    }
    for arch, (L, d, h, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, V), arch
    assert get_config("mixtral-8x22b").moe.n_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    assert get_config("llama4-scout-17b-a16e").moe.n_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe.top_k == 1
    assert get_config("zamba2-2.7b").ssm.d_state == 64
    assert get_config("mamba2-2.7b").ssm.d_state == 128
    assert get_config("mamba2-2.7b").is_attention_free


def test_ssd_chunked_vs_naive_recurrence():
    """Mamba-2 SSD chunked algorithm == the per-step recurrence."""
    from repro.models.mamba import ssd_chunked

    rng = np.random.RandomState(0)
    b, l, nh, hp, g, n = 2, 16, 4, 8, 1, 5
    xdt = jnp.asarray(rng.randn(b, l, nh, hp).astype(np.float32)) * 0.3
    dA = -jnp.asarray(rng.uniform(0.01, 0.5, (b, l, nh)).astype(np.float32))
    B = jnp.asarray(rng.randn(b, l, g, n).astype(np.float32)) * 0.3
    C = jnp.asarray(rng.randn(b, l, g, n).astype(np.float32)) * 0.3
    y, state = ssd_chunked(xdt, dA, B, C, chunk=4)
    # naive recurrence oracle
    s = np.zeros((b, nh, hp, n), np.float32)
    ys = []
    a = np.exp(np.asarray(dA))
    Bh = np.repeat(np.asarray(B), nh // g, axis=2)
    Ch = np.repeat(np.asarray(C), nh // g, axis=2)
    xe = np.asarray(xdt)
    for t in range(l):
        s = a[:, t][..., None, None] * s + np.einsum("bhp,bhn->bhpn", xe[:, t], Bh[:, t])
        ys.append(np.einsum("bhpn,bhn->bhp", s, Ch[:, t]))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), s, rtol=2e-4, atol=2e-4)


def test_moe_routing_no_drop_exact():
    """With ample capacity, MoE == dense mixture computed naively."""
    from repro.models.config import MoEConfig
    from repro.models.moe import declare_moe, moe_ffn
    from repro.models.layers import tree_init

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    decls = declare_moe(8, cfg)
    params = tree_init(decls, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 8), jnp.float32)
    y, aux = moe_ffn(params, x, cfg)
    # naive: full softmax top-2 mixture
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(x))
    for t in range(12):
        for j in range(2):
            e = int(idx[t, j])
            h = jax.nn.silu(x[t] @ params["w_gate"][e]) * (x[t] @ params["w_up"][e])
            ref[t] += float(gates[t, j]) * np.asarray(h @ params["w_down"][e])
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)
    assert float(aux["moe_lb_loss"]) >= 0.0


def test_blockwise_attention_equals_naive():
    rng = np.random.RandomState(2)
    b, L, h, hd = 2, 48, 3, 16
    q = jnp.asarray(rng.randn(b, L, h, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(b, L, h, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(b, L, h, hd).astype(np.float32))
    for window in (None, 5):
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        i, j = np.arange(L)[:, None], np.arange(L)[None, :]
        m = j <= i
        if window:
            m &= j > i - window
        s = jnp.where(jnp.asarray(m)[None, None], s, -jnp.inf)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
        got = blockwise_attention(q, k, v, causal=True, window=window,
                                  q_block=16, k_block=16, p_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)
        # bf16 probability buffers (§Perf H3): bounded, small degradation
        got16 = blockwise_attention(q, k, v, causal=True, window=window,
                                    q_block=16, k_block=16, p_dtype=jnp.bfloat16)
        np.testing.assert_allclose(np.asarray(got16), np.asarray(ref), atol=2e-2)


def test_blockwise_attention_grouped_gqa():
    """Grouped GQA (no KV repetition) == repeat-then-attend reference."""
    rng = np.random.RandomState(5)
    b, L, kv, g, hd = 2, 32, 2, 3, 8
    h = kv * g
    q = jnp.asarray(rng.randn(b, L, h, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(b, L, kv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(b, L, kv, hd).astype(np.float32))
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    # note repeat order: head i uses kv i // g in the grouped form, but
    # jnp.repeat gives kv i // g as well (repeat along axis) — consistent.
    ref = blockwise_attention(q, kr, vr, groups=1, causal=True,
                              q_block=8, k_block=8, p_dtype=jnp.float32)
    got = blockwise_attention(q, k, v, groups=g, causal=True,
                              q_block=8, k_block=8, p_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


def test_head_plan_padding():
    plan = HeadPlan.plan(40, 10, 16)     # phi3: 48 q / 12 kv, exact grouping
    assert (plan.pad_q, plan.pad_kv, plan.groups, plan.grouped) == (48, 12, 4, True)
    plan = HeadPlan.plan(14, 2, 16)      # internvl2: 16 q / 3 kv, repeat decode
    assert (plan.pad_q, plan.pad_kv, plan.grouped) == (16, 3, False)
    plan = HeadPlan.plan(24, 24, 16)     # musicgen MHA: pad to 32, exact
    assert (plan.pad_q, plan.pad_kv, plan.grouped) == (32, 32, True)
    plan = HeadPlan.plan(40, 8, 16)      # llama4: 16 kv × 5 = 80 (2× bound)
    assert (plan.pad_q, plan.pad_kv, plan.grouped) == (80, 16, True)
    plan = HeadPlan.plan(32, 4, 16)      # divisible: no padding
    assert (plan.pad_q, plan.pad_kv, plan.grouped) == (32, 4, True)


def test_skip_shapes_recorded():
    """DESIGN §5: long_500k must be skipped for full-attention archs and run
    for SSM/hybrid/SWA archs."""
    runs_long = {"h2o-danube-3-4b", "mixtral-8x22b", "zamba2-2.7b", "mamba2-2.7b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        skipped = dict(cfg.skip_shapes)
        if arch in runs_long:
            assert "long_500k" not in skipped, arch
        else:
            assert "long_500k" in skipped, arch
