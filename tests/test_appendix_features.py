"""App. A features through the full pipeline: partial syntax modeling
(operator masking), infinite ambiguity, character classes as generalized
segments, extra parentheses."""

import numpy as np
import pytest

from repro.core.engine import ParserEngine
from repro.core.numbering import OP_ALT, OP_CAT, number_regex
from repro.core.reference import ParallelArtifacts
from repro.core.segments import compute_segments
from repro.core.serial import SerialParser, parse_serial_matrix


def test_partial_syntax_masking_reduces_states():
    """App. A: masking operators removes their paren pairs from LSTs and
    shrinks the automaton; parsing semantics (acceptance) are unchanged."""
    full = compute_segments(number_regex("(ab|a)*"))
    masked = compute_segments(number_regex("(ab|a)*", mask_ops=(OP_ALT, OP_CAT)))
    assert masked.n <= full.n
    pf = SerialParser("(ab|a)*")
    pm = SerialParser("(ab|a)*", mask_ops=(OP_ALT, OP_CAT))
    for text in ["", "a", "ab", "aab", "ba", "abab"]:
        assert pf.accepts(text) == pm.accepts(text), text
    # masked LSTs contain no alt/cat parens
    s = pm.parse("aab")
    lst = s.lst_string(next(s.iter_trees()))
    # only the star and group pairs remain numbered
    assert lst.count("(") < pf.parse("aab").lst_string(
        next(pf.parse("aab").iter_trees())
    ).count("(")


def test_infinitely_ambiguous_re_returns_finite_sample():
    """App. A: (a|ε)*-style REs return a finite representative LST sample."""
    p = SerialParser("(a*|ab)+", inf_limit=2)
    s = p.parse("a")
    assert s.accepted
    n = s.count_trees()
    assert 1 <= n < 1000  # finite despite infinite true ambiguity
    for path in s.iter_trees(limit=5):
        lst = s.lst_string(path)
        assert lst.count("(") == lst.count(")")


def test_infinite_ambiguity_parallel_equals_serial():
    art = ParallelArtifacts.generate("(a*|ab)+")
    eng = ParserEngine(art.matrices)
    for text in ["a", "ab", "aab", "abab", ""]:
        ref = parse_serial_matrix(art.matrices, text)
        got = eng.parse(text, n_chunks=3)
        assert np.array_equal(ref.columns, got.columns), text


def test_char_classes_generalized_segments():
    """Fig. A1: classes keep the automaton compact — [a-z]+ has O(1) segments
    (not 26), and overlapping classes partition correctly."""
    t = compute_segments(number_regex("[a-z]+"))
    assert t.n <= 4
    # overlapping classes [ab] and [bc]: partition {a},{b},{c}
    t2 = compute_segments(number_regex("[ab][bc]"))
    p = SerialParser("[ab][bc]")
    for text, ok in [("ab", True), ("bc", True), ("bb", True), ("ba", False),
                     ("ca", False), ("aa", False)]:
        assert p.accepts(text) == ok, text


def test_extra_parentheses_groups_extracted():
    """App. A extra parens: a(bc) ≡ abc for the language, but the group is
    numbered and extractable from the SLPF."""
    p1 = SerialParser("a(bc)")
    p2 = SerialParser("abc")
    for text in ["abc", "ab", "abcd"]:
        assert p1.accepts(text) == p2.accepts(text)
    from repro.core.numbering import OPEN, OP_GROUP

    s = p1.parse("abc")
    g = next(sym.num for sym in p1.table.numbered.symbols
             if sym.kind == OPEN and sym.op == OP_GROUP)
    assert s.get_matches(g) == [(1, 3)]


def test_wildcard_and_escapes_end_to_end():
    art = ParallelArtifacts.generate(r"a.c\.")
    eng = ParserEngine(art.matrices)
    assert eng.parse("axc.", 2).accepted
    assert eng.parse("a.c.", 2).accepted
    assert not eng.parse("axcx", 2).accepted
    assert not eng.parse("a\nc.", 2).accepted  # '.' excludes newline
