"""Data pipeline: determinism/seekability + regex-structured extraction."""

import numpy as np
import pytest

from repro.data.pipeline import CorpusLM, RegexStructured, SyntheticLM
from repro.data.regen import random_regex, sample_string


def test_synthetic_deterministic_and_seekable():
    p = SyntheticLM(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    a = p.batch_at(17)["tokens"]
    b = p.batch_at(17)["tokens"]
    c = p.batch_at(18)["tokens"]
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (4, 8) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 100


def test_corpus_windows():
    corpus = bytes(range(256)) * 4
    p = CorpusLM(corpus=corpus, seq_len=16, global_batch=3, seed=0)
    a = p.batch_at(0)["tokens"]
    assert a.shape == (3, 16)
    assert np.array_equal(a, p.batch_at(0)["tokens"])


def test_regex_structured_records_valid():
    p = RegexStructured(pattern="(ka=(a|b)+;)+", seq_len=32, global_batch=2, seed=1)
    batch = p.batch_at(0)
    assert batch["tokens"].shape == (2, 32)
    assert batch["spans"].shape[0] == 2 and batch["spans"].shape[2] == 3
    # records parse back (spans non-empty for at least one row)
    assert (batch["spans"][:, :, 0] >= 0).any()
    # seekable
    again = p.batch_at(0)
    assert np.array_equal(batch["tokens"], again["tokens"])


def test_regen_sampled_strings_are_valid():
    """sample_string always produces members of L(e)."""
    from repro.core.numbering import number_regex
    from repro.core.segments import compute_segments
    from repro.core.matrices import build_matrices
    from repro.core.serial import recognize

    rng = np.random.Generator(np.random.Philox(5))
    for _ in range(10):
        ast = random_regex(6, rng)
        m = build_matrices(compute_segments(number_regex(ast)))
        for _ in range(3):
            s = sample_string(ast, rng)
            assert recognize(m, s), (ast, s)
