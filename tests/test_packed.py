"""Packed uint32 semiring layer: round-trips, OR-AND word ops, reach kernel.

Property tests (hypothesis when installed, a fixed seed sweep always) for the
host-side packers in ``core/matrices.py`` — including the n % 32 != 0 padding
edge — and for the jnp-side packed ops the "packed" backend is built from,
each checked against the dense boolean oracles (``boolean_matmul`` /
``boolean_matvec``).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.matrices import (
    boolean_matmul,
    boolean_matvec,
    pack_bits,
    pack_bits_jnp,
    pack_transition_table,
    pack_transition_table_jnp,
    packed_identity,
    packed_matvec,
    packed_matvec_T,
    packed_matvec_T_words,
    packed_matvec_words,
    packed_semiring_matmul,
    unpack_bits,
    unpack_bits_jnp,
)

SEEDS = list(range(8))


def _rand_mats(seed, n, density=0.2):
    rng = np.random.default_rng(seed)
    A = rng.random((n, n)) < density
    B = rng.random((n, n)) < density
    v = rng.random(n) < 0.35
    return A, B, v


# ------------------------------------------------------- host-side packers


def _check_roundtrip(seed: int, n: int, axis: int) -> None:
    rng = np.random.default_rng(seed)
    shape = [3, 4, 5]
    shape[axis] = n                      # n sits on the packed axis
    mat = rng.random(tuple(shape)) < 0.3
    packed = pack_bits(mat, axis=axis)
    assert packed.dtype == np.uint32
    assert packed.shape[axis] == -(-n // 32)
    assert np.array_equal(unpack_bits(packed, n, axis=axis), mat)


@pytest.mark.parametrize("axis", [-1, 0, 1])
# 1, 31, 33, 63: every n % 32 != 0 shape class around the word boundary
@pytest.mark.parametrize("n", [1, 31, 32, 33, 63, 64, 96])
def test_pack_unpack_roundtrip_any_width(n, axis):
    for seed in SEEDS:
        _check_roundtrip(seed, n, axis)


def test_pack_unpack_roundtrip_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(0, 10_000), st.integers(1, 130), st.sampled_from([-1, 0, 1]))
    @hyp.settings(max_examples=40, deadline=None)
    def run(seed, n, axis):
        _check_roundtrip(seed, n, axis)

    run()


@pytest.mark.parametrize("n", [24, 32, 40, 64])
def test_pack_transition_table_orientation(n):
    """N_packed[c, col] is the packed target set of source col — bit row of
    column col — including the n % 32 != 0 tail-padding edge."""
    rng = np.random.default_rng(n)
    N = rng.random((3, n, n)) < 0.25
    packed = pack_transition_table(N)
    W = -(-n // 32)
    assert packed.shape == (3, n, W)
    for c in range(3):
        for col in range(n):
            assert np.array_equal(
                unpack_bits(packed[c, col], n), N[c, :, col]
            ), (c, col)


# --------------------------------------------------------- jnp-side packers


@pytest.mark.parametrize("n", [32, 64, 96])
def test_jnp_packers_match_numpy(n):
    for seed in SEEDS:
        A, _, v = _rand_mats(seed, n)
        Nf = A.astype(np.float32)[None]
        assert np.array_equal(
            np.asarray(pack_transition_table_jnp(jnp.asarray(Nf))),
            pack_transition_table(A[None]),
        )
        assert np.array_equal(
            np.asarray(pack_bits_jnp(jnp.asarray(v.astype(np.float32)))),
            pack_bits(v),
        )
        packed = pack_bits(A)
        assert np.array_equal(
            np.asarray(unpack_bits_jnp(jnp.asarray(packed), n)),
            A.astype(np.float32),
        )


def test_packed_identity_is_packed_eye():
    for n in (32, 64, 128):
        assert np.array_equal(
            np.asarray(packed_identity(n)),
            pack_transition_table(np.eye(n, dtype=bool)[None])[0],
        )


# ------------------------------------------------- packed OR-AND vs oracle


def _check_packed_ops(seed: int, n: int, density: float) -> None:
    A, B, v = _rand_mats(seed, n, density)
    Qa = jnp.asarray(pack_transition_table(A[None])[0])
    Qb = jnp.asarray(pack_transition_table(B[None])[0])
    vf = jnp.asarray(v.astype(np.float32))
    vp = jnp.asarray(pack_bits(v))
    # matmul: packed product of packed operands == packed dense product
    C = pack_transition_table(boolean_matmul(A, B)[None])[0]
    assert np.array_equal(np.asarray(packed_semiring_matmul(Qa, Qb)), C)
    # matvec (f32 entries) and its free transpose
    assert np.array_equal(
        np.asarray(packed_matvec(Qa, vf)), boolean_matvec(A, v).astype(np.float32)
    )
    assert np.array_equal(
        np.asarray(packed_matvec_T(Qa, vf)),
        boolean_matvec(A.T, v).astype(np.float32),
    )
    # word-resident matvecs (the build&merge inner loop)
    assert np.array_equal(
        np.asarray(packed_matvec_words(Qa, vp)), pack_bits(boolean_matvec(A, v))
    )
    assert np.array_equal(
        np.asarray(packed_matvec_T_words(Qa, vp)),
        pack_bits(boolean_matvec(A.T, v)),
    )
    # identity is a two-sided no-op
    eye = packed_identity(n)
    assert np.array_equal(np.asarray(packed_semiring_matmul(eye, Qa)), np.asarray(Qa))
    assert np.array_equal(np.asarray(packed_semiring_matmul(Qa, eye)), np.asarray(Qa))


@pytest.mark.parametrize("n", [32, 64, 96, 160])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.3, 1.0])
def test_packed_ops_match_boolean_oracle(n, density):
    for seed in SEEDS[:4]:
        _check_packed_ops(seed, n, density)


def test_packed_ops_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        st.integers(0, 10_000),
        st.sampled_from([32, 64, 96]),
        st.floats(0.0, 1.0),
    )
    @hyp.settings(max_examples=30, deadline=None)
    def run(seed, n, density):
        _check_packed_ops(seed, n, density)

    run()


def test_packed_matmul_batched_leading_dims():
    """associative_scan calls the combine on stacked blocks — leading batch
    dims must broadcast like matmul."""
    rng = np.random.default_rng(3)
    mats = rng.random((5, 64, 64)) < 0.2
    Q = jnp.asarray(pack_transition_table(mats))
    got = np.asarray(packed_semiring_matmul(Q[:4], Q[1:]))
    for i in range(4):
        want = pack_transition_table(boolean_matmul(mats[i], mats[i + 1])[None])[0]
        assert np.array_equal(got[i], want), i


# ------------------------------------------------------------ reach kernel


@pytest.mark.parametrize("k", [1, 3, 8])
def test_packed_reach_kernel_matches_fold(k):
    """kernels/packed_reach.py (interpret mode) == the jnp packed fold =="""
    from repro.kernels.ops import packed_reach_chunk_product

    rng = np.random.default_rng(k)
    n, A = 64, 4
    N = rng.random((A + 1, n, n)) < 0.2
    N[A] = np.eye(n, dtype=bool)
    ids = rng.integers(0, A + 1, size=k).astype(np.int32)
    Np = jnp.asarray(pack_transition_table(N))
    got = np.asarray(packed_reach_chunk_product(Np, jnp.asarray(ids)))
    # dense oracle: P = N[x_k] ⊗ … ⊗ N[x_1]
    P = np.eye(n, dtype=bool)
    for cls in ids:
        P = boolean_matmul(N[cls], P)
    assert np.array_equal(got, pack_transition_table(P[None])[0])


def test_packed_kernel_backend_bit_identical():
    """PackedBackend(kernel=True) routes reach through the Pallas kernel and
    stays bit-identical to the XLA word-op path on a real parse."""
    from repro.core.backend import PackedBackend
    from repro.core.engine import ParserEngine
    from repro.core.reference import ParallelArtifacts

    art = ParallelArtifacts.generate("(a|b|ab)+")
    ek = ParserEngine(art.matrices, backend=PackedBackend(kernel=True))
    ej = ParserEngine(art.matrices, backend="packed")
    for text in ["", "ba", "abab", "ab" * 17]:
        a = ek.parse(text, n_chunks=4)
        b = ej.parse(text, n_chunks=4)
        assert np.array_equal(a.columns, b.columns), text
