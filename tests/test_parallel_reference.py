"""Paper-faithful parallel algorithm (Sect. 3.2 / Tab. 6 / Ex. 6) vs serial."""

import numpy as np
import pytest

from repro.core.reference import (
    ParallelArtifacts,
    parse_parallel_reference,
    recognize_parallel,
    split_chunks,
)
from repro.core.serial import parse_serial_matrix
from repro.data.regen import random_regex, sample_string


def test_paper_ex6_trace():
    """Ex. 6: x=abaaba with c=3 chunks — one LST, singleton clean columns."""
    art = ParallelArtifacts.generate("(ab|a)*")
    s = parse_parallel_reference(art, "abaaba", c=3)
    assert s.accepted and s.count_trees() == 1
    assert [int(c.sum()) for c in s.columns] == [1] * 7
    lst = s.lst_string(next(s.iter_trees()))
    assert lst.count("a") == 4 and lst.count("b") == 2


def test_fig9_four_trees():
    """Fig. 9: e3, x=abab has exactly 4 LSTs in the clean SLPF."""
    art = ParallelArtifacts.generate("(a|b|ab)+")
    s = parse_parallel_reference(art, "abab", c=2)
    assert s.count_trees() == 4


@pytest.mark.parametrize("c", [1, 2, 3, 5, 8])
def test_chunk_count_invariance(c):
    art = ParallelArtifacts.generate("(a|b|ab)+")
    ref = parse_serial_matrix(art.matrices, "ababab")
    got = parse_parallel_reference(art, "ababab", c=c)
    assert np.array_equal(ref.columns, got.columns)


@pytest.mark.parametrize("fused", [False, True])
def test_fused_builder_merger_equivalence(fused):
    """Fig. 14's unified builder&merger computes the same clean SLPF."""
    art = ParallelArtifacts.generate("x(yz|y)*z?")
    import itertools

    for n in range(1, 7):
        for chars in itertools.islice(itertools.product("xyz", repeat=n), 20):
            text = "".join(chars)
            ref = parse_serial_matrix(art.matrices, text)
            got = parse_parallel_reference(art, text, c=3, fused=fused)
            assert np.array_equal(ref.columns, got.columns), text


def test_parallel_recognizer():
    art = ParallelArtifacts.generate("(ab|a)*c")
    for text in ["c", "abc", "aac", "ab", "abac", ""]:
        assert recognize_parallel(art, text, c=3) == parse_serial_matrix(
            art.matrices, text
        ).accepted


def test_split_chunks_partitions():
    classes = np.arange(17, dtype=np.int32)
    for c in (1, 2, 3, 5, 17, 30):
        chunks = split_chunks(classes, c)
        assert np.array_equal(np.concatenate(chunks), classes)
        sizes = [len(ch) for ch in chunks]
        assert max(sizes) - min(sizes) <= 1  # near-equal split


def test_property_parallel_equals_serial():
    """Random REs × random texts × random chunk counts: identical SLPFs."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.core.numbering import number_regex
    from repro.core.segments import compute_segments

    @hyp.given(st.integers(0, 5_000), st.integers(3, 8), st.integers(1, 6))
    @hyp.settings(max_examples=25, deadline=None)
    def run(seed, size, c):
        rng = np.random.Generator(np.random.Philox(seed))
        ast = random_regex(size, rng)
        art = ParallelArtifacts.generate(compute_segments(number_regex(ast)))
        for _ in range(2):
            text = sample_string(ast, rng)[:10]
            ref = parse_serial_matrix(art.matrices, text)
            got = parse_parallel_reference(art, text, c=c, fused=bool(seed % 2))
            assert np.array_equal(ref.columns, got.columns)
        # also one invalid-ish random text
        bad = bytes(rng.integers(97, 123, size=6).astype(np.uint8))
        ref = parse_serial_matrix(art.matrices, bad)
        got = parse_parallel_reference(art, bad, c=c)
        assert np.array_equal(ref.columns, got.columns)

    run()
