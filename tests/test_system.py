"""End-to-end system behaviour: the paper's full pipeline in one flow.

RE string → parser generation (segments/NFA/DFA/ME-DFA/matrices) → multi-chunk
parallel parse (JAX engine) → clean SLPF → tree enumeration → group-match
extraction (the `regrep` use-case of Sect. 1) → constrained serving reuse of
the same artifacts.
"""

import re

import numpy as np
import pytest

from repro.core.engine import ParserEngine
from repro.core.numbering import OPEN, OP_GROUP
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix


MAIL_RE = r"(F:(a|b)+;T:((a|b)+,)+C:(a|b|;|,)*\.)+"


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate(MAIL_RE)


def test_regrep_pipeline(art):
    """Find all 'recipients' (the T: list items) — no false positives from
    the free-text C: field, unlike a grep for 'T:' (paper Sect. 1)."""
    text = "F:ab;T:a,ba,C:ab;,b.F:b;T:ab,C:."
    eng = ParserEngine(art.matrices)
    slpf = eng.parse(text, n_chunks=4)
    assert slpf.accepted
    ref = parse_serial_matrix(art.matrices, text)
    assert np.array_equal(slpf.columns, ref.columns)
    gnums = [s.num for s in art.table.numbered.symbols
             if s.kind == OPEN and s.op == OP_GROUP]
    spans = set()
    for g in gnums:
        spans |= set(slpf.get_matches(g))
    texts = {text[a:b] for a, b in spans}
    assert "a," in texts or "ba," in texts  # recipient items found
    for a, b in spans:
        assert 0 <= a <= b <= len(text)


def test_whole_pipeline_ambiguous_counts():
    art2 = ParallelArtifacts.generate("(a|b|ab|ba)+")
    eng = ParserEngine(art2.matrices)
    text = "abab"
    slpf = eng.parse(text, n_chunks=2)
    ref = parse_serial_matrix(art2.matrices, text)
    assert slpf.count_trees() == ref.count_trees() > 1
    for path in slpf.iter_trees(limit=10):
        lst = slpf.lst_string(path)
        leaves = re.sub(r"\d|\(|\)", "", lst)
        assert leaves == text


def test_parser_generation_fast():
    """Paper Sect. 5.2: generation times are ms-scale for benchmark REs."""
    import time

    t0 = time.time()
    ParallelArtifacts.generate(MAIL_RE)
    assert time.time() - t0 < 5.0
