"""Parser NFA / DFA / ME-DFA constructions (paper Sect. 2.3.4, 3.1, Tab. 5)."""

import numpy as np
import pytest

from repro.core.automata import build_dfa, build_medfa, build_nfa
from repro.core.segments import compute_segments


def test_paper_tab5_dfa_counts_exact():
    """Tab. 5 DFA column reproduces EXACTLY: |DFA(e(k))| = 2^{k+1} + 1."""
    for k in range(1, 8):
        t = compute_segments(f"(a|b)*a(a|b){{{k}}}")
        nfa = build_nfa(t)
        dfa = build_dfa(nfa)
        assert dfa.n_states == 2 ** (k + 1) + 1, k


def test_medfa_entries_equal_segments():
    """The ME-DFA's defining property (Sect. 3.1): one entry per segment —
    speculation bounded by ℓ (linear), not the DFA state count (exponential)."""
    for k in range(1, 8):
        t = compute_segments(f"(a|b)*a(a|b){{{k}}}")
        nfa = build_nfa(t)
        medfa = build_medfa(nfa)
        assert len(medfa.initial) == t.n == 2 * k + 7
        # entry j is the singleton {j}
        for j in range(t.n):
            assert medfa.states[medfa.initial[j]] == frozenset({j})
        # and the ME-DFA contains every DFA state's reachable structure
        dfa = build_dfa(nfa)
        assert medfa.n_states >= dfa.n_states - 1  # T1 = I may not be a singleton


def test_dfa_equivalent_to_nfa():
    """DFA and NFA accept the same language (powerset correctness)."""
    import itertools

    t = compute_segments("(ab|a)*")
    nfa = build_nfa(t)
    dfa = build_dfa(nfa)
    b2c = t.numbered.byte_to_class
    for n in range(0, 6):
        for s in itertools.product("ab", repeat=n):
            classes = [b2c[ord(c)] for c in s]
            d = dfa.run(dfa.initial[0], classes)
            assert nfa.accepts(classes) == (d is not None and dfa.final[d])


def test_reverse_nfa_recognizes_reversal():
    import itertools

    t = compute_segments("(ab|a)*c")
    nfa = build_nfa(t)
    rnfa = nfa.reverse()
    b2c = t.numbered.byte_to_class
    for n in range(0, 5):
        for s in itertools.product("abc", repeat=n):
            classes = [b2c[ord(c)] for c in s]
            assert nfa.accepts(classes) == rnfa.accepts(classes[::-1])


def test_transition_labels_are_source_end_letters():
    """Sect. 2.3.4: arc label = char class read by the SOURCE segment."""
    t = compute_segments("(ab|a)*")
    nfa = build_nfa(t)
    for src, by_cls in enumerate(nfa.delta):
        for cls in by_cls:
            assert cls in t.seg_classes[src]
