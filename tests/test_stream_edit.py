"""Mid-text edits (core/stream.py product segment tree) vs cold parses.

Every splice — ``edit``/``delete``/``insert`` at any position, spanning seal
boundaries, on evicted nodes, across snapshot/restore — must leave the
stream bit-identical to a cold parse of the edited text (packed columns,
acceptance) on EVERY registered backend.  The tree itself must stay
balanced (logarithmic height under many edits) and the obs layer must see
each edit (``stream_edits_total``, recompose-depth histogram).
"""

import math

import numpy as np
import pytest

from repro.api import Parser, ParserConfig
from repro.core.backend import _BACKENDS
from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.stream import StreamingParser

AMBIG = "(a|b|ab)+"   # ambiguous: many LSTs per text
BACKENDS = sorted(_BACKENDS)


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate(AMBIG)


@pytest.fixture(scope="module")
def cold(art):
    return ParserEngine(art.matrices)


@pytest.fixture(scope="module", params=BACKENDS)
def engine(request, art):
    return ParserEngine(art.matrices, backend=request.param)


def _check(sp, cold, text):
    """The stream's full observable state equals a cold parse of ``text``."""
    assert sp.n == len(text)
    ref = cold.parse(text)
    assert np.array_equal(sp.current_slpf().pack(), ref.pack()), text
    assert sp.accepted == ref.accepted, text


def test_edit_spanning_seal_boundary(engine, cold):
    sp = StreamingParser(engine, first_seal_len=4, max_seal_len=8)
    text = "ab" * 20                       # leaves 4, 8, 8, …: boundary at 12
    sp.append(text)
    new = text[:10] + "baba" + text[14:]   # [10, 14) crosses the 12 boundary
    assert sp.edit(10, 14, "baba") == len(new)
    _check(sp, cold, new)


def test_pure_delete_and_edge_inserts(engine, cold):
    sp = StreamingParser(engine, first_seal_len=4, max_seal_len=8)
    sp.insert(0, "ab")                     # insert into the EMPTY stream
    text = "ab"
    _check(sp, cold, text)
    sp.insert(len(text), "ab" * 9)         # insert at n (pure append splice)
    text = text + "ab" * 9
    _check(sp, cold, text)
    sp.insert(0, "ba")                     # insert at 0
    text = "ba" + text
    _check(sp, cold, text)
    sp.delete(3, 7)                        # pure delete (empty replacement)
    text = text[:3] + text[7:]
    _check(sp, cold, text)
    sp.delete(0, len(text))                # delete EVERYTHING
    assert sp.n == 0
    sp.insert(0, "ab")                     # and the stream still works
    _check(sp, cold, "ab")


def test_edit_touching_evicted_node(engine, cold):
    sp = StreamingParser(engine, first_seal_len=4, max_seal_len=8)
    text = "ab" * 16
    sp.append(text)
    # partial eviction: drop the widest resident product, edit inside it
    key, _, _ = max(sp.sealed_cache_entries(), key=lambda e: e[1])
    assert sp.drop_sealed_product(key) > 0
    new = text[:5] + "a" + text[6:]
    sp.edit(5, 6, "a")
    _check(sp, cold, new)
    # fully cold: every product evicted, the splice still lands exactly
    sp.drop_cache()
    new2 = new[:9] + new[12:]
    sp.delete(9, 12)
    _check(sp, cold, new2)


def test_snapshot_edit_restore_roundtrip(engine, cold):
    sp = StreamingParser(engine, first_seal_len=4, max_seal_len=8)
    text = "ab" * 12
    sp.append(text)
    snap = sp.snapshot()
    sp.delete(4, 8)
    _check(sp, cold, text[:4] + text[8:])
    sp.restore(snap)                       # rollback ACROSS the edit
    _check(sp, cold, text)
    sp.edit(0, 2, "ba")                    # editing after restore stays exact
    _check(sp, cold, "ba" + text[2:])


def test_edit_range_validation(cold):
    sp = StreamingParser(cold, first_seal_len=4)
    sp.append("abab")
    with pytest.raises(ValueError, match="out of bounds"):
        sp.edit(2, 1, "a")
    with pytest.raises(ValueError, match="out of bounds"):
        sp.edit(0, 9, "a")


def test_edit_position_fuzz(art, cold):
    """Random splices at random positions, capped and uncapped configs."""
    eng = ParserEngine(art.matrices)
    rng = np.random.default_rng(7)
    for cap in (None, 16):
        sp = StreamingParser(eng, first_seal_len=4, max_seal_len=cap)
        text = "".join(rng.choice(list("ab"), 60))
        sp.append(text)
        for _ in range(12):
            lo = int(rng.integers(0, sp.n + 1))
            hi = int(rng.integers(lo, min(sp.n, lo + 7) + 1))
            repl = "".join(rng.choice(list("ab"), int(rng.integers(0, 5))))
            text = text[:lo] + repl + text[hi:]
            assert sp.edit(lo, hi, repl) == len(text)
            if text:
                _check(sp, cold, text)


def test_tree_balance_and_edit_metrics(art):
    eng = ParserEngine(art.matrices)
    sp = StreamingParser(eng, first_seal_len=4, max_seal_len=4)
    sp.append("ab" * 64)                   # 32 fixed-size leaves
    m = eng.obs.metrics
    edits0 = m.counter("stream_edits_total").value
    depth0 = m.histogram("stream_edit_recompose_depth").count
    for i in range(10):
        sp.edit(3 + 7 * i, 5 + 7 * i, "ab")
    assert sp.edits == 10
    assert m.counter("stream_edits_total").value == edits0 + 10
    assert m.histogram("stream_edit_recompose_depth").count == depth0 + 10
    # the rope stays height-balanced through the splice churn
    assert sp.tree_height <= 2 * math.log2(max(2, sp.n_sealed_chunks)) + 2


def test_facade_edit_delete_insert(art, cold):
    """The public surface: ParserStream.edit + sugar, queued appends drain
    before the splice addresses the prefix."""
    p = Parser.from_matrices(
        art.matrices,
        ParserConfig(regex="<edit-facade>", first_seal_len=4, max_seal_len=8),
    )
    with p.open_stream() as st:
        text = "ab" * 10
        st.append(text)                    # still queued when edit arrives
        assert st.edit(2, 6, "ba") == len(text) - 2
        text = text[:2] + "ba" + text[6:]
        st.delete(0, 2)
        text = text[2:]
        st.insert(0, "ab")
        text = "ab" + text
        res = st.result()
        ref = cold.parse(text)
        assert np.array_equal(res.forest.pack(), ref.pack())
        assert st.accepted == ref.accepted
        assert st.n == len(text)
