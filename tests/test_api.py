"""Public API facade (repro/api.py): config, results, tickets, admission.

Covers the acceptance bars of the API redesign:
  * ``ParserConfig`` validation failures (bad backend, unresolvable mesh
    axes, non-pow2 bucket policy) and exact ``to_dict``/``from_dict``
    round-trips producing bit-identical parses;
  * facade-vs-direct-engine conformance (the full-corpus version lives in
    ``tests/test_conformance.py`` where the facade is a fifth route);
  * deadline-aware admission: admitted under a loose deadline, typed
    ``AdmissionError`` under a blown one, and a DEFINED cold-start path
    (un-served buckets are reported with queue depth instead of omitted);
  * the typed error hierarchy (``repro.errors``) raised by both services;
  * ``repro``'s lazy top-level exports (no jax import cost at ``import
    repro`` time).
"""

import json
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.api import ParseTicket, Parser, ParserConfig, SLOTargets
from repro.core.engine import ParserEngine, resolve_engine
from repro.errors import (
    AdmissionError,
    BudgetExceeded,
    ParseError,
    SessionNotFound,
)
from repro.serve.parse_service import ParseService
from repro.serve.stream_service import StreamService

PATTERN = "(a|b|ab)+"


@pytest.fixture(scope="module")
def parser():
    return Parser(ParserConfig(regex=PATTERN, n_chunks=4))


# ---------------------------------------------------------------- config


def test_config_rejects_bad_backend():
    with pytest.raises(ValueError, match="unknown parse backend"):
        ParserConfig(regex=PATTERN, backend="cuda-tensorcore")


def test_config_rejects_kernel_on_jnp():
    with pytest.raises(ValueError, match="kernel"):
        ParserConfig(regex=PATTERN, backend="jnp", kernel=True)


def test_config_rejects_non_pow2_buckets():
    with pytest.raises(ValueError, match="power of two"):
        ParserConfig(regex=PATTERN, min_chunk_len=12)
    with pytest.raises(ValueError, match="power of two"):
        ParserConfig(regex=PATTERN, first_seal_len=6)
    with pytest.raises(ValueError, match="power of two"):
        ParserConfig(regex=PATTERN, max_seal_len=48)


def test_config_rejects_unresolvable_mesh_axes():
    # 'model' is a real production axis but the declared parse mesh is
    # ('pod', 'data') — the chunk rule cannot resolve on it
    with pytest.raises(ValueError, match="does not resolve"):
        ParserConfig(regex=PATTERN, mesh="host", mesh_rules={"chunk": ("model",)})


def test_config_rejects_mesh_rules_without_mesh():
    with pytest.raises(ValueError, match="requires mesh"):
        ParserConfig(regex=PATTERN, mesh_rules={"chunk": ("pod",)})


def test_config_rejects_bad_mesh_and_empty_regex():
    with pytest.raises(ValueError, match="mesh"):
        ParserConfig(regex=PATTERN, mesh="tpu-pod-slice")
    with pytest.raises(ValueError, match="regex"):
        ParserConfig(regex="")
    with pytest.raises(ValueError, match="n_chunks"):
        ParserConfig(regex=PATTERN, n_chunks=0)


def test_config_rejects_bad_slo():
    with pytest.raises(ValueError, match="positive"):
        SLOTargets(p99_s=-1.0)
    with pytest.raises(ValueError, match="p50_s"):
        SLOTargets(p50_s=2.0, p99_s=1.0)


def test_config_dict_round_trip_exact():
    cfg = ParserConfig(
        regex=PATTERN,
        backend="packed",
        kernel=True,
        n_chunks=4,
        max_batch=16,
        first_seal_len=4,
        max_seal_len=64,
        cache_budget_bytes=1 << 20,
        max_pending=32,
        max_pending_chars=4096,
        slo=SLOTargets(p50_s=0.1, p99_s=0.5, default_deadline_s=2.0),
    )
    d = cfg.to_dict()
    # JSON-able all the way through (the declarative contract)
    cfg2 = ParserConfig.from_dict(json.loads(json.dumps(d)))
    assert cfg2 == cfg and cfg2.to_dict() == d
    with pytest.raises(ValueError, match="unknown ParserConfig keys"):
        ParserConfig.from_dict({**d, "max_qps": 100})


def test_config_mesh_rules_round_trip():
    cfg = ParserConfig(
        regex=PATTERN, mesh="host", mesh_rules={"chunk": ("pod",), "batch": "data"}
    )
    d = json.loads(json.dumps(cfg.to_dict()))
    assert ParserConfig.from_dict(d) == cfg
    rules = cfg.build_mesh_rules()
    assert rules.rules["chunk"] == "pod" and rules.rules["batch"] == "data"


def test_round_trip_config_parses_bit_identical():
    cfg = ParserConfig(regex=PATTERN, backend="packed", n_chunks=4)
    p1 = Parser(cfg)
    p2 = Parser(ParserConfig.from_dict(json.loads(json.dumps(cfg.to_dict()))))
    for text in ["", "abab", "ab" * 20, "x", "ba"]:
        a, b = p1.parse(text), p2.parse(text)
        assert np.array_equal(a.forest.pack(), b.forest.pack()), text
        assert a.ok == b.ok


# ------------------------------------------------------- facade vs engine


def test_facade_matches_direct_engine(parser):
    eng = ParserEngine(parser.matrices)
    for text in ["", "abab", "ab" * 40, "~", "ba" * 7]:
        res = parser.parse(text)
        direct = eng.parse(text, n_chunks=4)
        assert np.array_equal(res.forest.pack(), direct.pack()), text
        assert res.ok == direct.accepted
        assert res.backend == "jnp" and res.bucket is not None
        assert res.latency_s is not None and res.latency_s >= 0.0


def test_parse_batch_preserves_order(parser):
    texts = ["abab", "", "b", "a" * 23, "ab" * 40, "ba"]
    results = parser.parse_batch(texts)
    eng = ParserEngine(parser.matrices)
    for text, res in zip(texts, results):
        assert np.array_equal(res.forest.pack(), eng.parse(text, n_chunks=4).pack())


def test_result_accessors(parser):
    res = parser.parse("abab")
    assert res.ok and res.count_trees() == 4
    assert len(res.trees(limit=2)) == 2
    assert all(isinstance(t, str) for t in res.trees(limit=2))
    assert all(isinstance(t, tuple) for t in res.trees(limit=2, paths=True))
    assert res.matches(1) == [(0, 4)]       # outermost operator pair
    assert res.slpf is res.forest


def test_result_children_reports_direct_nesting():
    p = Parser(ParserConfig(regex="((a)(b))+", n_chunks=2))
    res = p.parse("abab")
    outer = min(p.groups)
    spans = res.matches(outer)
    assert (0, 2) in spans
    kids = res.children((0, 2))
    kid_spans = {(st, en) for _, st, en in kids}
    assert (0, 1) in kid_spans and (1, 2) in kid_spans
    # direct children only: nothing from the sibling iteration leaks in,
    # and a pair is never its own child (same-span NESTED pairs are fine —
    # an operator pair inside the group shares its span)
    assert (2, 3) not in kid_spans and (2, 4) not in kid_spans
    assert (outer, 0, 2) not in kids


def test_stream_facade_matches_cold_parse(parser):
    eng = ParserEngine(parser.matrices)
    with parser.open_stream() as stream:
        prefix = ""
        for piece in ["ab", "ab", "abab", "b"]:
            stream.append(piece)
            prefix += piece
        res = stream.result()
        assert np.array_equal(res.forest.pack(), eng.parse(prefix, n_chunks=4).pack())
        assert stream.accepted == res.ok
    with pytest.raises(SessionNotFound):
        parser.stream_service.slpf(stream.sid)   # closed on __exit__


# ---------------------------------------------------------------- tickets


def test_ticket_done_result_cancel(parser):
    t1 = parser.submit("abab")
    t2 = parser.submit("baba")
    assert not t1.done() and not t2.done()
    assert t2.cancel() is True               # never served
    r1 = t1.result()
    assert t1.done() and r1.ok
    assert t1.cancel() is False              # too late — already served
    with pytest.raises(ParseError, match="cancelled"):
        t2.result()
    assert isinstance(t1, ParseTicket)


# -------------------------------------------------------------- admission


def test_admission_loose_deadline_accepted(parser):
    res = parser.parse("abab", deadline_s=30.0)
    assert res.ok


def test_admission_blown_deadline_rejected(parser):
    svc = parser.parse_service
    parser.parse("abab")                      # seed the bucket's window
    bucket = svc.engine.bucket_shape(4, parser.config.n_chunks)
    svc._buckets[bucket].record(0.5)          # observed slow sample
    with pytest.raises(AdmissionError) as ei:
        parser.submit("abab", deadline_s=1e-4)
    assert ei.value.bucket == bucket
    assert ei.value.predicted_s >= 0.5 and ei.value.deadline_s == 1e-4
    with pytest.raises(AdmissionError):       # already-blown budget
        parser.submit("abab", deadline_s=0.0)
    assert isinstance(ei.value, ParseError)


def test_admission_cold_start_bucket_is_defined():
    p = Parser(ParserConfig(regex=PATTERN, n_chunks=4))
    svc = p.parse_service
    text = "ab" * 300                          # a bucket nothing has served
    bucket = svc.engine.bucket_shape(len(text), p.config.n_chunks)
    assert svc.admission_p99_s(bucket) == 0.0  # cold ⇒ optimistic predictor
    ticket = p.submit(text, deadline_s=0.050)  # cold bucket admits
    st = svc.stats
    # the bucket is REPORTED before first serve: served=0, live queue depth
    assert st["buckets"][bucket]["served"] == 0
    assert st["buckets"][bucket]["queue_depth"] == 1
    assert ticket.result().ok
    st = svc.stats
    assert st["buckets"][bucket]["served"] == 1
    assert st["buckets"][bucket]["queue_depth"] == 0   # drained, not omitted


def test_default_deadline_from_slo_config():
    p = Parser(
        ParserConfig(regex=PATTERN, n_chunks=4,
                     slo=SLOTargets(default_deadline_s=60.0))
    )
    assert p.parse("abab").ok                 # admits under the default
    bucket = p.parse_service.engine.bucket_shape(4, 4)
    p.parse_service._buckets[bucket].record(90.0)
    with pytest.raises(AdmissionError):       # default deadline now blown
        p.submit("abab")


def test_stream_admission_deadline():
    p = Parser(ParserConfig(regex=PATTERN, first_seal_len=4))
    stream = p.open_stream()
    assert stream.append("ab", deadline_s=30.0) == 2
    bucket = p.stream_service._session(stream.sid).parser._bucket_len(2)
    from repro.serve.parse_service import BucketStats

    p.stream_service._buckets.setdefault(bucket, BucketStats()).record(5.0)
    with pytest.raises(AdmissionError):
        stream.append("ab", deadline_s=1e-4)
    assert stream.result().ok


# ---------------------------------------------------------------- budgets


def test_parse_budget_exceeded():
    p = Parser(ParserConfig(regex=PATTERN, max_pending=2))
    p.submit("ab")
    p.submit("ba")
    with pytest.raises(BudgetExceeded) as ei:
        p.submit("abab")
    assert ei.value.budget == 2
    assert isinstance(ei.value, ValueError)   # old handlers keep working
    p.parse_service.run()
    assert p.submit("abab").result().ok       # drained queue admits again


def test_stream_budget_exceeded():
    p = Parser(ParserConfig(regex=PATTERN, max_pending_chars=4))
    stream = p.open_stream()
    stream.append("ab")
    with pytest.raises(BudgetExceeded):
        stream.append("abab")                 # 2 queued + 4 > 4


def test_stream_cold_bucket_reported_without_deadline():
    """A queued-but-unserved stream bucket appears in stats (served=0 with
    its live queue depth) even when the append carried NO deadline."""
    p = Parser(ParserConfig(regex=PATTERN, first_seal_len=4))
    stream = p.open_stream()
    stream.append("ab")                       # no deadline_s
    st = p.stream_service.stats
    assert st["buckets"], "cold bucket omitted from stream stats"
    (bucket,) = st["buckets"]
    assert st["buckets"][bucket]["served"] == 0
    assert st["buckets"][bucket]["queue_depth"] == 1
    assert stream.result().ok is not None     # drains fine afterwards


def test_parse_batch_admission_failure_cancels_queued():
    """A mid-batch rejection must not leave orphaned queued requests
    consuming the max_pending budget."""
    p = Parser(ParserConfig(regex=PATTERN, max_pending=2))
    with pytest.raises(BudgetExceeded):
        p.parse_batch(["ab", "ba", "abab"])   # third submit overflows
    assert p.parse_service.pending == 0       # first two were cancelled
    assert p.parse("abab").ok                 # budget fully available again


def test_ticket_records_admitted_deadline():
    p = Parser(ParserConfig(regex=PATTERN))
    assert p.submit("ab", deadline_s=0.5).deadline_s == 0.5
    assert p.submit("ab").deadline_s is None
    p2 = Parser(ParserConfig(regex=PATTERN,
                             slo=SLOTargets(default_deadline_s=3.0)))
    assert p2.submit("ab").deadline_s == 3.0  # config default applied


def test_session_not_found_is_typed_and_keyerror():
    p = Parser(ParserConfig(regex=PATTERN))
    with pytest.raises(SessionNotFound):
        p.stream_service.append(999, "ab")
    with pytest.raises(KeyError):             # back-compat
        p.stream_service.slpf(999)
    with pytest.raises(SessionNotFound):
        p.stream_service.close(999)


# ------------------------------------------------------------------ stats


def test_stats_aggregates_both_services():
    p = Parser(
        ParserConfig(regex=PATTERN, n_chunks=4,
                     slo=SLOTargets(p50_s=10.0, p99_s=20.0))
    )
    p.parse("abab")
    with p.open_stream() as stream:
        stream.append("abab")
        stream.result()
        st = p.stats()
        assert st["backend"] == "jnp"
        assert st["parse"]["batches_run"] >= 1
        assert st["stream"]["sessions"] == 1
        assert st["slo"]["targets"]["p99_s"] == 20.0
        for grade in st["slo"]["parse_buckets"].values():
            assert grade["p50_ok"] and grade["p99_ok"]   # loose targets
            assert grade["queue_depth"] == 0
        assert st["slo"]["stream_buckets"]               # graded too
        assert st["pending"] == 0


def test_stats_before_any_service_touch():
    p = Parser(ParserConfig(regex=PATTERN))
    st = p.stats()
    assert st["parse"] is None and st["stream"] is None
    assert st["slo"]["parse_buckets"] == {} and st["pending"] == 0


# ------------------------------------------------------- deprecation shims


def test_direct_service_construction_warns(parser):
    with pytest.warns(DeprecationWarning, match="repro:"):
        ParseService(parser.matrices)
    with pytest.warns(DeprecationWarning, match="repro:"):
        StreamService(parser.matrices)
    with pytest.warns(DeprecationWarning, match="repro:"):
        resolve_engine(parser.matrices, None)


def test_facade_path_does_not_warn(recwarn):
    import warnings

    p = Parser(ParserConfig(regex=PATTERN, n_chunks=4))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        p.parse("abab")
        with p.open_stream() as stream:
            stream.append("ab")
            stream.result()


# ------------------------------------------------------------ lazy exports


def test_top_level_exports_resolve():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    assert repro.Parser is Parser
    assert repro.list_backends() == sorted(repro.list_backends())
    assert {"jnp", "pallas", "packed"} <= set(repro.list_backends())


def test_import_repro_is_jax_free():
    """``import repro`` (and repro.errors) must not pay the jax import."""
    code = (
        "import sys; import repro; "
        # attribute access on a COLD import must resolve the submodule
        "assert issubclass(repro.errors.SessionNotFound, KeyError); "
        "assert 'jax' not in sys.modules, 'import repro pulled in jax'; "
        "assert repro.api.__name__ == 'repro.api'; "   # api pays jax, lazily
        "assert 'jax' in sys.modules; "
        "print('ok')"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "ok"
