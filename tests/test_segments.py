"""Segment computation vs the paper's tables and the brute-force oracle."""

import numpy as np
import pytest

from oracle import enumerate_lsts, lst_to_segments
from repro.core.numbering import number_regex
from repro.core.segments import compute_segments
from repro.data.regen import random_regex, sample_string
from repro.core import regex as rx


def test_paper_tab2_e2():
    """Tab. 2: RE e2 = (ab|a)* has exactly 10 segments, 3 initial, 3 final,
    one both initial and final."""
    t = compute_segments("(ab|a)*")
    assert t.n == 10
    assert int(t.initial.sum()) == 3
    assert int(t.final.sum()) == 3
    assert int((t.initial & t.final).sum()) == 1
    # the initial+final segment is the ε-LST "₁()₁⊣"
    both = int(np.flatnonzero(t.initial & t.final)[0])
    assert t.display(both).endswith("⊣")


def test_segment_shape_invariants():
    """Every segment = metasymbols* + one end-letter (terminal or ⊣)."""
    from repro.core.numbering import END, TERM

    for pat in ["(ab|a)*", "(a|b|ab)+", "a{2,3}b?", "[ab]c*"]:
        t = compute_segments(pat)
        syms = t.numbered.symbols
        for seg in t.segs:
            assert syms[seg[-1]].kind in (TERM, END)
            for sid in seg[:-1]:
                assert syms[sid].kind not in (TERM, END)


def test_ek_family_counts():
    """e(k) = (a|b)* a (a|b){k}: realizable segment count is 2k+7 (hand
    derivation in EXPERIMENTS.md §Paper-validation; Tab. 5's 4k+10 is not
    derivable from the paper's own Fig. 5 — documented discrepancy).  The
    qualitative claim (linear growth in k) is what matters and holds."""
    for k in range(1, 8):
        t = compute_segments(f"(a|b)*a(a|b){{{k}}}")
        assert t.n == 2 * k + 7


@pytest.mark.parametrize("pat,texts", [
    ("(ab|a)*", ["", "a", "ab", "aab", "abab", "aaa"]),
    ("(a|b|ab)+", ["ab", "abab", "ba"]),
    ("a{1,3}b", ["ab", "aab", "aaab"]),
])
def test_segments_cover_oracle_factors(pat, texts):
    """Every factor of every oracle-enumerated LST is a known segment."""
    numbered = number_regex(pat)
    t = compute_segments(numbered)
    known = set(t.segs)
    for text in texts:
        for lst in enumerate_lsts(numbered, text.encode()):
            for seg in lst_to_segments(numbered, lst):
                assert seg in known, (pat, text, seg)


def test_random_re_segments_cover_sampled_strings():
    """Property: for random REs, sampled valid strings' LST factors are all
    computed segments (Fig. 5 completeness)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(0, 10_000), st.integers(3, 9))
    @hyp.settings(max_examples=30, deadline=None)
    def run(seed, size):
        rng = np.random.Generator(np.random.Philox(seed))
        ast = random_regex(size, rng)
        numbered = number_regex(ast)
        t = compute_segments(numbered)
        known = set(t.segs)
        for _ in range(3):
            s = sample_string(ast, rng)[:8]
            for lst in enumerate_lsts(numbered, s, limit=50):
                for seg in lst_to_segments(numbered, lst):
                    assert seg in known

    run()
