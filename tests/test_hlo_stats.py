"""Trip-count-aware HLO analyzer vs programs with known costs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_stats import analyze_hlo_text


def _stats(fn, *args):
    return analyze_hlo_text(jax.jit(fn).lower(*args).compile().as_text())


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    s = _stats(lambda a, b: a @ b, a, b)
    assert abs(s.flops - 2 * 256 * 512 * 128) / (2 * 256 * 512 * 128) < 0.05


def test_scan_trip_count_multiplies():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    s = _stats(f, x, w)
    expect = 7 * 2 * 128 * 256 * 256
    assert s.flops >= expect
    assert s.flops < expect * 1.2
    assert s.unknown_trips == 0


def test_nested_scan_trips():
    def f(x, w):
        def inner(h, _):
            return jnp.minimum(h @ w, 1.0), None
        def outer(h, _):
            h2, _ = jax.lax.scan(inner, h, None, length=5)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s = _stats(f, x, w)
    expect = 15 * 2 * 64 ** 3
    assert expect <= s.flops < expect * 1.3
    assert s.unknown_trips == 0


def test_scan_bytes_do_not_explode():
    """Slice-aware byte model: a scan writing one row per step costs O(rows),
    not O(steps x full buffer)."""
    def f(x):
        def body(c, _):
            return c + 1.0, c
        _, ys = jax.lax.scan(body, x, None, length=64)
        return ys

    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    s = _stats(f, x)
    full = 64 * 1024 * 4
    # naive counting would be ~64 × (64·1024·4B) = 16.7 MB (O(trips × buffer));
    # slice-aware stays within a small constant of the data actually moved.
    assert full / 4 < s.bytes < 8 * full, s.bytes
