"""End-to-end training: loss goes down; crash → resume is trajectory-exact."""

from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeSpec
from repro.train.loop import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


SHAPE = ShapeSpec("tiny_train", seq_len=32, global_batch=4, kind="train")


def test_loss_decreases(mesh, tmp_path):
    from repro.optim.adamw import AdamWConfig

    cfg = get_smoke("tinyllama-1.1b")
    t = Trainer(cfg, SHAPE, mesh, tmp_path,
                TrainerConfig(total_steps=12, checkpoint_every=100, log_every=4),
                opt=AdamWConfig(lr_peak=5e-3, warmup_steps=2, total_steps=12))
    r = t.run()
    losses = [h["loss"] for h in r["history"]]
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_crash_resume_exact_trajectory(mesh, tmp_path):
    cfg = get_smoke("tinyllama-1.1b")
    a = Trainer(cfg, SHAPE, mesh, tmp_path / "a",
                TrainerConfig(total_steps=6, checkpoint_every=3, log_every=1))
    ra = a.run()

    b1 = Trainer(cfg, SHAPE, mesh, tmp_path / "b",
                 TrainerConfig(total_steps=6, checkpoint_every=3, log_every=1,
                               fail_at_step=4))
    with pytest.raises(RuntimeError, match="injected failure"):
        b1.run()
    b2 = Trainer(cfg, SHAPE, mesh, tmp_path / "b",
                 TrainerConfig(total_steps=6, checkpoint_every=3, log_every=1))
    rb = b2.run()
    assert abs(ra["final_loss"] - rb["final_loss"]) < 1e-4


def test_hybrid_arch_trains(mesh, tmp_path):
    cfg = get_smoke("zamba2-2.7b")
    t = Trainer(cfg, ShapeSpec("t", seq_len=16, global_batch=2, kind="train"),
                mesh, tmp_path, TrainerConfig(total_steps=3, checkpoint_every=100))
    r = t.run()
    assert np.isfinite(r["final_loss"])


def test_moe_arch_trains(mesh, tmp_path):
    cfg = get_smoke("mixtral-8x22b")
    t = Trainer(cfg, ShapeSpec("t", seq_len=16, global_batch=2, kind="train"),
                mesh, tmp_path, TrainerConfig(total_steps=3, checkpoint_every=100))
    r = t.run()
    assert np.isfinite(r["final_loss"])
