"""Multi-tenant fleet conformance: heterogeneous tenants, one engine pool.

Every tenant served through the fleet must be bit-identical to its own solo
``Parser`` — across every registered backend, across tenants whose ℓp lands
in the same or different automaton buckets, and including a dense-fallback
sparse tenant sharing a bucket with a width-reduced one.  The economics are
asserted too: compiled-program count scales with #buckets (not #tenants) and
the process-wide table cache serves repeat patterns without rebuilding.

Pattern zoo (jnp lane floor is 32, so ℓp buckets split at ℓ > 32):

  RX_SMALL   (a|b)*abb        ℓ=9,  4 classes, feasible width 4 (reduced)
  RX_MED     (a|b)×10         ℓ=21, 4 classes — same (Ab, ℓp)=(4, 32)
                              bucket as RX_SMALL, different true ℓ
  RX_LONG    a×40             ℓ=41, 4-class bucket at ℓp=64 — different
                              bucket from both
  RX_WIDE    a?×6             ℓ=28, width 21 → pow2 32 ≥ ℓp: the sparse
                              dense-fallback tenant; same (4, 32) bucket
                              as RX_SMALL on the sparse backend
"""

import numpy as np
import pytest

import repro
from repro import Parser, ParserConfig, ParserFleet
from repro.core.backend import list_backends
from repro.core.fleet import (
    FleetEngine,
    TenantSpec,
    clear_table_cache,
    normalize_regex,
)

RX_SMALL = "(a|b)*abb"
RX_MED = "(a|b)" * 10
RX_LONG = "a" * 40
RX_WIDE = "a?" * 6

TEXTS = {
    RX_SMALL: ["abb", "ababb", "bbabb", "a" * 7 + "bb"],
    RX_MED: ["ab" * 5, "ba" * 5, "a" * 10],
    RX_LONG: ["a" * 40],
    RX_WIDE: ["", "a", "aaa", "aaaaaa"],
}


def _assert_identical(result, oracle):
    assert np.array_equal(result.forest.classes, oracle.forest.classes)
    assert np.array_equal(result.forest.columns, oracle.forest.columns)
    assert result.ok == oracle.ok


# ------------------------------------------------------------- conformance


@pytest.mark.parametrize("backend", list_backends())
def test_fleet_conformant_per_backend(backend):
    """Each registered backend, as a fleet tenant, is bit-identical to its
    solo Parser on every text."""
    cfg = ParserConfig(regex=RX_SMALL, backend=backend, n_chunks=4)
    fleet = ParserFleet({"t": cfg})
    solo = Parser(cfg)
    for text in TEXTS[RX_SMALL]:
        _assert_identical(fleet.parse("t", text), solo.parse(text))


def test_mixed_backend_tenants_one_batch():
    """Tenants on different backends coexist; one parse_batch serves them
    all, each against its own oracle."""
    specs = {
        "jnp": ParserConfig(regex=RX_SMALL, backend="jnp", n_chunks=4),
        "packed": ParserConfig(regex=RX_SMALL, backend="packed", n_chunks=4),
        "sparse": ParserConfig(regex=RX_SMALL, backend="sparse", n_chunks=4),
    }
    fleet = ParserFleet(specs)
    solos = {k: Parser(c) for k, c in specs.items()}
    items = [(k, t) for k in specs for t in TEXTS[RX_SMALL]]
    results = fleet.parse_batch(items)
    for (k, text), res in zip(items, results):
        _assert_identical(res, solos[k].parse(text))


def test_same_and_different_lp_buckets():
    """Different true ℓ in one pow2 ℓp bucket, and a tenant that lands in
    its own bucket — all bit-identical, compile count = #buckets touched."""
    fleet = ParserFleet(
        {
            "small": ParserConfig(regex=RX_SMALL, n_chunks=4),
            "med": ParserConfig(regex=RX_MED, n_chunks=4),
            "long": ParserConfig(regex=RX_LONG, n_chunks=4),
        }
    )
    eng = fleet.engine
    assert eng.tenant("small").bucket_key == eng.tenant("med").bucket_key
    assert eng.tenant("long").bucket_key != eng.tenant("small").bucket_key
    assert eng.n_buckets == 2
    for name, rx in [("small", RX_SMALL), ("med", RX_MED), ("long", RX_LONG)]:
        solo = Parser(ParserConfig(regex=rx, n_chunks=4))
        for text in TEXTS[rx]:
            _assert_identical(fleet.parse(name, text), solo.parse(text))


def test_sparse_dense_fallback_shares_bucket():
    """A dense-fallback sparse tenant (feasible width ≥ ℓp) and a reduced
    one share an automaton bucket: the bucket binds at the member-max width
    (here the dense fallback S = ℓp) and both stay exact."""
    fleet = ParserFleet(
        {
            "reduced": ParserConfig(regex=RX_SMALL, backend="sparse", n_chunks=4),
            "dense": ParserConfig(regex=RX_WIDE, backend="sparse", n_chunks=4),
        }
    )
    eng = fleet.engine
    key = eng.tenant("reduced").bucket_key
    assert key == eng.tenant("dense").bucket_key
    runner = eng._buckets[key]
    assert runner.backend._width == key[2]  # bucket-wide dense fallback
    for name, rx in [("reduced", RX_SMALL), ("dense", RX_WIDE)]:
        solo = Parser(ParserConfig(regex=rx, backend="sparse", n_chunks=4))
        for text in TEXTS[rx]:
            _assert_identical(fleet.parse(name, text), solo.parse(text))


def test_sparse_bucket_width_grows_on_tenant_add():
    """Adding a wider tenant to a sparse bucket re-binds the shared width
    and re-jits; already-registered tenants stay bit-identical after."""
    fleet = ParserFleet(
        {"reduced": ParserConfig(regex=RX_SMALL, backend="sparse", n_chunks=4)}
    )
    runner = fleet.engine._buckets[fleet.engine.tenant("reduced").bucket_key]
    narrow = runner.backend._width
    solo = Parser(ParserConfig(regex=RX_SMALL, backend="sparse", n_chunks=4))
    _assert_identical(fleet.parse("reduced", "ababb"), solo.parse("ababb"))
    fleet.add("dense", ParserConfig(regex=RX_WIDE, backend="sparse", n_chunks=4))
    assert runner.backend._width > narrow
    for text in TEXTS[RX_SMALL]:
        _assert_identical(fleet.parse("reduced", text), solo.parse(text))


# ---------------------------------------------------------------- economics


def test_compile_count_scales_with_buckets_not_tenants():
    """12 same-bucket tenants, one text shape: ONE compiled program."""
    fleet = ParserFleet(
        {f"t{i}": ParserConfig(regex=RX_SMALL, n_chunks=4) for i in range(12)}
    )
    texts = [(f"t{i}", "ababb") for i in range(12)]
    fleet.parse_batch(texts)
    assert fleet.compile_count == 1
    fleet.parse_batch(texts)  # steady state: still one program
    assert fleet.compile_count == 1
    assert fleet.engine.n_buckets == 1


def test_table_cache_shared_across_fleets():
    clear_table_cache()
    patterns = {"a": RX_SMALL, "b": RX_MED}
    f1 = ParserFleet({k: ParserConfig(regex=v, n_chunks=4) for k, v in patterns.items()})
    snap1 = {
        str(k): v for k, v in f1.obs.metrics.snapshot().items()
    }
    assert snap1["table_cache_misses_total"][0]["value"] == 2.0
    assert "table_cache_hits_total" not in snap1
    f2 = ParserFleet({k: ParserConfig(regex=v, n_chunks=4) for k, v in patterns.items()})
    snap2 = {str(k): v for k, v in f2.obs.metrics.snapshot().items()}
    assert snap2["table_cache_hits_total"][0]["value"] == 2.0
    assert "table_cache_misses_total" not in snap2


def test_normalize_regex_is_structural():
    assert normalize_regex(RX_SMALL) == normalize_regex(RX_SMALL)
    assert normalize_regex("ab") != normalize_regex("ba")
    assert normalize_regex("(a)") != normalize_regex("a")  # groups number parens


def test_table_cache_key_includes_backend():
    clear_table_cache()
    fleet = ParserFleet(
        {
            "j": ParserConfig(regex=RX_SMALL, backend="jnp"),
            "s": ParserConfig(regex=RX_SMALL, backend="sparse"),
        }
    )
    snap = {str(k): v for k, v in fleet.obs.metrics.snapshot().items()}
    assert snap["table_cache_misses_total"][0]["value"] == 2.0


# ------------------------------------------------------------------- facade


def test_fleet_engine_rejects_duplicate_and_unknown_tenants():
    eng = FleetEngine()
    eng.add_tenant("t", TenantSpec(regex=RX_SMALL))
    with pytest.raises(ValueError, match="already registered"):
        eng.add_tenant("t", TenantSpec(regex=RX_SMALL))
    with pytest.raises(KeyError, match="unknown fleet tenant"):
        eng.tenant("ghost")


def test_parser_fleet_rejects_mesh_and_unknown_tenant():
    fleet = ParserFleet({"t": RX_SMALL})
    with pytest.raises(ValueError, match="mesh"):
        fleet.add("m", ParserConfig(regex=RX_SMALL, mesh="host"))
    with pytest.raises(KeyError):
        fleet.parse("ghost", "abb")


def test_fleet_stats_shape_and_slo_grades():
    fleet = ParserFleet(
        {
            "fast": ParserConfig(
                regex=RX_SMALL,
                n_chunks=4,
                slo=repro.SLOTargets(p99_s=1e4),  # generously satisfied
                weight=2.0,
            ),
            "plain": ParserConfig(regex=RX_MED, n_chunks=4),
        }
    )
    fleet.parse_batch([("fast", "abb"), ("plain", "ab" * 5)])
    s = fleet.stats()
    assert s["backend"] == "fleet"
    assert s["fleet"]["n_tenants"] == 2
    assert s["fleet"]["n_buckets"] == 1  # same (jnp, 4, 32) bucket
    fast = s["tenants"]["fast"]
    assert fast["served"] == 1 and fast["weight"] == 2.0
    assert fast["slo"]["p99_ok"] is True
    assert "p99_ok" not in s["tenants"]["plain"]["slo"]  # no targets set
    assert s["metrics"]  # registry snapshot present


def test_fleet_tenant_budget_rejected_typed():
    fleet = ParserFleet(
        {"t": ParserConfig(regex=RX_SMALL, n_chunks=4, max_pending=2)}
    )
    fleet.submit("t", "abb")
    fleet.submit("t", "abb")
    with pytest.raises(repro.BudgetExceeded):
        fleet.submit("t", "abb")


def test_fleet_results_in_input_order_across_buckets():
    fleet = ParserFleet(
        {
            "small": ParserConfig(regex=RX_SMALL, n_chunks=4),
            "long": ParserConfig(regex=RX_LONG, n_chunks=4),
        }
    )
    items = [("long", "a" * 40), ("small", "abb"), ("small", "bab"), ("long", "a" * 39)]
    results = fleet.parse_batch(items)
    assert [r.ok for r in results] == [True, True, False, False]
    assert results[0].backend == "jnp" and results[0].n_chunks == 4
