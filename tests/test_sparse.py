"""Sparse feasible-start backend: representation ops + edge-case coverage.

The cross-route bit-identity of the "sparse" backend is enforced by the
conformance harness (tests/test_conformance.py enumerates the registry).
This file covers what the harness cannot see from outside the opaque
product contract:

  * the (S, 1+W) sparse representation ops against dense Boolean oracles
    (compose / matvec / matvec_T / identity flag semantics);
  * the feasible-start computation's edge cases from the ISSUE checklist —
    empty texts (all-PAD chunks → flagged identity products), the
    dense-fallback rule (bucket reaches ℓp), single-state feasible sets,
    seal-boundary chunks in streaming, and ℓp not a multiple of the carried
    row bucket S;
  * the observability satellites: ``ParseResult.speculation`` and
    ``Parser.stats()["speculation"]``.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Parser, ParserConfig
from repro.core.backend import SparseBackend
from repro.core.engine import ParserEngine
from repro.core.matrices import (
    SPARSE_EMPTY,
    SPARSE_IDENT,
    boolean_matmul,
    boolean_matvec,
    feasible_start_widths,
    pack_transition_table,
    sparse_compose,
    sparse_identity,
    sparse_init_rows,
    sparse_is_identity,
    sparse_matvec,
    sparse_matvec_T,
    sparse_to_packed,
)
from repro.core.reference import ParallelArtifacts
from repro.core.segments import compute_segments
from repro.core.stream import StreamingParser

LP = 64
W = LP // 32


def _sparsify(M: np.ndarray, S: int) -> jnp.ndarray:
    """Dense {0,1} (ℓp, ℓp) → the sparse (S, 1+W) rep listing its nonzero
    columns (the test-side constructor; the backend builds these in reach)."""
    cols = np.where(M.any(axis=0))[0]
    assert len(cols) <= S, "test matrix too dense for the chosen S"
    packed = pack_transition_table(M[None])[0]          # (ℓp, W): row=col set
    P = np.full((S, 1 + W), int(SPARSE_EMPTY), dtype=np.uint32)
    P[:, 1:] = 0
    P[: len(cols), 0] = cols
    P[: len(cols), 1:] = packed[cols]
    return jnp.asarray(P)


def _random_sparse_dense(rng, n_cols):
    """A random Boolean matrix with exactly ``n_cols`` nonzero columns."""
    M = np.zeros((LP, LP), dtype=bool)
    cols = rng.choice(LP, size=n_cols, replace=False)
    for c in cols:
        M[rng.choice(LP, size=rng.integers(1, 5), replace=False), c] = True
    return M


# ------------------------------------------------------- representation ops


def test_sparse_ops_match_dense_oracle():
    rng = np.random.default_rng(7)
    for _ in range(10):
        A = _random_sparse_dense(rng, 6)
        B = _random_sparse_dense(rng, 5)
        Pa, Pb = _sparsify(A, 8), _sparsify(B, 8)
        v = rng.integers(0, 2, LP).astype(np.float32)

        # compose(later=A, earlier=B) ≡ A ⊗ B, carried on B's columns
        C = sparse_compose(Pa, Pb)
        assert np.array_equal(
            np.asarray(sparse_to_packed(C, LP)),
            pack_transition_table((boolean_matmul(A, B))[None])[0],
        )
        # matvec / matvec_T against the Boolean oracle
        assert np.array_equal(
            np.asarray(sparse_matvec(Pa, jnp.asarray(v))) > 0.5,
            boolean_matvec(A, v > 0.5),
        )
        assert np.array_equal(
            np.asarray(sparse_matvec_T(Pa, jnp.asarray(v))) > 0.5,
            boolean_matvec(A.T, v > 0.5),
        )


def test_sparse_identity_semantics():
    rng = np.random.default_rng(3)
    A = _random_sparse_dense(rng, 4)
    Pa = _sparsify(A, 8)
    I = sparse_identity(8, W)
    v = jnp.asarray(rng.integers(0, 2, LP).astype(np.float32))

    assert bool(sparse_is_identity(I)) and not bool(sparse_is_identity(Pa))
    assert int(I[0, 0]) == int(SPARSE_IDENT)
    # identity is a two-sided compose no-op and a matvec no-op
    for composed in (sparse_compose(Pa, I), sparse_compose(I, Pa)):
        assert np.array_equal(
            np.asarray(sparse_to_packed(composed, LP)),
            np.asarray(sparse_to_packed(Pa, LP)),
        )
    assert np.array_equal(np.asarray(sparse_matvec(I, v)), np.asarray(v))
    assert np.array_equal(np.asarray(sparse_matvec_T(I, v)), np.asarray(v))
    # and it densifies to the packed identity
    assert np.array_equal(
        np.asarray(sparse_to_packed(I, LP)),
        pack_transition_table(np.eye(LP, dtype=bool)[None])[0],
    )


def test_sparse_init_rows_sentinels():
    idx = jnp.asarray([3, 40, int(SPARSE_EMPTY)], dtype=jnp.int32)
    R = np.asarray(sparse_init_rows(idx, LP))
    assert R.shape == (3, W)
    assert R[0, 0] == 1 << 3 and R[0, 1] == 0
    assert R[1, 1] == 1 << 8 and R[1, 0] == 0
    assert not R[2].any()                     # sentinel slot → zero row


# --------------------------------------------------------- edge-case parses


def _engines(pattern, **sparse_kw):
    art = ParallelArtifacts.generate(pattern)
    e_ref = ParserEngine(art.matrices, backend="jnp")
    e_sp = ParserEngine(art.matrices, backend=SparseBackend(**sparse_kw))
    return e_ref, e_sp


def _assert_identical(e_ref, e_sp, texts, n_chunks=4):
    for text in texts:
        ref = e_ref.parse(text, n_chunks=n_chunks)
        got = e_sp.parse(text, n_chunks=n_chunks)
        assert np.array_equal(got.pack(), ref.pack()), text
        assert got.accepted == ref.accepted, text


def test_empty_text_all_pad_chunks():
    """Empty input: every chunk is all-PAD → every product is the flagged
    identity, and the parse matches the oracle."""
    e_ref, e_sp = _engines("(ab|a)*")
    _assert_identical(e_ref, e_sp, [b""])
    t = e_sp.tables
    chunks = jnp.asarray(e_sp._pad_to(np.zeros(0, np.int32), 4, 8))
    P = e_sp.phases.reach(t.N, chunks)
    assert P.shape[0] == 4 and bool(sparse_is_identity(P).all())


def test_dense_fallback_carries_all_rows():
    """Dense-fallback rule: when the pow2 width bucket reaches ℓp the backend
    carries S = ℓp rows — still bit-identical, no reduction."""
    e_ref, e_sp = _engines("(ab|a)*", min_width=4096)
    assert e_sp.backend._width == e_sp.tables.ell_pad
    _assert_identical(
        e_ref, e_sp, [b"", b"a", b"abaab", b"abab" * 9, b"ab~a"]
    )


def test_single_state_feasible_sets():
    """A cyclic distinct-letter RE: mid-cycle classes admit exactly one
    start state — the deepest reduction the representation must carry."""
    e_ref, e_sp = _engines("(abc)*")
    text = b"abcabcabc"
    classes = e_sp.classes_of_text(text)
    c, k = e_sp.bucket_shape(len(classes), 4)
    widths = feasible_start_widths(
        e_sp.tables.N, np.asarray(e_sp._pad_to(classes, c, k)).reshape(c, k)
    )
    assert (widths == 1).any(), widths        # 'b'/'c'-led chunks: one state
    _assert_identical(e_ref, e_sp, [text, b"abc", b"b", b"bcabc"])


def test_streaming_seal_boundary_chunks():
    """Appends that land exactly on, one short of, and one past every seal
    boundary keep the sparse sealed cache bit-identical to a cold parse."""
    e_ref, e_sp = _engines("(a|b|ab)+")
    text = b"abbaababba" * 4
    for cut in (3, 4, 5, 8, 9, 16):
        sp = StreamingParser(e_sp, first_seal_len=4)
        sp.append(e_sp.classes_of_text(text[:cut]))
        sp.append(e_sp.classes_of_text(text[cut:]))
        ref = e_ref.parse(text, n_chunks=4)
        assert np.array_equal(sp.current_slpf().pack(), ref.pack()), cut
        assert sp.n_sealed_chunks > 0


def test_ell_pad_not_multiple_of_row_bucket():
    """e(31): ℓ = 69 → ℓp = 96 with S = 64 — 96 % 64 ≠ 0, so gathered rows
    straddle the pow2 bucket; products must still compose exactly."""
    table = compute_segments("(a|b)*a(a|b){31}")
    e_ref = ParserEngine(table, backend="jnp")
    e_sp = ParserEngine(table, backend="sparse")
    lp, S = e_sp.tables.ell_pad, e_sp.backend._width
    assert S < lp and lp % S != 0, (lp, S)
    rng = np.random.default_rng(5)
    texts = [bytes(rng.choice([97, 98], size=n)) for n in (1, 33, 70)]
    _assert_identical(e_ref, e_sp, texts)


def test_kernel_path_bit_identical():
    e_ref, e_sp = _engines("(a|b|ab)+", kernel=True, interpret=True)
    _assert_identical(e_ref, e_sp, [b"", b"a", b"abba" * 6])


def test_feasible_depth_two_prunes_harder():
    e_ref, e_sp = _engines("(a|b)*a(a|b){5}", depth=2)
    text = b"abab" * 4
    classes = e_sp.classes_of_text(text)
    c, k = e_sp.bucket_shape(len(classes), 4)
    chunks = np.asarray(e_sp._pad_to(classes, c, k)).reshape(c, k)
    w1 = feasible_start_widths(e_sp.tables.N, chunks, depth=1)
    w2 = feasible_start_widths(e_sp.tables.N, chunks, depth=2)
    assert (w2[w2 >= 0] <= w1[w1 >= 0]).all()
    _assert_identical(e_ref, e_sp, [text, b"a", b"abaabb"])


# ------------------------------------------------------- binding + metadata


def test_unbound_backend_raises():
    b = SparseBackend()
    with pytest.raises(RuntimeError, match="unbound"):
        b.reach(jnp.zeros((2, 32, 32)), jnp.zeros((1, 8), jnp.int32))
    with pytest.raises(RuntimeError, match="unbound"):
        b.identity_product(32)


def test_bound_backend_rejects_other_automaton():
    _, e_sp = _engines("(ab|a)*")
    with pytest.raises(ValueError, match="bound to"):
        e_sp.backend.identity_product(e_sp.tables.ell_pad * 2)


def test_speculation_metadata_and_stats():
    p = Parser(ParserConfig(regex="(abc)*", backend="sparse"))
    r = p.parse(b"abcabc")
    spec = r.speculation
    assert spec is not None
    assert spec["width_max"] <= spec["product_rows"] <= spec["ell_pad"]
    assert spec["n_chunks_real"] >= 1 and spec["depth"] == 1
    st = p.stats()["speculation"]
    assert st["product_rows"] == spec["product_rows"]
    (agg,) = st["buckets"].values()
    assert agg["parses"] == 1 and agg["width_max"] == spec["width_max"]
    # dense backends carry no speculation metadata
    pd = Parser(ParserConfig(regex="(abc)*", backend="packed"))
    assert pd.parse(b"abc").speculation is None
    assert pd.stats()["speculation"] is None


def test_config_validates_feasible_depth():
    with pytest.raises(ValueError, match="feasible_depth"):
        ParserConfig(regex="a", feasible_depth=0)
    with pytest.raises(ValueError, match="sparse"):
        ParserConfig(regex="a", backend="packed", feasible_depth=2)
    cfg = ParserConfig(regex="a", backend="sparse", feasible_depth=3)
    assert ParserConfig.from_dict(cfg.to_dict()) == cfg
