"""Continuous batching: slot reuse is isolation-exact and non-blocking."""

import numpy as np
import pytest

import jax

from repro.configs import get_smoke
from repro.core.reference import ParallelArtifacts
from repro.models.model import init_params
from repro.serve.engine import ServeEngine, TokenDFA, byte_vocab
from repro.serve.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def setup():
    import dataclasses

    # float32: slot-reuse equality checks are exact-token comparisons, and a
    # reused slot decodes at a shifted absolute position — RoPE values rounded
    # to bf16 differ per position by enough (~0.2 in logits) to flip near-tie
    # argmaxes even with perfect isolation.  In f32 the positional noise is
    # ~1e-6 while a genuine K/V or SSM leak would still shift logits by O(0.1),
    # so the test stays discriminative for what it actually asserts.
    cfg = get_smoke("tinyllama-1.1b")
    cfg = dataclasses.replace(
        cfg, dtype="float32", param_dtype="float32", attn_p_dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _isolated_greedy(cfg, params, prompt, max_new, eos=0):
    eng = ServeEngine(cfg, params, max_seq=64, batch=1, eos_id=eos)
    res = eng.generate(prompt[None, :], max_new=max_new, temperature=0.0)
    toks = []
    for t in res.tokens[0]:
        if t == eos:
            break
        toks.append(int(t))
    return np.asarray(toks, np.int32)


def test_more_requests_than_slots(setup):
    """6 requests through 2 slots: every output matches isolated generation
    (slot reuse leaks nothing; admission order preserved per slot)."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, size=L).astype(np.int32),
                max_new=5)
        for i, L in enumerate([3, 5, 2, 4, 3, 6])
    ]
    batcher = ContinuousBatcher(cfg, params, batch=2, max_seq=64, eos_id=0)
    for r in reqs:
        batcher.submit(r)
    done = batcher.run()
    assert len(done) == len(reqs)
    for r in done:
        ref = _isolated_greedy(cfg, params, r.prompt, r.max_new)
        np.testing.assert_array_equal(r.output, ref), r.rid


def test_constrained_requests_in_batch(setup):
    cfg, params = setup
    art = ParallelArtifacts.generate("(ab|a)*c")
    tdfa = TokenDFA.from_matrices(art.matrices, byte_vocab(cfg.vocab_size))
    reqs = [
        Request(rid=i, prompt=np.array([ord("a")], np.int32), max_new=8,
                temperature=1.0, constraint=tdfa)
        for i in range(4)
    ]
    batcher = ContinuousBatcher(cfg, params, batch=2, max_seq=64, eos_id=0, seed=7)
    for r in reqs:
        batcher.submit(r)
    done = batcher.run()
    import re

    assert len(done) == 4
    for r in done:
        s = "".join(chr(c) for c in r.output)
        # The DFA mask guarantees every emitted token follows a live transition,
        # so prompt 'a' + generated is always a valid DFA path (language prefix);
        # if the request finished before max_new (EOS is only unmasked in final
        # states) the output must be a full member of L((ab|a)*c).
        state = tdfa.initial
        for tok in [ord("a")] + [int(t) for t in r.output]:
            state = int(tdfa.delta[state, tok])
            assert state >= 0, ("dead-state transition", s)
        if r.output.size < r.max_new:
            assert re.fullmatch("(ab|a)*c", "a" + s), s
