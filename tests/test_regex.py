"""RE string parser / AST utilities (core/regex.py)."""

import pytest

from repro.core import regex as rx


def test_basic_ast():
    ast = rx.parse_regex("(ab|a)*")
    assert isinstance(ast, rx.Star)
    assert isinstance(ast.item, rx.Group)
    alt = ast.item.item
    assert isinstance(alt, rx.Alt) and len(alt.items) == 2


def test_escapes_classes_wildcard():
    ast = rx.parse_regex(r"\(x[0-9a-f].\n")
    cat = ast
    assert isinstance(cat, rx.Cat)
    assert cat.items[0] == rx.Lit(ord("("))
    cc = cat.items[2]
    assert isinstance(cc, rx.CharClass) and cc.contains(ord("7")) and cc.contains(ord("c"))
    assert not cc.contains(ord("g"))
    wild = cat.items[3]
    assert isinstance(wild, rx.CharClass) and wild.contains(ord("z")) and not wild.contains(10)
    assert cat.items[4] == rx.Lit(10)


def test_negated_class():
    cc = rx.parse_regex("[^0-9]")
    assert isinstance(cc, rx.CharClass)
    assert cc.contains(ord("a")) and not cc.contains(ord("5"))


def test_bounded_repetition():
    ast = rx.parse_regex("a{2,4}")
    assert isinstance(ast, rx.Repeat) and (ast.lo, ast.hi) == (2, 4)
    ast = rx.parse_regex("a{3}")
    assert (ast.lo, ast.hi) == (3, 3)
    ast = rx.parse_regex("a{2,}")
    assert (ast.lo, ast.hi) == (2, None)
    with pytest.raises(rx.RegexSyntaxError):
        rx.parse_regex("a{4,2}")


def test_nullable_and_infinite_ambiguity():
    assert rx.nullable(rx.parse_regex("a*"))
    assert not rx.nullable(rx.parse_regex("a+"))
    assert rx.nullable(rx.parse_regex("(a|\\e)"))
    # paper: (a|ε)* is infinitely ambiguous (iterator over nullable body)
    assert rx.infinitely_ambiguous(rx.parse_regex("(a|\\e)*"))
    assert rx.infinitely_ambiguous(rx.parse_regex("(a*|ab)+"))
    assert not rx.infinitely_ambiguous(rx.parse_regex("(ab|a)*"))


def test_node_size_matches_paper_family():
    # Ex. 5: ||e(k)|| = 3k + 7 on the paper's counting (3 symbols per repeat
    # copy: a, b, one union pair).  Our parser additionally numbers the user's
    # grouping parens (App. A extra parens): one extra symbol per copy inside
    # the repeat (4k) and one around the starred union (+1): 4k + 8.
    for k in range(1, 6):
        ast = rx.parse_regex(f"(a|b)*a(a|b){{{k}}}")
        assert rx.node_size(ast) == 4 * k + 8


def test_syntax_errors():
    for bad in ["(a", "a)", "[a", "a{", "*a", "a|*"]:
        with pytest.raises(rx.RegexSyntaxError):
            rx.parse_regex(bad)
