"""Brute-force oracles for the parser tests.

``enumerate_lsts``: all LSTs of a text by DFS over the numbered RE's Glushkov
graph (paper Prop. 1: the LST language is the local language of ``e# ⊣``) —
completely independent of segments/automata/matrices, so it cross-checks the
entire production pipeline.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.numbering import END, EPS, NumberedRE, TERM


def enumerate_lsts(
    numbered: NumberedRE, text: bytes, limit: int = 100_000, rep_limit: int = 2
) -> List[Tuple[int, ...]]:
    """All LSTs as tuples of sids.  ``rep_limit`` bounds per-metasymbol repeats
    between consecutive terminals (matches the tool's App. A policy)."""
    syms = numbered.symbols
    follow = numbered.follow
    classes = [numbered.byte_to_class[b] for b in text]
    n = len(classes)
    out: List[Tuple[int, ...]] = []

    def matches(sid: int, pos: int) -> bool:
        s = syms[sid]
        if s.kind != TERM or pos >= n:
            return False
        return classes[pos] != 0 and classes[pos] in numbered.term_classes[sid]

    # DFS states: (sid just taken, chars consumed, path, counts since last terminal)
    stack = []
    for s0 in sorted(numbered.first):
        stack.append((s0, (s0,), 0, {s0: 1}))
    while stack:
        sid, path, consumed, counts = stack.pop()
        s = syms[sid]
        if s.kind == END:
            if consumed == n:
                out.append(path)
                if len(out) >= limit:
                    return out
            continue
        if s.kind == TERM:
            if not matches(sid, consumed):
                continue
            consumed += 1
            counts = {}
        for nxt in sorted(follow.get(sid, ())):
            c = counts.get(nxt, 0)
            if c >= rep_limit and syms[nxt].kind != TERM and syms[nxt].kind != END:
                continue
            nc = dict(counts)
            nc[nxt] = c + 1
            stack.append((nxt, path + (nxt,), consumed, nc))
    return out


def lst_to_segments(numbered: NumberedRE, lst: Tuple[int, ...]) -> List[Tuple[int, ...]]:
    """Factor an LST (sid sequence) into its maximal segments."""
    syms = numbered.symbols
    segs: List[Tuple[int, ...]] = []
    cur: List[int] = []
    for sid in lst:
        cur.append(sid)
        if syms[sid].kind in (TERM, END):
            segs.append(tuple(cur))
            cur = []
    assert not cur, "LST must end with an end-letter"
    return segs


def render_lst(numbered: NumberedRE, lst: Tuple[int, ...]) -> str:
    return "".join(numbered.display_sym(s) for s in lst)
