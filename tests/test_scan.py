"""Generic chunked three-phase scan (core/scan.py) — the paper's schema."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scan import (
    associative_prefix,
    chunk_fold,
    chunked_scan,
    exclusive_entries,
)

AFFINE_COMBINE = lambda later, earlier: (
    later[0] * earlier[0],
    later[0] * earlier[1] + later[1],
)
AFFINE_APPLY = lambda e, s: e[0] * s + e[1]


def _serial_fold(a, b, init):
    s, outs = init, []
    for t in range(len(a)):
        s = a[t] * s + b[t]
        outs.append(s)
    return np.stack(outs)


def test_chunked_scan_equals_fold():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(0, 1000), st.sampled_from([1, 2, 3, 4, 6, 12]))
    @hyp.settings(max_examples=25, deadline=None)
    def run(seed, n_chunks):
        rng = np.random.RandomState(seed)
        n = 24
        a = jnp.asarray(rng.uniform(0.5, 1.0, n).astype(np.float32))
        b = jnp.asarray(rng.randn(n).astype(np.float32))
        init = jnp.float32(rng.randn())
        got = chunked_scan(
            AFFINE_COMBINE, AFFINE_APPLY, (a, b), init,
            (jnp.float32(1.0), jnp.float32(0.0)), n_chunks,
        )
        ref = _serial_fold(np.asarray(a), np.asarray(b), float(init))
        np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-5, atol=1e-5)

    run()


def test_associative_prefix_matmul():
    rng = np.random.RandomState(0)
    mats = jnp.asarray(rng.rand(5, 3, 3).astype(np.float32))
    pref = associative_prefix(lambda l, e: l @ e, mats)
    acc = np.eye(3, dtype=np.float32)
    for i in range(5):
        acc = np.asarray(mats[i]) @ acc
        np.testing.assert_allclose(np.asarray(pref[i]), acc, rtol=2e-4)


def test_exclusive_entries_shift():
    a = jnp.asarray(np.array([2.0, 3.0, 5.0], np.float32))
    b = jnp.zeros(3, jnp.float32)
    entries = exclusive_entries(
        AFFINE_COMBINE, AFFINE_APPLY, (a, b), jnp.float32(1.0)
    )
    np.testing.assert_allclose(np.asarray(entries), [1.0, 2.0, 6.0])


def test_chunk_fold_matrix_monoid():
    rng = np.random.RandomState(1)
    mats = jnp.asarray((rng.rand(6, 4, 4) < 0.3).astype(np.float32))
    combine = lambda l, e: jnp.minimum(l @ e, 1.0)
    out = chunk_fold(combine, mats, jnp.eye(4, dtype=jnp.float32))
    acc = np.eye(4, dtype=np.float32)
    for i in range(6):
        acc = np.minimum(np.asarray(mats[i]) @ acc, 1.0)
    np.testing.assert_allclose(np.asarray(out), acc)
