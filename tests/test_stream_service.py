"""Session-level streaming service (serve/stream_service.py)."""

import numpy as np
import pytest

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.serve.stream_service import StreamService

AMBIG = "(a|b|ab)+"


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate(AMBIG)


@pytest.fixture(scope="module")
def engine(art):
    return ParserEngine(art.matrices)


def test_interleaved_sessions_are_exact(art, engine):
    svc = StreamService(engine, max_batch=4, first_seal_len=4)
    texts = {0: "abab" * 3, 1: "b" + "ab" * 10, 2: "ba", 3: ""}
    sids = {k: svc.open() for k in texts}
    # interleave appends round-robin, two chars at a time
    offsets = {k: 0 for k in texts}
    while any(offsets[k] < len(texts[k]) for k in texts):
        for k in texts:
            piece = texts[k][offsets[k] : offsets[k] + 2]
            offsets[k] += len(piece)
            if piece:
                svc.append(sids[k], piece)
    for k, text in texts.items():
        got = svc.slpf(sids[k])
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(got.columns, ref.columns), text
        cold = engine.parse(text)
        assert np.array_equal(got.pack(), cold.pack())


def test_same_bucket_sessions_share_one_reach_batch(engine):
    svc = StreamService(engine, max_batch=8, first_seal_len=8)
    sids = [svc.open() for _ in range(8)]
    for sid in sids:
        svc.append(sid, "abab")          # same piece bucket for every session
    svc.drain()
    assert svc.batches_run == 1          # one batched reach, not 8
    assert svc.pending_chars == 0


def test_fifo_and_max_batch(engine):
    svc = StreamService(engine, max_batch=2, first_seal_len=8)
    sids = [svc.open() for _ in range(5)]
    for sid in sids:
        svc.append(sid, "ab")
    svc.drain()
    assert svc.batches_run == 3          # ceil(5 / 2)


def test_eviction_by_bytes_budget_is_exact(art, engine):
    per_product = engine.tables.ell_pad ** 2 * 4
    svc = StreamService(
        engine, max_batch=4, first_seal_len=4,
        cache_budget_bytes=3 * per_product,   # room for ~1 session's cache
    )
    texts = {0: "abab" * 4, 1: "ab" * 9, 2: "ba" + "ab" * 6}
    sids = {k: svc.open() for k in texts}
    for k, text in texts.items():
        svc.append(sids[k], text)
    svc.drain()
    assert svc.evictions > 0             # budget forced cache drops
    for k, text in texts.items():        # …but results are untouched
        got = svc.slpf(sids[k])
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(got.columns, ref.columns), text
    assert svc.stats["rebuilds"] > 0     # evicted sessions rebuilt on touch


def test_eviction_converges_below_join_cache_size(art, engine):
    """bugfix: cache_nbytes counts join-cache bytes — per-product eviction
    must RELEASE them too (drop_sealed_product reports the true freed
    bytes), so a budget smaller than the join cache converges by per-node
    drops alone instead of spinning over budget into whole-cache drops."""
    svc = StreamService(engine, max_batch=4, first_seal_len=4)
    a, b = svc.open(), svc.open()
    for sid in (a, b):
        svc.append(sid, "ab" * 14)        # 28 chars → sealed exactly 4+8+16
        svc.slpf(sid)                     # builds + caches the join entries
    pa = svc._sessions[a].parser
    join_bytes = pa._join_nbytes()
    assert join_bytes > 0
    svc.cache_budget_bytes = join_bytes // 2
    svc._maybe_evict()
    # the LRU victim is FULLY reclaimed by per-node drops — products and
    # join entries both — without falling back to a whole-cache cold drop
    assert pa.cache_nbytes == 0
    assert not pa._cold                   # classes+structure stay warm
    # the protected most-recent session is never touched
    assert svc._sessions[b].parser.cache_nbytes > 0
    # correctness is untouched; the re-query pays per-chunk rebuilds
    ref = parse_serial_matrix(art.matrices, "ab" * 14)
    assert np.array_equal(svc.slpf(a).columns, ref.columns)
    assert pa.rebuilds == 3               # one per re-reached chunk (4, 8, 16)


def test_cost_aware_eviction_order(art, engine):
    """Largest-chunk sealed products evict first; LRU session breaks ties."""
    per_product = engine.tables.ell_pad ** 2 * 4
    svc = StreamService(engine, max_batch=4, first_seal_len=4)
    text = "ab" * 14                      # 28 chars → sealed chunks 4, 8, 16
    # touch order a < b < c; c (most recent) is never evicted
    a, b, c = (svc.open() for _ in range(3))
    for sid in (a, b, c):
        svc.append(sid, text)
    svc.drain()

    def resident_lens(sid):
        return sorted(
            chars for _, chars, _ in svc._sessions[sid].parser.sealed_cache_entries()
        )

    for sid in (a, b, c):
        assert resident_lens(sid) == [4, 8, 16]
    # one product over budget → exactly one drop: A's (LRU) largest chunk
    svc.cache_budget_bytes = svc.bytes_cached - per_product
    svc._maybe_evict()
    assert svc.evictions == 1
    assert resident_lens(a) == [4, 8]
    assert resident_lens(b) == [4, 8, 16]
    # next drop: chunk size dominates LRU — B's 16 goes before A's 8
    svc.cache_budget_bytes = svc.bytes_cached - per_product
    svc._maybe_evict()
    assert svc.evictions == 2
    assert resident_lens(a) == [4, 8]
    assert resident_lens(b) == [4, 8]
    assert resident_lens(c) == [4, 8, 16]
    # partial eviction trades work, never correctness
    for sid in (a, b, c):
        got = svc.slpf(sid)
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(got.columns, ref.columns)
    assert svc.stats["rebuilds"] >= 2


def test_eviction_falls_back_to_full_drop(engine):
    """A budget below what product drops can free forces whole-cache drops."""
    svc = StreamService(engine, max_batch=4, first_seal_len=4,
                        cache_budget_bytes=1)
    a, b = svc.open(), svc.open()
    svc.append(a, "abab" * 3)
    svc.append(b, "abab" * 3)
    svc.drain()
    # most recent session is never evicted; the LRU one went fully cold
    assert svc._sessions[a].parser.cache_nbytes == 0
    assert svc._sessions[b].parser.cache_nbytes > 0


def test_stats_shape_and_contents(engine):
    svc = StreamService(engine, max_batch=4, first_seal_len=8)
    a, b = svc.open(), svc.open()
    svc.append(a, "abab")
    svc.append(b, "ab" * 8)
    svc.drain()
    svc.slpf(a)
    st = svc.stats
    for key in ("backend", "sessions", "pending", "peak_queue_depth",
                "batches_run", "compile_count", "bytes_cached", "evictions",
                "rebuilds", "buckets"):
        assert key in st, key
    assert st["backend"] == "jnp"
    assert st["sessions"] == 2 and st["pending"] == 0
    assert st["pending_chars"] == 0
    assert st["peak_queue_depth"] == 2   # request units, like ParseService
    assert st["bytes_cached"] > 0 and st["evictions"] == 0
    served = sum(v["served"] for v in st["buckets"].values())
    assert served == 2                   # one completed append per session
    for v in st["buckets"].values():
        assert v["mean_latency_s"] >= 0.0
        assert v["max_latency_s"] >= v["mean_latency_s"]
        # sorted-window percentiles (SLO inputs): ordered and bounded by max
        assert 0.0 <= v["p50_latency_s"] <= v["p99_latency_s"] <= v["max_latency_s"]


def test_steady_state_sessions_never_recompile(art):
    eng = ParserEngine(art.matrices)
    svc = StreamService(eng, max_batch=4, first_seal_len=4)
    def one_round():
        sids = [svc.open() for _ in range(3)]
        for sid in sids:
            for piece in ("ab", "abab", "ab" * 6):
                svc.append(sid, piece)
        for sid in sids:
            svc.slpf(sid)
            svc.close(sid)
    one_round()
    warm = eng.compile_count
    one_round()
    assert eng.compile_count == warm


def test_empty_session_holds_no_cache_bytes(engine):
    """A fresh session's shared identity tail is not phantom cache — a tight
    budget must not 'evict' empty sessions instead of real products."""
    svc = StreamService(engine, first_seal_len=4, cache_budget_bytes=1)
    svc.open()
    assert svc.bytes_cached == 0
    svc.drain()
    assert svc.evictions == 0


def test_slpf_drains_only_that_session(engine):
    svc = StreamService(engine, first_seal_len=4)
    a, b = svc.open(), svc.open()
    svc.append(a, "abab")
    svc.append(b, "ab" * 6)
    svc.slpf(a)                          # must not absorb b's backlog
    assert svc.stats["pending_chars"] == 12
    svc.drain()
    assert svc.stats["pending_chars"] == 0


def test_close_frees_session(engine):
    svc = StreamService(engine, first_seal_len=4)
    sid = svc.open()
    svc.append(sid, "ab")
    svc.drain()
    svc.close(sid)
    assert svc.stats["sessions"] == 0 and svc.bytes_cached == 0
    with pytest.raises(KeyError):
        svc.slpf(sid)


def test_rejects_backend_with_prebuilt_engine(engine):
    with pytest.raises(ValueError, match="prebuilt ParserEngine"):
        StreamService(engine, backend="pallas")


# ------------------------------------------------------- packed backend


@pytest.fixture(scope="module")
def packed_engine(art):
    return ParserEngine(art.matrices, backend="packed")


def _packed_product_bytes(eng):
    """Bytes of ONE packed sealed product: (ℓp, W) uint32 words."""
    lp = eng.tables.ell_pad
    return lp * (lp // 32) * 4


def test_packed_eviction_and_rebuild_are_exact(art, packed_engine):
    """Eviction + transparent rebuild under the packed backend: the bytes
    budget is enforced against packed product sizes and results are exact."""
    eng = packed_engine
    per_product = _packed_product_bytes(eng)
    # the packed cache entry is 32× smaller than the f32 layout's ℓp²·4
    assert per_product * 32 == eng.tables.ell_pad ** 2 * 4
    svc = StreamService(
        eng, max_batch=4, first_seal_len=4,
        cache_budget_bytes=3 * per_product,
    )
    texts = {0: "abab" * 4, 1: "ab" * 9, 2: "ba" + "ab" * 6}
    sids = {k: svc.open() for k in texts}
    for k, text in texts.items():
        svc.append(sids[k], text)
    svc.drain()
    assert svc.evictions > 0
    # byte accounting uses the packed itemsize, per entry and in aggregate
    for s in svc._sessions.values():
        for _, _, nbytes in s.parser.sealed_cache_entries():
            assert nbytes == per_product
    assert svc.bytes_cached < 3 * (eng.tables.ell_pad ** 2 * 4)
    for k, text in texts.items():        # rebuild on touch, results exact
        got = svc.slpf(sids[k])
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(got.columns, ref.columns), text
    assert svc.stats["rebuilds"] > 0


def test_packed_cost_aware_eviction_order(packed_engine):
    """The largest-chunk-first ranking holds with packed product sizes."""
    eng = packed_engine
    per_product = _packed_product_bytes(eng)
    svc = StreamService(eng, max_batch=4, first_seal_len=4)
    a, b = svc.open(), svc.open()
    for sid in (a, b):
        svc.append(sid, "ab" * 14)        # sealed chunks 4, 8, 16
    svc.drain()
    svc.cache_budget_bytes = svc.bytes_cached - per_product
    svc._maybe_evict()
    assert svc.evictions == 1             # exactly one packed product freed
    lens = sorted(
        chars for _, chars, _ in svc._sessions[a].parser.sealed_cache_entries()
    )
    assert lens == [4, 8]                 # LRU session's largest chunk went


def test_packed_snapshot_restore_under_eviction(art, packed_engine):
    """snapshot → evict → restore round-trips the packed product cache."""
    eng = packed_engine
    svc = StreamService(eng, max_batch=4, first_seal_len=4)
    sid = svc.open()
    text = "abab" * 4
    svc.append(sid, text)
    svc.drain()
    parser = svc._sessions[sid].parser
    snap = parser.snapshot()
    assert snap.sealed_products[0].dtype == np.uint32    # packed repr held
    # force a whole-cache eviction, then restore the warm snapshot
    svc.cache_budget_bytes = 1
    svc.open()                            # a newer session so sid is LRU
    svc._maybe_evict()
    assert parser.cache_nbytes == 0
    parser.restore(snap)
    assert parser.cache_nbytes > 0 and parser.rebuilds == 0
    got = svc.slpf(sid)
    ref = parse_serial_matrix(art.matrices, text)
    assert np.array_equal(got.columns, ref.columns)
    assert parser.rebuilds == 0           # restore made the rebuild unnecessary
    # a COLD snapshot round-trips too (rebuild deferred to next touch)
    parser.drop_cache()
    cold = parser.snapshot()
    assert cold.sealed_products is None
    parser.restore(cold)
    assert np.array_equal(svc.slpf(sid).columns, ref.columns)
    # per-chunk rebuild accounting: 2 sealed leaves (4+8) + the 4-char tail
    assert parser.rebuilds == 3
