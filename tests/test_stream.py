"""Streaming incremental parse (core/stream.py) vs the cold engine + oracles.

Every incremental state must be *bit-identical* to a cold parse of the same
prefix — packed columns and tree counts — on an ambiguous RE, for any split
of the text into appends, across seal boundaries, and after snapshot/restore
or cache eviction.
"""

import numpy as np
import pytest

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts, parse_parallel_reference
from repro.core.serial import parse_serial_matrix
from repro.core.stream import StreamingParser

AMBIG = "(a|b|ab)+"   # ambiguous: many LSTs per text

TEXTS = ["b", "ab", "abab", "ababab", "a" * 23, "ab" * 40, "ba", "axb"]


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate(AMBIG)


@pytest.fixture(scope="module")
def engine(art):
    return ParserEngine(art.matrices)


def _splits(text, cuts):
    pieces, prev = [], 0
    for c in list(cuts) + [len(text)]:
        pieces.append(text[prev:c])
        prev = c
    return pieces


def _assert_stream_equals_cold(sp, engine, art, prefix):
    got = sp.current_slpf()
    cold = engine.parse(prefix)
    assert np.array_equal(got.pack(), cold.pack()), prefix
    assert got.count_trees() == cold.count_trees()
    ref = parse_serial_matrix(art.matrices, prefix)
    assert np.array_equal(got.columns, ref.columns)


@pytest.mark.parametrize("text", TEXTS)
def test_single_append_equals_cold_parse(art, engine, text):
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append(text)
    _assert_stream_equals_cold(sp, engine, art, text)


def test_every_split_of_a_text(art, engine):
    text = "abababab"
    for c1 in range(len(text) + 1):
        for c2 in range(c1, len(text) + 1):
            sp = StreamingParser(engine, first_seal_len=4)
            for piece in _splits(text, [c1, c2]):
                sp.append(piece)
            _assert_stream_equals_cold(sp, engine, art, text)


def test_char_at_a_time_every_prefix(art, engine):
    """Each intermediate state is exact, not just the final one."""
    text = "ab" * 9
    sp = StreamingParser(engine, first_seal_len=4)
    for i, ch in enumerate(text):
        sp.append(ch)
        prefix = text[: i + 1]
        got = sp.current_slpf()
        cold = engine.parse(prefix)
        assert np.array_equal(got.pack(), cold.pack()), prefix
        assert got.count_trees() == cold.count_trees()


def test_matches_paper_reference_oracle(art, engine):
    text = "ababab"
    sp = StreamingParser(engine, first_seal_len=4)
    for piece in ("ab", "a", "bab"):
        sp.append(piece)
    got = sp.current_slpf()
    paper = parse_parallel_reference(art, text, c=3)
    assert np.array_equal(got.columns, paper.columns)


def test_empty_stream(art, engine):
    sp = StreamingParser(engine)
    assert sp.n == 0
    slpf = sp.current_slpf()
    expected = (art.matrices.I & art.matrices.F)[None, :]
    assert np.array_equal(slpf.columns, expected)
    assert slpf.classes.shape == (0,)
    cold = engine.parse("")
    assert np.array_equal(slpf.pack(), cold.pack())
    # empty prefix of (a|b|ab)+ is not a valid text
    assert sp.accepted == cold.accepted


def test_zero_length_appends_are_noops(art, engine):
    sp = StreamingParser(engine, first_seal_len=4)
    assert sp.append("") == 0
    sp.append("abab")
    before = sp.current_slpf().pack()
    assert sp.append("") == 0
    assert sp.append(b"") == 0
    assert sp.n == 4
    assert np.array_equal(sp.current_slpf().pack(), before)
    _assert_stream_equals_cold(sp, engine, art, "abab")


def test_append_crossing_seal_boundaries(art, engine):
    """One append spanning several geometric seal boundaries at once."""
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("ab")                       # tail only
    text = "ab" + "ab" * 20               # crosses the 4- and 8-seals (+ more)
    sp.append("ab" * 20)
    assert sp.n_sealed_chunks >= 2
    _assert_stream_equals_cold(sp, engine, art, text)


def test_geometric_sealing_bounds_chunk_count(engine):
    sp = StreamingParser(engine, first_seal_len=4)
    n = 500
    sp.append("ab" * (n // 2))
    # sealed lengths 4, 8, 16, … — O(log n) chunks, power-of-two sizes only
    assert sp.n_sealed_chunks <= int(np.log2(n)) + 1
    lens = [len(c) for c in sp._sealed_classes]
    assert all(l & (l - 1) == 0 for l in lens)
    assert lens == sorted(lens)


def test_snapshot_restore_roundtrip(art, engine):
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("abab")
    sp.append("ab")
    snap = sp.snapshot()
    base = sp.current_slpf().pack()

    sp.append("ba" * 8)                   # diverge (crosses a seal)
    _assert_stream_equals_cold(sp, engine, art, "ababab" + "ba" * 8)

    sp.restore(snap)
    assert sp.n == 6
    assert np.array_equal(sp.current_slpf().pack(), base)
    sp.append("abab")                     # re-diverge differently
    _assert_stream_equals_cold(sp, engine, art, "ababab" + "abab")

    # restore into a *fresh* parser on the same engine
    sp2 = StreamingParser(engine, first_seal_len=4)
    sp2.restore(snap)
    assert np.array_equal(sp2.current_slpf().pack(), base)


def test_accepted_tracks_prefix_validity(engine):
    sp = StreamingParser(engine, first_seal_len=4)
    for ch, ok in [("a", True), ("b", True), ("x", False), ("a", False)]:
        sp.append(ch)
        assert sp.accepted == ok, sp.n


def test_invalid_text_empty_forest(art, engine):
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("a")
    sp.append("xb")                       # 'x' has no arc: forest dies
    got = sp.current_slpf()
    assert not got.accepted and got.count_trees() == 0
    _assert_stream_equals_cold(sp, engine, art, "axb")


def test_no_per_append_rejit(art, engine):
    """Steady-state appends reuse the bucketed phase programs: a second
    identical stream compiles nothing new."""
    eng = ParserEngine(art.matrices)   # fresh engine: clean compile counter
    text = "ab" * 40

    def stream():
        sp = StreamingParser(eng, first_seal_len=4)
        for ch in text:
            sp.append(ch)
        return sp.current_slpf()

    first = stream()
    warm = eng.compile_count
    second = stream()
    assert eng.compile_count == warm       # zero re-jit on the warm stream
    assert np.array_equal(first.pack(), second.pack())


def test_drop_cache_rebuilds_transparently(art, engine):
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("abab" * 4)
    assert sp.cache_nbytes > 0
    sp.drop_cache()
    assert sp.cache_nbytes == 0
    _assert_stream_equals_cold(sp, engine, art, "abab" * 4)   # rebuilt
    # per-chunk accounting: 2 sealed leaves (4+8) + the 4-char tail re-reach
    assert sp.rebuilds == 3 and sp.cache_nbytes > 0
    sp.append("ab")                        # appending after eviction works too
    _assert_stream_equals_cold(sp, engine, art, "abab" * 4 + "ab")


def test_snapshot_of_cold_parser_is_o1_and_restores(art, engine):
    """Snapshotting an evicted parser must not rebuild its device cache."""
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("abab" * 3)
    sp.drop_cache()
    snap = sp.snapshot()
    assert sp.cache_nbytes == 0 and sp.rebuilds == 0   # still cold
    sp2 = StreamingParser(engine, first_seal_len=4)
    sp2.restore(snap)
    _assert_stream_equals_cold(sp2, engine, art, "abab" * 3)
    assert sp2.rebuilds == 2               # rebuilt on touch, per sealed chunk


def test_restore_clamps_seal_boundary_to_cap(art, engine):
    """bugfix: restore must clamp the snapshot's seal boundary to THIS
    parser's max_seal_len — the cap is a promise, never exceeded, even for
    snapshots taken under a larger or uncapped config."""
    sp = StreamingParser(engine, first_seal_len=4)     # uncapped
    sp.append("ab" * 40)                               # leaves 4,8,16,32; tail 20
    assert sp._next_seal == 64 and sp._tail_len == 20
    capped = StreamingParser(engine, first_seal_len=4, max_seal_len=16)
    capped.restore(sp.snapshot())
    assert capped._next_seal <= 16                     # clamped, not verbatim
    assert capped._tail_len < capped._next_seal        # oversized tail resealed
    _assert_stream_equals_cold(capped, engine, art, "ab" * 40)
    pre = capped.n_sealed_chunks
    capped.append("ab" * 20)
    # every chunk sealed after the restore honors the cap
    assert all(len(c) <= 16 for c in capped._sealed_classes[pre:])
    _assert_stream_equals_cold(capped, engine, art, "ab" * 60)


def test_partial_eviction_counts_rebuilds_per_chunk(art, engine):
    """bugfix: rebuild accounting is per re-reached chunk — dropping two
    products then touching the stream reports TWO rebuilds, not one event."""
    sp = StreamingParser(engine, first_seal_len=4)
    sp.append("ab" * 14)                               # sealed leaves 4, 8, 16
    before = engine.obs.metrics.counter("stream_rebuilds_total").value
    for key, _, _ in sorted(sp.sealed_cache_entries(), key=lambda e: -e[1])[:2]:
        assert sp.drop_sealed_product(key) > 0
    _assert_stream_equals_cold(sp, engine, art, "ab" * 14)
    assert sp.rebuilds == 2
    assert (
        engine.obs.metrics.counter("stream_rebuilds_total").value == before + 2
    )


def test_absorb_product_rejects_boundary_crossing(engine):
    sp = StreamingParser(engine, first_seal_len=4)
    with pytest.raises(ValueError, match="seal boundary"):
        sp.absorb_product(np.zeros(9, dtype=np.int32), sp._eye)


def test_max_seal_len_caps_chunk_size(art, engine):
    sp = StreamingParser(engine, first_seal_len=4, max_seal_len=100)
    assert sp.max_seal_len == 64          # floored: the cap is never exceeded
    sp.append("ab" * 100)
    assert max(len(c) for c in sp._sealed_classes) <= 64
    _assert_stream_equals_cold(sp, engine, art, "ab" * 100)


def test_streaming_on_pallas_backend(art):
    """The same prefix cache runs on the Pallas kernels (interpret on CPU),
    bit-identical to the jnp cold parse."""
    eng = ParserEngine(art.matrices, backend="pallas")
    sp = StreamingParser(eng, first_seal_len=4)
    for piece in ("ab", "ab", "abab"):
        sp.append(piece)
    got = sp.current_slpf()
    cold = ParserEngine(art.matrices).parse("ababab" + "ab")
    assert np.array_equal(got.pack(), cold.pack())
    assert got.count_trees() == cold.count_trees()


def test_rejects_backend_with_prebuilt_engine(art, engine):
    with pytest.raises(ValueError, match="prebuilt ParserEngine"):
        StreamingParser(engine, backend="pallas")
