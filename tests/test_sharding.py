"""Sharding rules / logical axes / shape-aware specs (parallel/sharding.py)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import abstract_params, param_logical_axes
from repro.parallel.sharding import MeshRules, adapt_rules_for, divisible, spec_axes
from repro.train.step import map_with_logical, shape_aware_spec


def _amesh(shape, axes):
    # logical mesh structure on 1 real device: use abstract mesh
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # jax ≤ 0.4: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


@pytest.fixture(scope="module")
def mesh():
    return _amesh((16, 16), ("data", "model"))


def test_resolve_basic(mesh):
    r = MeshRules()
    assert r.resolve(("batch", None, "mlp"), mesh) == P("data", None, "model")
    # axis reuse within one tensor is dropped (specs must be disjoint)
    assert r.resolve(("mlp", "vocab"), mesh) == P("model")


def test_resolve_duplicate_axis_dropping_across_dims():
    """Mesh axes consumed by an earlier dim drop from later dims' rules."""
    r = MeshRules()
    m = _amesh((2, 4, 2), ("pod", "data", "model"))
    # batch takes ('pod','data'); chunk's rule names the same axes → replicated
    assert r.resolve(("batch", "chunk"), m) == P(("pod", "data"))
    # restricting batch to 'data' leaves 'pod' for the chunk dim — exactly the
    # distributed batched-parse composition (batch × chunk sharding)
    rb = r.with_overrides(batch="data")
    assert rb.resolve(("batch", "chunk"), m) == P("data", "pod")


def test_resolve_absent_axis_filtering_one_axis_mesh():
    """Axes the mesh lacks are filtered, not errors; all-gone → replicated."""
    r = MeshRules()
    m_data = _amesh((8,), ("data",))
    assert r.resolve(("chunk",), m_data) == P("data")     # 'pod' filtered away
    assert r.resolve_axes("chunk", m_data) == ("data",)
    m_model = _amesh((8,), ("model",))
    assert r.resolve(("chunk",), m_model) == P()          # nothing left
    assert r.resolve(("batch", "chunk"), m_model) == P()
    assert r.resolve_axes("chunk", m_model) == ()


def test_chunk_rule_across_mesh_shapes():
    """'chunk' → ('pod','data') resolves per-mesh without rule edits."""
    r = MeshRules()
    m3 = _amesh((2, 4, 2), ("pod", "data", "model"))
    assert r.resolve(("chunk",), m3) == P(("pod", "data"))
    assert r.resolve_axes("chunk", m3) == ("pod", "data")
    m2 = _amesh((4, 2), ("data", "model"))
    assert r.resolve(("chunk",), m2) == P("data")
    assert r.resolve_axes("chunk", m2) == ("data",)


def test_spec_axes_helper():
    spec = P("data", None, ("pod", "model"))
    assert spec_axes(spec, 0) == ("data",)
    assert spec_axes(spec, 1) == ()
    assert spec_axes(spec, 2) == ("pod", "model")
    assert spec_axes(spec, 7) == ()                       # past trimmed tail


def test_shape_aware_divisibility(mesh):
    r = MeshRules()
    # batch 1 cannot shard over 16 devices -> replicated
    assert shape_aware_spec((1, 128), ("batch", None), mesh, r) == P()
    assert shape_aware_spec((32, 128), ("batch", None), mesh, r) == P("data")
    # vocab 151655 % 16 != 0 -> replicated
    assert shape_aware_spec((151655, 896), ("vocab", None), mesh, r) == P()


def test_adapt_rules_per_arch(mesh):
    r = MeshRules()
    # phi3: padded q heads 48 shard; kv padded 12 does not divide 16 -> replicated
    phi3 = adapt_rules_for(get_config("phi3-medium-14b"), mesh, r)
    assert phi3.rules["heads"] == "model"
    assert phi3.rules["kv_heads"] is None
    # mixtral: 8 experts don't divide 16 -> expert-FFN TP instead of EP
    mix = adapt_rules_for(get_config("mixtral-8x22b"), mesh, r)
    assert mix.rules["experts"] is None
    assert mix.rules["expert_mlp"] == "model"
    # llama4: 16 experts divide 16 -> EP; expert hidden dim then unsharded
    l4 = adapt_rules_for(get_config("llama4-scout-17b-a16e"), mesh, r)
    assert l4.rules["experts"] == "model"
    assert l4.rules["expert_mlp"] is None


def test_param_specs_cover_all_leaves(mesh):
    """Every parameter leaf resolves to a valid, shape-divisible spec."""
    for arch in ("phi3-medium-14b", "mixtral-8x22b", "mamba2-2.7b", "zamba2-2.7b"):
        cfg = get_config(arch)
        rules = adapt_rules_for(cfg, mesh, MeshRules())
        abstract = abstract_params(cfg, tp=16)
        logical = param_logical_axes(cfg, tp=16)
        specs = map_with_logical(
            abstract, logical,
            lambda a, lg: shape_aware_spec(a.shape, lg, mesh, rules),
        )
        for a, s in zip(jax.tree.leaves(abstract), jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            for dim, entry in zip(a.shape, tuple(s)):
                if entry is None:
                    continue
                axes = (entry,) if isinstance(entry, str) else entry
                size = int(np.prod([mesh.shape[ax] for ax in axes]))
                assert dim % size == 0, (arch, a.shape, s)


def test_divisible_helper(mesh):
    assert divisible(32, mesh, "data")
    assert not divisible(33, mesh, "data")
    assert divisible(7, mesh, None)
