"""Checkpoint manager: atomicity, keep-k GC, bf16 round-trip, elastic load."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4), jnp.float32),
        "b16": jax.random.normal(k, (4,), jnp.bfloat16),
        "nested": {"step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    m.save(10, t, extra={"loss": 1.5})
    step, got, extra = m.restore(t)
    assert step == 10 and extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert str(a.dtype) == str(np.asarray(b).dtype) or np.asarray(b).dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    assert m.all_steps() == [3, 4]


def test_atomic_publish_ignores_tmp(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    m.save(5, _tree())
    # simulate a crash mid-write: stray tmp dir must be invisible to restore
    (tmp_path / "step_0000000009.tmp").mkdir()
    (tmp_path / "step_0000000009.tmp" / "garbage").write_text("x")
    assert m.latest_step() == 5
    step, _, _ = m.restore(_tree())
    assert step == 5


def test_restore_shape_mismatch_raises(tmp_path):
    m = CheckpointManager(tmp_path)
    m.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        m.restore({"w": jnp.zeros((8, 4))})


def test_async_save(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    m.async_save(3, _tree())
    m.wait()
    assert m.latest_step() == 3


def test_elastic_restore_onto_sharding(tmp_path):
    """Checkpoints are full arrays: restoring onto a (1-device) NamedSharding
    works regardless of the mesh that wrote them."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    m = CheckpointManager(tmp_path)
    t = _tree()
    m.save(2, t)
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    step, got, _ = m.restore(t, shardings=sh)
    assert step == 2
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


@pytest.mark.slow
def test_elastic_remesh_subprocess(tmp_path):
    """Fault-tolerance requirement: a checkpoint written on a (2,4) mesh
    restores onto a (4,2) mesh AND onto a 2-device subset mesh with identical
    values — elastic scaling across restarts (separate process: device count
    is locked at jax init)."""
    import subprocess, sys, os
    from pathlib import Path

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager

tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "emb": jnp.arange(32, dtype=jnp.bfloat16).reshape(16, 2)}}
from repro.launch.mesh import make_mesh_compat
mesh_a = make_mesh_compat((2, 4), ("data", "model"))
sh_a = {{"w": NamedSharding(mesh_a, P("data", "model")),
        "emb": NamedSharding(mesh_a, P("data", None))}}
placed = jax.tree.map(lambda t, s: jax.device_put(t, s), tree, sh_a)
m = CheckpointManager(r"{tmp_path}", keep=2)
m.save(1, placed)

# restore on a different topology
mesh_b = make_mesh_compat((4, 2), ("data", "model"))
sh_b = {{"w": NamedSharding(mesh_b, P("model", "data")),
        "emb": NamedSharding(mesh_b, P(None, "model"))}}
step, got, _ = m.restore(tree, shardings=sh_b)
for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

# restore on a smaller world (2 devices) — node-loss scenario
mesh_c = make_mesh_compat((2,), ("data",), devices=jax.devices()[:2])
sh_c = jax.tree.map(lambda _: NamedSharding(mesh_c, P("data")), tree)
step, got2, _ = m.restore(tree, shardings=sh_c)
for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got2)):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
print("ELASTIC-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC-OK" in out.stdout
