"""Observability layer (repro/obs): tracer, metrics registry, exporters.

Covers the acceptance bars of the observability PR:
  * span mechanics — contextvars parenting, retroactive ``emit`` against a
    pre-minted root id, disabled-tracer no-ops, the bounded ring buffer;
  * ``validate_span_tree`` structural guarantees (one root, parents resolve,
    child durations bounded) and its failure modes;
  * ``MetricsRegistry`` — the METRIC_CATALOG rot guard (unknown name is a
    ``KeyError`` at creation time), counter monotonicity, labeled series,
    Prometheus rendering;
  * the shared BENCH_*.json perf-trajectory schema;
  * facade integration — a traced ``Parser.parse`` leaves a complete span
    tree in the JSONL log, a trace ID survives the submit → ticket.result()
    round trip, ``Parser.stats()`` is a live registry view, and the hlo
    static cost attaches per compiled bucket;
  * the split queue-wait / compute latency windows wrap independently at
    ``LATENCY_WINDOW`` samples (regression: one window used to conflate
    wait with compute).
"""

import json

import pytest

import repro
from repro.obs import (
    METRIC_CATALOG,
    MetricsRegistry,
    ObsConfig,
    Tracer,
    prometheus_text,
    read_spans_jsonl,
    validate_bench_report,
    validate_metric_names,
    validate_span_dict,
    validate_span_tree,
    write_bench_json,
)
from repro.serve.parse_service import LATENCY_WINDOW, BucketStats

PATTERN = "(a|b|ab)+"


# ------------------------------------------------------------------ tracer


def test_span_nesting_parents_via_context():
    tr = Tracer(enabled=True)
    tid = tr.new_trace_id()
    with tr.span("parse.request", trace_id=tid) as root:
        with tr.span("phase.reach") as child:
            pass
    spans = tr.drain()
    assert [s.name for s in spans] == ["phase.reach", "parse.request"]
    reach, req = spans
    assert reach.trace_id == tid          # inherited from the open parent
    assert reach.parent_id == req.span_id
    assert req.parent_id is None
    assert req.duration_s >= reach.duration_s >= 0.0


def test_emit_accepts_preminted_root_id():
    # the service pattern: children are written mid-flight against a root id
    # minted at submit; the root span itself lands only at collection
    tr = Tracer(enabled=True)
    tid = tr.new_trace_id()
    root_id = tr._new_span_id()
    tr.emit("parse.queue_wait", t_start_s=1.0, duration_s=0.5,
            trace_id=tid, parent_id=root_id)
    tr.emit("parse.request", t_start_s=1.0, duration_s=2.0,
            trace_id=tid, span_id=root_id)
    dicts = [s.to_dict() for s in tr.drain()]
    for d in dicts:
        validate_span_dict(d)
    tree = validate_span_tree(dicts, tid)
    assert tree["root"]["span_id"] == root_id
    assert [c["name"] for c in tree["children"]] == ["parse.queue_wait"]


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    assert tr.new_trace_id() is None
    with tr.span("parse.request") as sp:
        sp.set_attr("ignored", 1)         # NullSpan: attribute sink
    assert tr.emit("x", t_start_s=0.0, duration_s=0.0) is None
    assert tr.drain() == []


def test_ring_buffer_bounded():
    tr = Tracer(enabled=True, max_spans=4)
    for i in range(10):
        tr.emit(f"s{i}", t_start_s=float(i), duration_s=0.0)
    names = [s.name for s in tr.drain()]
    assert names == ["s6", "s7", "s8", "s9"]


def test_validate_span_tree_failure_modes():
    def span(name, sid, parent=None):
        return {"name": name, "trace_id": "t", "span_id": sid,
                "parent_id": parent, "t_start_s": 0.0, "duration_s": 1.0,
                "attrs": {}}

    with pytest.raises(ValueError, match="no spans"):
        validate_span_tree([], "t")
    with pytest.raises(ValueError, match="2 roots"):
        validate_span_tree([span("a", "1"), span("b", "2")], "t")
    with pytest.raises(ValueError, match="not in trace"):
        validate_span_tree([span("a", "1"), span("b", "2", parent="missing")],
                           "t")
    # direct children summing past the root wall-clock is a broken tree
    bad = [span("root", "1"),
           span("c1", "2", parent="1"), span("c2", "3", parent="1")]
    with pytest.raises(ValueError, match="exceed root"):
        validate_span_tree(bad, "t")


# ----------------------------------------------------------------- metrics


def test_unknown_metric_name_is_keyerror():
    reg = MetricsRegistry()
    with pytest.raises(KeyError, match="unknown metric"):
        reg.counter("requests_totl")      # typo must fail loudly
    with pytest.raises(KeyError):
        reg.gauge("no_such_gauge")
    with pytest.raises(KeyError):
        reg.histogram("no_such_histogram")


def test_counter_monotonic_and_labeled_series():
    reg = MetricsRegistry()
    a = reg.counter("requests_total", service="parse")
    b = reg.counter("requests_total", service="stream")
    a.inc()
    a.inc(2)
    b.inc()
    assert a.value == 3 and b.value == 1  # distinct labeled series
    assert reg.counter("requests_total", service="parse") is a
    validate_metric_names(reg.snapshot())
    text = prometheus_text(reg.snapshot())
    assert 'repro_requests_total{service="parse"} 3.0' in text
    assert 'repro_requests_total{service="stream"} 1.0' in text


def test_validate_metric_names_rejects_unknown():
    with pytest.raises(KeyError):
        validate_metric_names(["requests_total", "made_up_metric"])
    validate_metric_names(METRIC_CATALOG)  # the catalog validates itself


# ------------------------------------------------------------ BENCH schema


def test_bench_json_roundtrip(tmp_path):
    out = write_bench_json(
        "unit", config={"quick": True}, metrics={"rows": [{"v": 1}]},
        out_dir=tmp_path, timestamp=123.0,
    )
    assert out.name == "BENCH_unit.json"
    d = json.loads(out.read_text())
    validate_bench_report(d)
    assert d["name"] == "unit" and d["timestamp"] == 123.0
    assert d["metrics"]["rows"] == [{"v": 1}]


def test_bench_schema_violations(tmp_path):
    good = {"name": "x", "timestamp": 1.0, "config": {}, "metrics": {}}
    validate_bench_report(good)
    for break_it in (
        lambda d: d.pop("metrics"),
        lambda d: d.update(extra=1),
        lambda d: d.update(name=""),
        lambda d: d.update(timestamp=0),
        lambda d: d.update(config=[]),
    ):
        d = dict(good)
        break_it(d)
        with pytest.raises(ValueError):
            validate_bench_report(d)
    with pytest.raises(TypeError):        # must be JSON round-trippable
        write_bench_json("bad", config={}, metrics={"x": object()},
                         out_dir=tmp_path)


# ------------------------------------------------- facade integration


@pytest.fixture()
def traced_parser(tmp_path):
    log = tmp_path / "spans.jsonl"
    p = repro.Parser(repro.ParserConfig(
        regex=PATTERN, n_chunks=4,
        obs={"enabled": True, "span_log": str(log)},
    ))
    yield p, log
    p.close()


def test_traced_parse_emits_complete_span_tree(traced_parser):
    p, log = traced_parser
    r = p.parse("abab" * 8)
    assert r.ok and r.trace_id is not None
    spans = read_spans_jsonl(log)
    for d in spans:
        validate_span_dict(d)
    tree = validate_span_tree(spans, r.trace_id)
    assert tree["root"]["name"] == "parse.request"
    child_names = {c["name"] for c in tree["children"]}
    assert {"phase.reach", "phase.join", "phase.build_merge",
            "phase.host_build"} <= child_names


def test_trace_id_survives_submit_roundtrip(traced_parser):
    p, log = traced_parser
    ticket = p.submit("abab" * 4)
    r = ticket.result()
    assert r.ok and r.trace_id is not None
    tree = validate_span_tree(read_spans_jsonl(log), r.trace_id)
    assert tree["root"]["name"] == "parse.request"
    names = {c["name"] for c in tree["children"]}
    assert {"parse.queue_wait", "parse.batch_compute"} <= names


def test_traced_route_bit_identical_to_fused(traced_parser):
    import numpy as np

    p, _ = traced_parser
    plain = repro.Parser(repro.ParserConfig(regex=PATTERN, n_chunks=4))
    text = "ab" * 37
    assert np.array_equal(p.parse(text).forest.pack(),
                          plain.parse(text).forest.pack())
    plain.close()


def test_stream_appends_form_span_trees(traced_parser):
    p, log = traced_parser
    with p.open_stream() as stream:
        stream.append("abab")
        stream.append("ab" * 10)
        assert stream.accepted
    spans = read_spans_jsonl(log)
    roots = [s for s in spans if s["name"] == "stream.append"]
    assert len(roots) == 2
    for root in roots:
        tree = validate_span_tree(spans, root["trace_id"])
        names = {c["name"] for c in tree["children"]}
        assert {"stream.append_queue_wait", "stream.append_compute"} <= names


def test_stats_is_live_registry_view(traced_parser):
    p, _ = traced_parser

    def served():
        snap = p.stats()["metrics"]
        return sum(s["value"] for s in snap.get("requests_total", []))

    p.parse("abab")
    first = served()
    p.parse("abab")
    p.submit("abab").result()
    second = served()
    assert second == first + 2            # counters only ever move up
    validate_metric_names(p.stats()["metrics"])


def test_stats_attaches_hlo_static_cost(traced_parser):
    p, _ = traced_parser
    p.parse("abab" * 8)
    hlo = p.stats()["hlo"]
    assert hlo, "traced parser with hlo=True must report static cost"
    for bucket, phases in hlo.items():
        assert set(phases) == {"reach", "join", "build_merge", "total"}
        assert phases["total"]["flops"] > 0
        assert phases["total"]["bytes"] > 0


def test_hlo_off_by_config(tmp_path):
    p = repro.Parser(repro.ParserConfig(
        regex=PATTERN, n_chunks=4,
        obs=ObsConfig(enabled=True, hlo=False),
    ))
    p.parse("abab")
    assert p.stats()["hlo"] is None
    p.close()


# ------------------------------------------- latency window split


def test_bucket_stats_windows_wrap_independently():
    s = BucketStats()
    # 100 fast-queue samples, then LATENCY_WINDOW + 100 slow-queue samples:
    # once wrapped, the window must contain ONLY the recent regime
    for _ in range(100):
        s.record(0.2, queue_s=0.0, compute_s=0.2)
    for _ in range(LATENCY_WINDOW + 100):
        s.record(1.5, queue_s=1.0, compute_s=0.5)
    assert len(s.window) == LATENCY_WINDOW
    assert len(s.queue_window) == LATENCY_WINDOW
    assert len(s.compute_window) == LATENCY_WINDOW
    d = s.as_dict()
    assert d["p50_queue_s"] == d["p99_queue_s"] == 1.0
    assert d["p50_compute_s"] == d["p99_compute_s"] == 0.5
    assert d["p50_latency_s"] == d["p99_latency_s"] == 1.5
    # lifetime aggregates still see every sample
    assert d["served"] == LATENCY_WINDOW + 200
    assert d["max_latency_s"] == 1.5


def test_bucket_stats_single_positional_record():
    # pre-split call sites record latency only; the split windows stay empty
    s = BucketStats()
    s.record(5.0)
    d = s.as_dict()
    assert d["p99_latency_s"] == 5.0
    assert d["p50_queue_s"] == 0.0 and d["p50_compute_s"] == 0.0


def test_window_quantile_nearest_rank():
    # nearest-rank: a 2-sample window's p99 is its slowest OBSERVED sample,
    # not an interpolated value just below it (the admission predictor must
    # not under-report)
    s = BucketStats()
    s.record(0.1)
    s.record(0.5)
    assert s.latency_quantile_s(99.0) == 0.5
