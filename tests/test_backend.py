"""Backend switch (jnp / pallas-interpret) + batched bucketed front-end."""

import numpy as np
import pytest

from repro.core.backend import (
    JnpBackend,
    PackedBackend,
    PallasBackend,
    SparseBackend,
    get_backend,
    join_entries,
)
from repro.core.engine import ParserEngine, _entries_from_products
from repro.core.reference import ParallelArtifacts, parse_parallel_reference
from repro.core.serial import parse_serial_matrix

BACKENDS = ["jnp", "pallas", "packed", "sparse"]

TEXTS = ["", "b", "ba", "abab", "ababab", "a" * 23, "ab" * 40]


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate("(a|b|ab)+")


@pytest.fixture(scope="module", params=BACKENDS)
def engine(art, request):
    return ParserEngine(art.matrices, backend=request.param)


def test_get_backend_resolution():
    assert isinstance(get_backend("jnp"), JnpBackend)
    assert isinstance(get_backend("pallas"), PallasBackend)
    assert isinstance(get_backend("packed"), PackedBackend)
    assert isinstance(get_backend("sparse"), SparseBackend)
    b = PallasBackend(interpret=True)
    assert get_backend(b) is b
    with pytest.raises(ValueError, match="unknown parse backend"):
        get_backend("cuda")


def test_join_is_the_engine_join():
    """The engine's join phase IS the shared scan-based implementation."""
    assert _entries_from_products is join_entries


@pytest.mark.parametrize("c", [1, 2, 4, 8])
def test_backend_equivalence_vs_reference(art, engine, c):
    """Identical SLPF columns vs both oracles (core/reference + core/serial)."""
    for text in TEXTS:
        got = engine.parse(text, n_chunks=c)
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(ref.columns, got.columns), (engine.backend.name, text, c)
        paper = parse_parallel_reference(art, text, c=min(c, max(1, len(text))))
        assert np.array_equal(paper.columns, got.columns), (engine.backend.name, text, c)


def test_backends_agree_bit_exactly(art):
    engines = [ParserEngine(art.matrices, backend=b) for b in BACKENDS]
    for text in TEXTS:
        outs = [e.parse(text, n_chunks=4) for e in engines]
        for e, got in zip(engines[1:], outs[1:]):
            assert np.array_equal(outs[0].columns, got.columns), (
                e.backend.name, text,
            )


def test_parse_batch_matches_per_text_parse(art, engine):
    """Mixed-length batch output is exactly the per-text parse output."""
    got = engine.parse_batch(TEXTS, n_chunks=4)
    assert len(got) == len(TEXTS)
    for text, slpf in zip(TEXTS, got):
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(ref.columns, slpf.columns), (engine.backend.name, text)
        single = engine.parse(text, n_chunks=4)
        assert np.array_equal(single.columns, slpf.columns), (engine.backend.name, text)


@pytest.mark.parametrize("backend", BACKENDS)
def test_parse_batch_compiles_one_program_per_bucket(art, backend):
    """Mixed lengths hit a handful of static shapes, not one jit per length."""
    eng = ParserEngine(art.matrices, backend=backend)
    texts = ["a" * n for n in (0, 1, 2, 5, 9, 17, 23, 31)]  # one (c=4, k=8) bucket
    eng.parse_batch(texts, n_chunks=4)
    assert eng.compile_count == 1
    # Same bucket + same padded batch-slot count → zero recompilation.
    eng.parse_batch(["ab" * 3, "b" * 30] * 4, n_chunks=4)
    assert eng.compile_count == 1
    # A genuinely new bucket (k=16) compiles exactly one more program.
    eng.parse_batch(["a" * 60], n_chunks=4)
    assert eng.compile_count == 2


def test_single_parse_reuses_bucketed_program(art):
    """parse() no longer re-jits per text length inside a bucket."""
    eng = ParserEngine(art.matrices)
    for n in (1, 3, 7, 12, 20, 31):
        eng.parse("a" * n, n_chunks=4)
    assert eng.compile_count == 1


def test_empty_text_routes_through_bucketed_path(art, engine):
    """Zero-length requests use the same padded/jitted program (no special
    case) and pin the seed's SLPF output: the single column I ∧ F."""
    slpf = engine.parse("", n_chunks=8)
    expected = (art.matrices.I & art.matrices.F)[None, :]
    assert np.array_equal(slpf.columns, expected)
    assert slpf.classes.shape == (0,)
    ref = parse_serial_matrix(art.matrices, "")
    assert np.array_equal(slpf.columns, ref.columns)
    # and through the batch front-end, mixed with non-empty texts
    outs = engine.parse_batch(["", "abab", ""], n_chunks=8)
    assert np.array_equal(outs[0].columns, expected)
    assert np.array_equal(outs[2].columns, expected)


def test_pallas_engine_reaches_kernels(art, monkeypatch):
    """ParserEngine(backend="pallas") actually invokes kernels/reach.py and
    kernels/build.py (not the jnp fallback)."""
    import repro.kernels.build as kbuild
    import repro.kernels.reach as kreach

    calls = []
    real_reach = kreach.reach_chunk_product
    real_build = kbuild.build_merge_chunk
    monkeypatch.setattr(
        kreach, "reach_chunk_product",
        lambda *a, **k: calls.append("reach") or real_reach(*a, **k),
    )
    monkeypatch.setattr(
        kbuild, "build_merge_chunk",
        lambda *a, **k: calls.append("build") or real_build(*a, **k),
    )
    eng = ParserEngine(art.matrices, backend="pallas")
    got = eng.parse("abab", n_chunks=2)
    ref = parse_serial_matrix(art.matrices, "abab")
    assert np.array_equal(ref.columns, got.columns)
    assert "reach" in calls and "build" in calls


def test_pallas_lane_pad_floor(art):
    """The pallas backend forces the kernels' 128-lane MXU alignment."""
    eng = ParserEngine(art.matrices, backend="pallas", lane_pad=32)
    assert eng.tables.ell_pad % 128 == 0
