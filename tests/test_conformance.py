"""Cross-backend conformance harness: every registered backend, every route.

One check, quantified over the whole system: for a corpus of REs (fixed +
REgen-random; hypothesis-driven when installed, a fixed seed corpus always)
and adversarial texts (empty, single-char, seal-boundary lengths, corrupted /
non-matching, long valid), EVERY backend in the ``core/backend.py`` registry
must produce bit-identical SLPFs across all six execution routes:

  fused        ``ParserEngine.parse`` (one jitted three-phase program)
  phase-split  ``ParserEngine.phases`` reach → join → build&merge run as
               separate programs over first-class boundary arrays
  streaming    ``core/stream.py`` incremental appends + ``current_slpf``
  edit         ``core/stream.py`` mid-text splices (the product segment
               tree) repairing a corrupted stream into the same text
  mesh         ``ParserEngine(mesh=...)`` (1-device mesh: the shard_map
               programs with the product-stack all-gather resident)
  facade       ``repro.Parser`` (repro/api.py) — the public API path through
               ``submit``/``ParseTicket`` and the bucket-batched service

and the SLPF's tree set must equal ``tests/oracle.py``'s brute-force LST
enumeration (checked on oracle-sized texts; longer texts are anchored to the
serial matrix parser, itself oracle-validated in test_serial.py).

The registry is enumerated at runtime — a newly registered backend joins the
harness with no test edits.
"""

import zlib

import numpy as np
import pytest

import jax.numpy as jnp

from oracle import enumerate_lsts
from repro.api import Parser, ParserConfig
from repro.core.backend import _BACKENDS
from repro.core.engine import ParserEngine
from repro.core.numbering import number_regex
from repro.core.reference import ParallelArtifacts
from repro.core.segments import compute_segments
from repro.core.serial import parse_serial_matrix
from repro.core.stream import StreamingParser
from repro.data.regen import random_regex, sample_string
from repro.launch.mesh import make_parse_mesh

BACKENDS = sorted(_BACKENDS)
N_CHUNKS = 4
FIRST_SEAL = 4
ORACLE_MAX_LEN = 6          # tree-set compare vs the DFS oracle up to here

FIXED_PATTERNS = ["(ab|a)*", "(a|b|ab)+", "x(yz|y)*z?"]
RANDOM_SEEDS = [11, 23, 47]
CORPUS = FIXED_PATTERNS + [f"seed:{s}" for s in RANDOM_SEEDS]

_cache = {}


def _artifacts(key):
    """(art, numbered AST or pattern, a deterministic rng) for one corpus key."""
    if key not in _cache:
        if key.startswith("seed:"):
            rng = np.random.Generator(np.random.Philox(int(key[5:])))
            ast = random_regex(7, rng)
            numbered = number_regex(ast)
            art = ParallelArtifacts.generate(compute_segments(numbered))
            _cache[key] = (art, numbered, ast)
        else:
            numbered = number_regex(key)
            art = ParallelArtifacts.generate(key)
            _cache[key] = (art, numbered, None)
    return _cache[key]


def _engine(key, backend, mesh=False):
    ck = (key, backend, mesh)
    if ck not in _cache:
        art, _, _ = _artifacts(key)
        _cache[ck] = ParserEngine(
            art.matrices,
            backend=backend,
            mesh=make_parse_mesh() if mesh else None,
        )
    return _cache[ck]


def _facade(key, backend):
    """The public-API route: a ``repro.Parser`` over the same matrices."""
    ck = ("facade", key, backend)
    if ck not in _cache:
        art, _, _ = _artifacts(key)
        _cache[ck] = Parser.from_matrices(
            art.matrices,
            ParserConfig(regex=f"<conformance:{key}>", backend=backend,
                         n_chunks=N_CHUNKS),
        )
    return _cache[ck]


def _adversarial_texts(key):
    """Deterministic per-RE text set covering the adversarial classes."""
    _, _, ast = _artifacts(key)
    rng = np.random.Generator(np.random.Philox(zlib.crc32(key.encode())))
    if ast is not None:
        sample = lambda: sample_string(ast, rng, max_rep=3)
    else:
        art, _, _ = _artifacts(key)
        sample = lambda: _sample_from_pattern(key, rng)
    long = b""
    while len(long) < 24:
        long += sample()
    texts = [
        b"",                          # empty
        long[:1],                     # single char (valid prefix byte)
        b"~",                         # single char outside every alphabet
        long[:FIRST_SEAL],            # exactly one seal boundary
        long[: 2 * FIRST_SEAL],       # second boundary
        long[: 2 * FIRST_SEAL + 1],   # one past it
        long[:6],                     # oracle-sized
        long,                         # long valid-ish
        long[: len(long) // 2] + b"~" + long[len(long) // 2 :],  # corrupted
    ]
    return list(dict.fromkeys(texts))


def _sample_from_pattern(pattern, rng):
    from repro.core import regex as rx

    return sample_string(rx.parse_regex(pattern), rng, max_rep=3)


def _tree_set(slpf):
    return {
        tuple(sid for q in path for sid in slpf.table.segs[q])
        for path in slpf.iter_trees()
    }


def _phase_split_parse(eng, text):
    """The phase-boundary route: run reach/join/build&merge as separate
    programs over first-class boundary arrays, assemble like the engine."""
    classes = eng.classes_of_text(text)
    c, k = eng.bucket_shape(len(classes), N_CHUNKS)
    chunks = jnp.asarray(eng._pad_to(classes, c, k))
    t = eng.tables
    P = eng.phases.reach(t.N, chunks)
    Jf, Jb, col0p = eng.phases.join(P, t.I, t.F)
    cols = eng.phases.build_merge(t.N, chunks, Jf, Jb)
    return eng._assemble(np.asarray(col0p), np.asarray(cols), classes)


def _edit_parse(eng, text):
    """The edit route: append a CORRUPTED stream, then repair it with
    splices — junk deleted mid-text, the first char deleted and re-inserted
    — so the final prefix equals ``text`` only through the segment tree's
    edit path (delete, insert, boundary-crossing splices all exercised)."""
    classes = eng.classes_of_text(text)
    sp = StreamingParser(
        eng, first_seal_len=FIRST_SEAL, max_seal_len=4 * FIRST_SEAL
    )
    junk = np.full(3, eng.tables.pad_class, dtype=np.int32)
    mid = len(classes) // 2
    sp.append(np.concatenate([classes[:mid], junk]))
    sp.append(classes[mid:])
    sp.edit(mid, mid + 3, np.zeros(0, dtype=np.int32))   # delete the junk
    if len(classes):
        sp.edit(0, 1, np.zeros(0, dtype=np.int32))       # drop the first char…
        sp.edit(0, 0, classes[:1])                       # …and splice it back
    return sp


def _stream_parse(eng, text):
    sp = StreamingParser(eng, first_seal_len=FIRST_SEAL)
    classes = eng.classes_of_text(text)
    step, i = 1, 0
    while i < len(classes):                 # varying piece sizes: 1, 2, 3, …
        sp.append(classes[i : i + step])
        i += step
        step = min(step + 1, 7)
    return sp.current_slpf()


def _check_text(key, backend, text, mesh_engine=None):
    art, numbered, _ = _artifacts(key)
    eng = _engine(key, backend)
    fused = eng.parse(text, n_chunks=N_CHUNKS)

    # anchor: serial matrix parser (oracle-validated) on every text
    ref = parse_serial_matrix(art.matrices, text)
    assert np.array_equal(fused.columns, ref.columns), (key, backend, text)

    # brute-force LST oracle on oracle-sized texts
    if len(text) <= ORACLE_MAX_LEN:
        oracle = {tuple(l) for l in enumerate_lsts(numbered, text)}
        assert fused.count_trees() == len(oracle), (key, backend, text)
        assert _tree_set(fused) == oracle, (key, backend, text)

    # phase-split and streaming routes, bit-identical to fused
    split = _phase_split_parse(eng, text)
    assert np.array_equal(split.pack(), fused.pack()), (key, backend, text)
    streamed = _stream_parse(eng, text)
    assert np.array_equal(streamed.pack(), fused.pack()), (key, backend, text)

    # edit route: splices repairing a corrupted stream land bit-identical
    edited = _edit_parse(eng, text)
    assert np.array_equal(
        edited.current_slpf().pack(), fused.pack()
    ), (key, backend, text)
    assert edited.accepted == fused.accepted, (key, backend, text)

    # facade route: the public repro.Parser API (ticketed service path)
    res = _facade(key, backend).parse(text)
    assert np.array_equal(res.forest.pack(), fused.pack()), (key, backend, text)
    assert res.ok == fused.accepted and res.backend == backend

    # mesh route (1-device): same program placed through shard_map
    if mesh_engine is not None:
        meshed = mesh_engine.parse(text, n_chunks=N_CHUNKS)
        assert np.array_equal(meshed.pack(), fused.pack()), (key, backend, text)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("key", CORPUS)
def test_backend_conformance_corpus(key, backend):
    """Fixed seed corpus — always runs (hypothesis-free CI images)."""
    for text in _adversarial_texts(key):
        _check_text(key, backend, text)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_conformance_mesh_route(backend):
    """The 1-device-mesh route on a corpus slice (shard_map programs are the
    expensive part — one pattern exercises the placement for each backend)."""
    key = CORPUS[1]
    mesh_engine = _engine(key, backend, mesh=True)
    for text in _adversarial_texts(key)[:6]:
        _check_text(key, backend, text, mesh_engine=mesh_engine)


def test_backend_conformance_property():
    """hypothesis-driven REs and texts on top of the fixed corpus."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.sampled_from(RANDOM_SEEDS), st.integers(0, 10_000))
    @hyp.settings(max_examples=10, deadline=None)
    def run(re_seed, text_seed):
        key = f"seed:{re_seed}"
        _, _, ast = _artifacts(key)
        rng = np.random.Generator(np.random.Philox(text_seed))
        text = sample_string(ast, rng, max_rep=3)[:16]
        if text_seed % 3 == 0 and text:
            pos = text_seed % len(text)
            text = text[:pos] + b"~" + text[pos + 1 :]   # corrupt one byte
        for backend in BACKENDS:
            _check_text(key, backend, text)

    run()


def test_registry_contains_all_three_backends():
    """The harness quantifies over the registry — pin the expected floor."""
    assert {"jnp", "pallas", "packed"} <= set(BACKENDS)
