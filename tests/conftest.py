import os
import sys
from pathlib import Path

# Tests see the REAL device count (1 CPU); only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).parent))          # tests/oracle.py
sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

# hypothesis is an optional test dependency (offline CI images lack it);
# property-based tests importorskip it individually.
try:
    from hypothesis import settings
except ModuleNotFoundError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
