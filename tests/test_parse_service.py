"""Request-level batched parse service (serve/parse_service.py)."""

import numpy as np
import pytest

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.serve.parse_service import ParseRequest, ParseService


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate("(a|b|ab)+")


def test_service_serves_mixed_lengths_exactly(art):
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    texts = ["abab", "", "b", "a" * 23, "ab" * 40, "ba", "ababab"]
    rids = [svc.submit(t) for t in texts]
    done = svc.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    by_rid = {r.rid: r for r in done}
    for rid, text in zip(rids, texts):
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(by_rid[rid].slpf.columns, ref.columns), text


def test_service_batches_same_bucket_requests(art):
    svc = ParseService(art.matrices, max_batch=8, n_chunks=4)
    for _ in range(8):
        svc.submit("abab")                # all land in one (c, k) bucket
    svc.run()
    assert svc.batches_run == 1          # one device batch, not 8


def test_service_respects_max_batch_and_fifo(art):
    svc = ParseService(art.matrices, max_batch=2, n_chunks=4)
    for i in range(5):
        svc.submit("ab" * (i + 1))       # lengths 2..10 — same k=8 bucket
    done = svc.run()
    assert svc.batches_run == 3          # ceil(5 / 2)
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]   # FIFO completion


def test_service_steady_state_never_recompiles(art):
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    for t in ["abab", "ba", "ababab", "b"]:
        svc.submit(t)
    svc.run()
    warm = svc.compile_count
    for _ in range(3):
        for t in ["ab", "abba" * 2, "a" * 20, "b"]:
            svc.submit(t)
        svc.run()
    assert svc.compile_count == warm     # same buckets → same programs


def test_service_rejects_backend_with_prebuilt_engine(art):
    """backend= must not be silently ignored when an engine is passed."""
    eng = ParserEngine(art.matrices)
    with pytest.raises(ValueError, match="prebuilt ParserEngine"):
        ParseService(eng, backend="pallas")


def test_bucket_cached_at_submit_not_per_step(art, monkeypatch):
    """The service buckets each request once (at submit); scheduling never
    recomputes bucket_shape for queued requests (was O(queue) per step)."""
    svc = ParseService(art.matrices, max_batch=2, n_chunks=4)
    texts = ["ab" * (i + 1) for i in range(6)]
    for t in texts:
        svc.submit(t)
    queued = list(svc._queue)
    assert all(r.bucket is not None for r in queued)

    def boom(n, c):
        raise AssertionError("bucket_shape recomputed during scheduling")

    monkeypatch.setattr(svc.engine, "bucket_shape", boom)
    for req in queued:
        svc._bucket_of(req)              # served from the submit-time cache
    monkeypatch.undo()                   # engine.parse_batch buckets its batch
    done = svc.run()
    assert len(done) == len(texts)


def test_service_stats(art):
    svc = ParseService(art.matrices, max_batch=2, n_chunks=4)
    for t in ["abab", "ba", "a" * 60, "ababab"]:   # two buckets
        svc.submit(t)
    assert svc.stats["pending"] == 4
    assert svc.stats["peak_queue_depth"] == 4
    done = svc.run()
    st = svc.stats
    assert st["backend"] == "jnp"        # which phase backend is live
    assert st["pending"] == 0
    assert st["batches_run"] == svc.batches_run >= 2
    assert st["compile_count"] == svc.compile_count
    served = sum(v["served"] for v in st["buckets"].values())
    assert served == 4
    assert sum(v["batches"] for v in st["buckets"].values()) == svc.batches_run
    for v in st["buckets"].values():
        assert 0.0 <= v["mean_latency_s"] <= v["max_latency_s"]
        # p50/p99 over the sorted sample window — the SLO-item observables
        assert 0.0 <= v["p50_latency_s"] <= v["p99_latency_s"] <= v["max_latency_s"]
    for req in done:
        assert req.latency_s is not None and req.latency_s >= 0.0
        assert req.bucket is not None


def test_service_accepts_prebuilt_engine(art):
    eng = ParserEngine(art.matrices, backend="pallas")
    svc = ParseService(eng, max_batch=2, n_chunks=2)
    assert svc.engine is eng
    rid = svc.submit("abab")
    (req,) = svc.run()
    assert req.rid == rid and req.done
    ref = parse_serial_matrix(art.matrices, "abab")
    assert np.array_equal(req.slpf.columns, ref.columns)


# ----------------------------------------------------- cancellation (flagged)


def test_cancel_never_burns_slot_or_sample(art, monkeypatch):
    """Regression: a cancelled request must not occupy a batch slot nor
    record a latency sample — the scheduler purges flagged rows before
    packing (previously a cancel racing batch selection could still ride)."""
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    reqs = [svc.submit_request("abab") for _ in range(3)]
    rows_seen = []
    orig = svc.engine.parse_batch

    def spy(classes_list, n_chunks=None):
        rows_seen.append(len(classes_list))
        return orig(classes_list, n_chunks=n_chunks)

    monkeypatch.setattr(svc.engine, "parse_batch", spy)
    assert svc.cancel(reqs[1].rid) is True
    assert svc.cancel(reqs[1].rid) is False      # idempotent
    assert svc.pending == 2
    assert svc.step() is True
    assert rows_seen == [2]                      # the cancelled row never packed
    assert reqs[0].done and reqs[2].done and not reqs[1].done
    assert reqs[1].cancelled and reqs[1].latency_s is None
    bucket = reqs[0].bucket
    assert svc._buckets[bucket].served == 2      # no sample for the cancel
    assert svc.cancel(reqs[0].rid) is False      # already served


def test_cancel_lands_while_batch_in_flight(art, monkeypatch):
    """The ISSUE scenario: a cancel arriving while ANOTHER bucket's batch is
    executing on device — the flagged request must be skipped afterwards,
    burning no slot and leaving no latency sample."""
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    short = svc.submit_request("abab")
    long = svc.submit_request("ab" * 40)         # a different (c, k) bucket
    assert short.bucket != long.bucket
    orig = ParseService._execute

    def execute_and_cancel(bucket, batch):
        assert svc.cancel(long.rid) is True      # lands mid-flight
        return orig(svc, bucket, batch)

    monkeypatch.setattr(svc, "_execute", execute_and_cancel)
    assert svc.step() is True                    # serves the short bucket
    assert short.done and not long.done and long.cancelled
    assert svc.batches_run == 1
    assert svc.pending == 0
    assert svc.step() is False                   # nothing live remains
    assert not svc._queue                        # flagged residue purged
    assert svc._buckets[long.bucket].served == 0


# ------------------------------------------------------------- weighted-fair


def test_weighted_fair_exact_serve_order(art):
    """Two tenants, weight 1 vs 2, equal-length texts, max_batch=1: the WFQ
    vtime order is deterministic — the weight-2 tenant is served twice as
    often (name-ordered tie-break)."""
    svc = ParseService(art.matrices, max_batch=1, n_chunks=4)
    svc.register_tenant("a", weight=1.0)
    svc.register_tenant("b", weight=2.0)
    for _ in range(4):
        svc.submit("abab", tenant="a")
    for _ in range(4):
        svc.submit("abab", tenant="b")
    done = svc.run()
    assert [r.tenant for r in done] == ["a", "b", "b", "a", "b", "b", "a", "a"]


def test_weighted_fair_no_starvation(art):
    """A hot tenant's backlog cannot starve a light tenant: the light
    tenant's single request is served next step, not after the flood."""
    svc = ParseService(art.matrices, max_batch=1, n_chunks=4)
    svc.register_tenant("hot", weight=1.0)
    svc.register_tenant("light", weight=1.0)
    for _ in range(6):
        svc.submit("abab", tenant="hot")
    svc.step()                                   # hot advances its vtime
    svc.submit("abab", tenant="light")
    svc.step()
    st = svc.stats
    assert st["tenants"]["light"]["served"] == 1  # served immediately
    assert st["tenants"]["hot"]["served"] == 1
    assert st["tenants"]["hot"]["pending"] == 5


def test_same_bucket_riders_fill_across_tenants(art):
    """Batch head comes from the fair pick; same-bucket requests from other
    tenants ride along in the same device batch (each charging itself)."""
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    svc.register_tenant("a", weight=1.0)
    svc.register_tenant("b", weight=1.0)
    svc.submit("abab", tenant="a")
    svc.submit("baba", tenant="b")
    svc.submit("abba", tenant="a")
    assert svc.step() is True
    assert svc.batches_run == 1                  # one batch served all three
    st = svc.stats
    assert st["tenants"]["a"]["served"] == 2
    assert st["tenants"]["b"]["served"] == 1
    assert st["tenants"]["b"]["vtime"] > 0.0     # riders charge themselves


def test_tenant_budget_is_private(art):
    from repro.errors import BudgetExceeded

    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    svc.register_tenant("vip", weight=1.0, max_pending=1)
    svc.submit("abab", tenant="vip")
    with pytest.raises(BudgetExceeded, match="vip"):
        svc.submit("abab", tenant="vip")
    svc.submit("abab")                           # other tenants unaffected
    st = svc.stats
    assert st["tenants"]["vip"]["rejects"] == 1
    done = svc.run()
    assert len(done) == 2
