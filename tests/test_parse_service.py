"""Request-level batched parse service (serve/parse_service.py)."""

import numpy as np
import pytest

from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.serve.parse_service import ParseRequest, ParseService


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate("(a|b|ab)+")


def test_service_serves_mixed_lengths_exactly(art):
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    texts = ["abab", "", "b", "a" * 23, "ab" * 40, "ba", "ababab"]
    rids = [svc.submit(t) for t in texts]
    done = svc.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    by_rid = {r.rid: r for r in done}
    for rid, text in zip(rids, texts):
        ref = parse_serial_matrix(art.matrices, text)
        assert np.array_equal(by_rid[rid].slpf.columns, ref.columns), text


def test_service_batches_same_bucket_requests(art):
    svc = ParseService(art.matrices, max_batch=8, n_chunks=4)
    for _ in range(8):
        svc.submit("abab")                # all land in one (c, k) bucket
    svc.run()
    assert svc.batches_run == 1          # one device batch, not 8


def test_service_respects_max_batch_and_fifo(art):
    svc = ParseService(art.matrices, max_batch=2, n_chunks=4)
    for i in range(5):
        svc.submit("ab" * (i + 1))       # lengths 2..10 — same k=8 bucket
    done = svc.run()
    assert svc.batches_run == 3          # ceil(5 / 2)
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]   # FIFO completion


def test_service_steady_state_never_recompiles(art):
    svc = ParseService(art.matrices, max_batch=4, n_chunks=4)
    for t in ["abab", "ba", "ababab", "b"]:
        svc.submit(t)
    svc.run()
    warm = svc.compile_count
    for _ in range(3):
        for t in ["ab", "abba" * 2, "a" * 20, "b"]:
            svc.submit(t)
        svc.run()
    assert svc.compile_count == warm     # same buckets → same programs


def test_service_rejects_backend_with_prebuilt_engine(art):
    """backend= must not be silently ignored when an engine is passed."""
    eng = ParserEngine(art.matrices)
    with pytest.raises(ValueError, match="prebuilt ParserEngine"):
        ParseService(eng, backend="pallas")


def test_bucket_cached_at_submit_not_per_step(art, monkeypatch):
    """The service buckets each request once (at submit); scheduling never
    recomputes bucket_shape for queued requests (was O(queue) per step)."""
    svc = ParseService(art.matrices, max_batch=2, n_chunks=4)
    texts = ["ab" * (i + 1) for i in range(6)]
    for t in texts:
        svc.submit(t)
    queued = list(svc._queue)
    assert all(r.bucket is not None for r in queued)

    def boom(n, c):
        raise AssertionError("bucket_shape recomputed during scheduling")

    monkeypatch.setattr(svc.engine, "bucket_shape", boom)
    for req in queued:
        svc._bucket_of(req)              # served from the submit-time cache
    monkeypatch.undo()                   # engine.parse_batch buckets its batch
    done = svc.run()
    assert len(done) == len(texts)


def test_service_stats(art):
    svc = ParseService(art.matrices, max_batch=2, n_chunks=4)
    for t in ["abab", "ba", "a" * 60, "ababab"]:   # two buckets
        svc.submit(t)
    assert svc.stats["pending"] == 4
    assert svc.stats["peak_queue_depth"] == 4
    done = svc.run()
    st = svc.stats
    assert st["backend"] == "jnp"        # which phase backend is live
    assert st["pending"] == 0
    assert st["batches_run"] == svc.batches_run >= 2
    assert st["compile_count"] == svc.compile_count
    served = sum(v["served"] for v in st["buckets"].values())
    assert served == 4
    assert sum(v["batches"] for v in st["buckets"].values()) == svc.batches_run
    for v in st["buckets"].values():
        assert 0.0 <= v["mean_latency_s"] <= v["max_latency_s"]
        # p50/p99 over the sorted sample window — the SLO-item observables
        assert 0.0 <= v["p50_latency_s"] <= v["p99_latency_s"] <= v["max_latency_s"]
    for req in done:
        assert req.latency_s is not None and req.latency_s >= 0.0
        assert req.bucket is not None


def test_service_accepts_prebuilt_engine(art):
    eng = ParserEngine(art.matrices, backend="pallas")
    svc = ParseService(eng, max_batch=2, n_chunks=2)
    assert svc.engine is eng
    rid = svc.submit("abab")
    (req,) = svc.run()
    assert req.rid == rid and req.done
    ref = parse_serial_matrix(art.matrices, "abab")
    assert np.array_equal(req.slpf.columns, ref.columns)
