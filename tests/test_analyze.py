"""repro.analyze: static diagnostics, auto backend, admission policy, lint.

Four clusters:

  * pattern leg — ambiguity verdicts on hand-written + REgen fixtures, the
    static feasible-start width bounds validated against the widths the
    sparse backend actually observes (bound >= observed; the carried pow2
    bucket is the tightest one over the depth-1 bound), density/cost sanity,
    and the hardcoded lane-pad mirror staying true to ``core/backend.py``;
  * facade policy — ``analyze="off"|"warn"|"strict"`` at ``Parser``
    construction and ``ParserFleet.add``, the typed
    ``PathologicalPatternError``, the service-level pattern guard, and
    ``stats()["analysis"]``;
  * ``backend="auto"`` — resolves to a registered backend and parses
    bit-identically to that backend named explicitly, solo and in a fleet;
  * program leg — every registered backend's compiled phase programs lint
    clean; seeded f64 / host-callback / dynamic-shape violations are caught.
"""

import subprocess
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parents[1] / "src"))

import repro
from repro.analyze import (
    AnalysisReport,
    analyze_matrices,
    analyze_pattern,
    backend_cost_model,
    choose_backend,
    feasible_width_bounds,
    lint_engine,
    lint_hlo_text,
    lint_jaxpr,
    lint_program,
    sparse_width_bucket,
)
from repro.analyze.pattern import _MIN_LANE_PAD
from repro.core.backend import _BACKENDS, get_backend
from repro.core.matrices import build_matrices, feasible_start_widths
from repro.core.numbering import number_regex
from repro.core.segments import compute_segments
from repro.data.regen import random_regex, sample_string
from repro.errors import ParseError, PathologicalPatternError

PATHOLOGICAL = ["(a*)*", "(a?)+", "(a*)+", "((a|b)*)*"]
# "x(yz|y)*z?" is genuinely ambiguous: "xyz" parses as x·(yz) or x·(y)·z
FINITE = ["a|a", "(a|b|ab)+", "(ab|ba|abba)+", "x(yz|y)*z?"]
UNAMBIGUOUS = ["abc", "a*b", "(ab|a)*", "(a|b)*abb"]
WIDTH_SEEDS = [11, 23, 47, 101]


# ------------------------------------------------------------ pattern leg


@pytest.mark.parametrize("pattern", PATHOLOGICAL)
def test_pathological_fixtures(pattern):
    r = analyze_pattern(pattern)
    assert r.ambiguity == "pathological"
    assert r.verdict == "pathological"


@pytest.mark.parametrize("pattern", FINITE)
def test_finitely_ambiguous_fixtures(pattern):
    r = analyze_pattern(pattern)
    assert r.ambiguity == "finite"
    assert r.verdict == "ok"


@pytest.mark.parametrize("pattern", UNAMBIGUOUS)
def test_unambiguous_fixtures(pattern):
    r = analyze_pattern(pattern)
    assert r.ambiguity == "unambiguous"
    assert r.ambiguity_exact
    assert r.verdict == "ok"


def test_regen_corpus_analyzes():
    """Every REgen pattern gets a definite, internally consistent report."""
    for seed in WIDTH_SEEDS:
        rng = np.random.Generator(np.random.Philox(seed))
        ast = random_regex(7, rng)
        m = build_matrices(compute_segments(number_regex(ast)))
        r = analyze_matrices(m)
        assert r.ambiguity in ("unambiguous", "finite", "pathological")
        assert r.recommended_backend in ("jnp", "packed", "sparse")
        assert len(r.width_bounds) >= 1 and r.width_bounds[0] <= r.ell_pad
        # bounds shrink (or hold) with depth: deeper prefixes prune harder
        assert all(
            a >= b for a, b in zip(r.width_bounds, r.width_bounds[1:])
        )


def test_report_schema_round_trips():
    import json

    d = analyze_pattern("(a|b|ab)+").to_dict()
    json.dumps(d)  # JSON-able end to end
    for key in (
        "pattern", "ell", "ell_pad", "n_classes", "nullable", "ambiguity",
        "ambiguity_exact", "width_bounds", "width_exact", "width_bucket",
        "density", "cost", "recommended_backend", "verdict",
    ):
        assert key in d, f"stats()['analysis'] schema lost {key!r}"
    assert set(d["cost"]) == {"jnp", "pallas", "packed", "sparse"}


def _spec_parser(pattern_or_matrices, depth, n_chunks=4):
    cfg = repro.ParserConfig(
        regex="placeholder", backend="sparse", feasible_depth=depth,
        n_chunks=n_chunks, analyze="off",
    )
    if isinstance(pattern_or_matrices, str):
        return repro.Parser(cfg.replace(regex=pattern_or_matrices))
    return repro.Parser.from_matrices(
        pattern_or_matrices, cfg.replace(regex="<prebuilt>")
    )


def _corpus_text(ast_or_pattern, rng, n_chars):
    """A text of EXACTLY n_chars drawn from the pattern's language samples
    (full chunks: every chunk's leading chars are real, so the per-depth
    bounds apply to what the backend observes)."""
    from repro.core import regex as rx

    node = (
        rx.parse_regex(ast_or_pattern)
        if isinstance(ast_or_pattern, str)
        else ast_or_pattern
    )
    text = b""
    for _ in range(64):
        text += sample_string(node, rng, max_rep=3) or b"a"
        if len(text) >= n_chars:
            break
    return (text + b"a" * n_chars)[:n_chars]


@pytest.mark.parametrize("key", UNAMBIGUOUS + FINITE + [f"seed:{s}" for s in WIDTH_SEEDS])
@pytest.mark.parametrize("depth", [1, 2])
def test_static_width_bound_vs_observed(key, depth):
    """The acceptance check: static bound >= every observed speculation
    width, and the pow2 bucket the backend carries is the tightest bucket
    over the depth-1 bound (tight within one pow2 step by construction)."""
    rng = np.random.Generator(np.random.Philox(abs(hash(key)) % 2**31))
    if key.startswith("seed:"):
        ast = random_regex(7, np.random.Generator(np.random.Philox(int(key[5:]))))
        m = build_matrices(compute_segments(number_regex(ast)))
        p = _spec_parser(m, depth)
        report = analyze_matrices(m, depth=depth)
        sample_src = ast
    else:
        p = _spec_parser(key, depth)
        report = analyze_pattern(key, depth=depth)
        sample_src = key
    c, k = p.engine.bucket_shape(1, 4)[0], None  # c fixed by config
    # full chunks: text length = c * k for the smallest bucket
    c = 4
    k = p.engine.bucket_shape(c * p.config.min_chunk_len, c)[1]
    n = c * k
    observed = []
    for _ in range(6):
        res = p.parse(_corpus_text(sample_src, rng, n))
        spec = res.speculation
        assert spec is not None and spec["depth"] == depth
        observed.append(spec["width_max"])
    bound = report.width_bounds[depth - 1]
    assert max(observed) <= bound, (
        f"{key}@d{depth}: observed width {max(observed)} exceeds the "
        f"static bound {bound}"
    )
    # the backend's carried product rows = bucket(depth-1 bound): tightest
    # pow2 over the bound (within one bucket of any observed width)
    carried = int(p.engine.backend._width)
    assert carried == sparse_width_bucket(
        report.width_bounds[0], report.ell_pad
    )
    if carried < report.ell_pad:  # reduced: pow2-tight over the bound
        assert carried < 2 * max(report.width_bounds[0], 8)


def test_width_bounds_match_runtime_fold():
    """The static frontier and the runtime per-chunk fold agree exactly when
    every class sequence of the text is enumerated at depth 1."""
    m = build_matrices(compute_segments("(a|b|ab)+"))
    N = np.asarray(m.N)
    bounds, exact = feasible_width_bounds(N, 1)
    assert exact
    n_real = N.shape[0] - 1
    widths = []
    for a in range(n_real):
        chunk = np.array([[a]], dtype=np.int64)
        w = feasible_start_widths(N, chunk, depth=1)
        widths.append(int(w[0]))
    assert bounds[0] == max(widths)


def test_min_lane_pad_mirror_matches_backends():
    """The analyzer's jax-free lane-pad table must track core/backend.py."""
    for name, lane in _MIN_LANE_PAD.items():
        assert get_backend(name).min_lane_pad == lane, (
            f"analyze/pattern.py's _MIN_LANE_PAD[{name!r}]={lane} no longer "
            "matches the real backend — update the mirror"
        )
    assert set(_MIN_LANE_PAD) == set(_BACKENDS)


def test_cost_model_prefers_reduction():
    """A width-reduced automaton models sparse fastest; unreduced never
    recommends sparse; pallas is never auto-picked."""
    cost = backend_cost_model(40, width_bucket_32=4)
    assert choose_backend(cost, reduced=True) == "sparse"
    assert choose_backend(cost, reduced=False) in ("packed", "jnp")
    for ell in (8, 40, 200, 1000):
        for w in (2, 16, 200):
            c = backend_cost_model(ell, w)
            assert choose_backend(c, reduced=True) != "pallas"
            for name in ("jnp", "pallas", "packed", "sparse"):
                assert c[name]["t_total"] > 0


def test_density_profile_bounds():
    r = analyze_pattern("(a|b|ab)+")
    d = r.density
    assert 0.0 < d["class_mean"] <= d["class_max"] <= 1.0
    assert d["union"] <= d["saturation"] <= 1.0


# -------------------------------------------------------- facade policy


def test_strict_rejects_pathological_at_construction():
    with pytest.raises(PathologicalPatternError) as ei:
        repro.Parser(repro.ParserConfig(regex="(a*)*", analyze="strict"))
    err = ei.value
    assert err.pattern == "(a*)*"
    assert err.ambiguity == "pathological"
    assert isinstance(err, ValueError) and isinstance(err, ParseError)


def test_warn_mode_warns_and_serves():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        p = repro.Parser("(a?)+")  # analyze="warn" is the default
    assert any(
        issubclass(w.category, UserWarning) and "pathologically" in str(w.message)
        for w in caught
    )
    assert p.parse("aa").ok  # pathological != broken; warn still serves


def test_off_mode_skips_construction_analysis():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        p = repro.Parser(repro.ParserConfig(regex="(a*)*", analyze="off"))
    assert not any(issubclass(w.category, UserWarning) for w in caught)
    # stats() still computes the report lazily
    assert p.stats()["analysis"]["verdict"] == "pathological"


def test_analyze_knob_validated():
    with pytest.raises(ValueError, match="analyze"):
        repro.ParserConfig(regex="ab", analyze="loud")


def test_config_round_trips_new_fields():
    cfg = repro.ParserConfig(regex="(a|b)+", backend="auto", analyze="strict")
    assert repro.ParserConfig.from_dict(cfg.to_dict()) == cfg


def test_fleet_strict_rejects_and_keeps_serving():
    fleet = repro.ParserFleet({"good": "(a|b|ab)+"})
    with pytest.raises(PathologicalPatternError):
        fleet.add("bad", repro.ParserConfig(regex="(a*)*", analyze="strict"))
    assert sorted(fleet.tenants) == ["good"]
    assert fleet.parse("good", "ab").ok  # rejection is per tenant


def test_service_pattern_guard_blocks_admission():
    p = repro.Parser(repro.ParserConfig(regex="(a|b|ab)+", analyze="warn"))
    svc = p.parse_service
    svc.set_pattern_guard("pathological", "strict")
    with pytest.raises(PathologicalPatternError):
        p.parse("ab")
    svc.set_pattern_guard("pathological", "warn")  # non-strict: serves
    assert p.parse("ab").ok
    ss = p.stream_service
    ss.set_pattern_guard("pathological", "strict")
    sid = ss.open()
    with pytest.raises(PathologicalPatternError):
        ss.append(sid, "ab")


def test_analysis_report_on_parser_and_metrics():
    p = repro.Parser(repro.ParserConfig(regex="(a|b|ab)+"))
    assert isinstance(p.analysis, AnalysisReport)
    s = p.stats()
    assert s["analysis"]["verdict"] == "ok"
    from repro.obs import validate_metric_names

    snap = s["metrics"]
    validate_metric_names(snap)
    flat = {str(k): v for k, v in snap.items()}
    assert flat["analyzer_verdicts_total"][0]["labels"]["verdict"] == "ok"


# ------------------------------------------------------- backend="auto"


def test_auto_backend_bit_identical():
    """Acceptance: auto parses bit-identically to its selected backend
    across the conformance corpus patterns."""
    rng = np.random.Generator(np.random.Philox(7))
    for pattern in UNAMBIGUOUS + FINITE:
        auto = repro.Parser(repro.ParserConfig(
            regex=pattern, backend="auto", n_chunks=4, analyze="off",
        ))
        chosen = auto.backend_name
        assert chosen in repro.list_backends()
        explicit = repro.Parser(repro.ParserConfig(
            regex=pattern, backend=chosen, n_chunks=4, analyze="off",
        ))
        for _ in range(4):
            text = _corpus_text(pattern, rng, int(rng.integers(1, 24)))
            fa = auto.parse(text).forest
            fe = explicit.parse(text).forest
            assert np.array_equal(fa.columns, fe.columns)
            assert np.array_equal(fa.classes, fe.classes)
            assert fa.count_trees() == fe.count_trees()


def test_auto_backend_in_fleet_bit_identical():
    fleet = repro.ParserFleet({
        "auto": repro.ParserConfig(regex="(a|b|ab)+", backend="auto"),
    })
    resolved = fleet.stats()["tenants"]["auto"]["backend"]
    assert resolved in repro.list_backends()
    fleet.add("explicit", repro.ParserConfig(regex="(a|b|ab)+", backend=resolved))
    for text in ("abab", "ba", "abba" * 3):
        ra = fleet.parse("auto", text)
        re_ = fleet.parse("explicit", text)
        assert ra.backend == resolved
        assert np.array_equal(ra.forest.columns, re_.forest.columns)


def test_auto_validation_rules():
    with pytest.raises(ValueError, match="kernel"):
        repro.ParserConfig(regex="ab", backend="auto", kernel=True)
    repro.ParserConfig(regex="ab", backend="auto", feasible_depth=2)  # ok
    with pytest.raises(ValueError, match="auto"):
        repro.ParserConfig(regex="ab", backend="auto").build_backend()


# ---------------------------------------------------------- program leg


@pytest.mark.parametrize("backend", sorted(_BACKENDS))
def test_phase_programs_lint_clean(backend):
    p = repro.Parser(repro.ParserConfig(
        regex="(a|b|ab)+", backend=backend, analyze="off",
    ))
    findings = lint_engine(p.engine, buckets=((4, 32),), label=backend)
    assert findings == [], [str(f) for f in findings]


def test_lint_catches_seeded_f64():
    import jax
    import jax.numpy as jnp

    with jax.experimental.enable_x64():
        prog = jax.jit(lambda x: x.astype(jnp.float64) * 2.0)
        findings = lint_program(
            prog, (jax.ShapeDtypeStruct((4, 4), jnp.float32),), "t:f64"
        )
    assert "f64" in {f.rule for f in findings}
    assert all(f.program == "t:f64" for f in findings)


def test_lint_catches_seeded_callback():
    import jax
    import jax.numpy as jnp

    def cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct((4,), jnp.float32), x
        )

    findings = lint_jaxpr(jax.make_jaxpr(jax.jit(cb))(jnp.ones(4)), "t:cb")
    assert "host-callback" in {f.rule for f in findings}


def test_lint_hlo_text_scans():
    bad = "  %x.1 = f64[4,4]{1,0} convert(%p.0)\n"
    assert {f.rule for f in lint_hlo_text(bad, "t")} == {"f64"}
    cb = '  %y = f32[4]{0} custom-call(%p), custom_call_target="xla_ffi_python_cpu_callback"\n'
    assert {f.rule for f in lint_hlo_text(cb, "t")} == {"host-callback"}
    assert lint_hlo_text("  %z = f32[4]{0} add(%a, %b)\n", "t") == []


# --------------------------------------------------------------- compat


def test_launch_analysis_reexports_roofline():
    from repro.analyze import roofline
    from repro.launch import analysis

    assert analysis.Roofline is roofline.Roofline
    assert analysis.PEAK_FLOPS == roofline.PEAK_FLOPS
    assert analysis.analyze_compiled is roofline.analyze_compiled
    assert analysis.collective_bytes is roofline.collective_bytes


def test_bench_trend_new_gate_is_informational(tmp_path):
    """A BENCH file absent at --base reports as a new gate, exit 0."""
    repo_root = Path(__file__).parents[1]
    target = repo_root / "BENCH_analyze_selftest_newgate.json"
    target.write_text(
        '{"name": "selftest", "timestamp": "2026-01-01T00:00:00", '
        '"config": {}, "metrics": {"rows": [{"name": "throughput_x", '
        '"value": 123.0, "derived": "texts/s"}]}}'
    )
    try:
        proc = subprocess.run(
            [sys.executable, "scripts/bench_trend.py", "--base", "HEAD"],
            cwd=repo_root, capture_output=True, text=True,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "new gate" in proc.stdout
        assert "123.0" in proc.stdout
    finally:
        target.unlink()
