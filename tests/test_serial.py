"""Serial parsers (Fig. 10 matrix form; Sect. 4.1 DFA form) vs the oracle."""

import numpy as np
import pytest

from oracle import enumerate_lsts, render_lst
from repro.core.matrices import build_matrices
from repro.core.numbering import number_regex
from repro.core.segments import compute_segments
from repro.core.serial import SerialParser, parse_serial_dfa, parse_serial_matrix, recognize


def _setup(pat):
    numbered = number_regex(pat)
    table = compute_segments(numbered)
    return numbered, build_matrices(table)


def test_paper_ex4_ab():
    """Ex. 4: clean SLPF of x=ab for e2 — singleton columns, one LST."""
    numbered, m = _setup("(ab|a)*")
    s = parse_serial_matrix(m, "ab")
    assert s.accepted
    assert [int(c.sum()) for c in s.columns] == [1, 1, 1]
    assert s.count_trees() == 1
    lst = s.lst_string(next(s.iter_trees()))
    assert lst.startswith("1(") and lst.endswith(")1")


@pytest.mark.parametrize("pat", ["(ab|a)*", "(a|b|ab)+", "a{1,3}b?", "x(yz|y)*z?"])
def test_tree_sets_match_oracle(pat):
    """The SLPF encodes exactly the oracle's LST set (count and content)."""
    import itertools

    numbered, m = _setup(pat)
    alphabet = "abxyz"
    for n in range(0, 5):
        for chars in itertools.islice(itertools.product(alphabet, repeat=n), 40):
            text = "".join(chars)
            oracle = {tuple(l) for l in enumerate_lsts(numbered, text.encode())}
            s = parse_serial_matrix(m, text)
            assert s.count_trees() == len(oracle), (pat, text)
            got = set()
            for path in s.iter_trees():
                flat = tuple(sid for q in path for sid in s.table.segs[q])
                got.add(flat)
            assert got == oracle, (pat, text)


def test_dfa_parser_equals_matrix_parser():
    p = SerialParser("(a|b|ab)+")
    import itertools

    for n in range(0, 6):
        for chars in itertools.islice(itertools.product("ab", repeat=n), 30):
            text = "".join(chars)
            a = p.parse(text, method="matrix")
            b = p.parse(text, method="dfa")
            assert np.array_equal(a.columns, b.columns), text


def test_recognizer_matches_parser():
    p = SerialParser("(ab|a)*c")
    for text in ["c", "abc", "aac", "ab", "", "abac"]:
        assert p.accepts(text) == p.parse(text).accepted, text


def test_empty_text():
    p = SerialParser("(ab|a)*")
    s = p.parse("")
    assert s.accepted and s.count_trees() == 1  # ε has the single LST ₁()₁
    p2 = SerialParser("ab")
    assert not p2.parse("").accepted


def test_invalid_text_empty_forest():
    p = SerialParser("(ab|a)*")
    s = p.parse("ba")
    assert not s.accepted and s.count_trees() == 0
    assert not s.columns.any()  # clean SLPF of invalid text is empty
