"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineTables, ParserEngine, pack_columns_u32
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.kernels import ops


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 128, 128), (128, 256, 384), (384, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("density", [0.02, 0.3])
def test_semiring_matmul_sweep(m, k, n, dtype, density):
    ka, kb = jax.random.split(jax.random.PRNGKey(m + k + n))
    a = (jax.random.uniform(ka, (m, k)) < density).astype(dtype)
    b = (jax.random.uniform(kb, (k, n)) < density).astype(dtype)
    got = ops.semiring_matmul(a, b)
    ref = ops.semiring_matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32)
    )


@pytest.mark.parametrize("pat", ["(ab|a)*", "(a|b|ab)+", "x(yz|y)*z?"])
@pytest.mark.parametrize("klen", [1, 7, 33])
def test_reach_kernel_sweep(pat, klen):
    art = ParallelArtifacts.generate(pat)
    t = EngineTables.from_matrices(art.matrices, lane_pad=128)
    rng = np.random.RandomState(klen)
    ids = jnp.asarray(rng.randint(0, t.N.shape[0], size=klen), jnp.int32)
    got = ops.reach_chunk_product(t.N, ids)
    ref = ops.reach_chunk_product_ref(t.N, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("pat", ["(ab|a)*", "(a|b|ab)+"])
@pytest.mark.parametrize("klen", [1, 8, 21])
def test_build_merge_kernel_sweep(pat, klen):
    art = ParallelArtifacts.generate(pat)
    t = EngineTables.from_matrices(art.matrices, lane_pad=128)
    rng = np.random.RandomState(klen + 17)
    ids = jnp.asarray(rng.randint(0, t.N.shape[0], size=klen), jnp.int32)
    got = ops.build_merge_chunk(t.N, ids, t.I, t.F)
    ref = ops.build_merge_chunk_ref(t.N, ids, t.I, t.F)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("L,hd", [(64, 32), (128, 64), (96, 64)])
@pytest.mark.parametrize("window", [None, 13])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(L, hd, window, dtype):
    from repro.kernels.ops import flash_attention, flash_attention_ref

    key = jax.random.PRNGKey(L + hd)
    b, h = 2, 3
    q = jax.random.normal(key, (b, L, h, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, L, h, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, L, h, hd), dtype)
    got = flash_attention(q, k, v, True, window, 32, 32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_flash_attention_grad_matches_oracle():
    from repro.kernels.ops import flash_attention, flash_attention_ref

    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 2, 32), jnp.float32)
    g = jax.grad(lambda q_: flash_attention(q_, k, v, True, None, 32, 32).sum())(q)
    gr = jax.grad(lambda q_: flash_attention_ref(q_, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=3e-5)


@pytest.mark.parametrize("q,hp,n", [(32, 16, 8), (64, 32, 16), (16, 8, 8)])
def test_ssd_chunk_kernel_sweep(q, hp, n):
    from repro.kernels.ops import ssd_chunk, ssd_chunk_ref

    rng = np.random.RandomState(q + n)
    P = 4
    xdt = jnp.asarray(rng.randn(P, q, hp).astype(np.float32)) * 0.3
    dA = -np.abs(rng.uniform(0.01, 0.4, (P, q, 1))).astype(np.float32)
    cs = jnp.asarray(np.cumsum(dA, axis=1))
    B = jnp.asarray(rng.randn(P, q, n).astype(np.float32)) * 0.3
    C = jnp.asarray(rng.randn(P, q, n).astype(np.float32)) * 0.3
    S = jnp.asarray(rng.randn(P, hp, n).astype(np.float32)) * 0.3
    y, Sc = ssd_chunk(xdt, cs, B, C, S)
    yr, Scr = ssd_chunk_ref(xdt, cs, B, C, S)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(Sc), np.asarray(Scr), rtol=2e-4, atol=2e-4)


def test_ssd_chunk_kernel_matches_model_ssd():
    """Kernel chunks + core/scan join ≡ models.mamba.ssd_chunked end to end."""
    from repro.core.scan import exclusive_entries
    from repro.kernels.ops import ssd_chunk
    from repro.models.mamba import ssd_chunked

    rng = np.random.RandomState(0)
    b, l, nh, hp, n, chunk = 2, 32, 2, 8, 8, 8
    xdt = jnp.asarray(rng.randn(b, l, nh, hp).astype(np.float32)) * 0.3
    dA = -jnp.asarray(np.abs(rng.uniform(0.01, 0.4, (b, l, nh))).astype(np.float32))
    B = jnp.asarray(rng.randn(b, l, 1, n).astype(np.float32)) * 0.3
    C = jnp.asarray(rng.randn(b, l, 1, n).astype(np.float32)) * 0.3
    y_ref, _ = ssd_chunked(xdt, dA, B, C, chunk)

    nc = l // chunk
    cs = jnp.cumsum(dA.reshape(b, nc, chunk, nh), axis=2)
    decay = jnp.exp(cs[:, :, -1])                                   # (b, nc, nh)
    Bh = jnp.broadcast_to(B.reshape(b, nc, chunk, 1, n), (b, nc, chunk, nh, n))
    Ch = jnp.broadcast_to(C.reshape(b, nc, chunk, 1, n), (b, nc, chunk, nh, n))
    xc = xdt.reshape(b, nc, chunk, nh, hp)

    def flat(t):  # (b, nc, chunk, nh, ...) -> (b*nc*nh, chunk, ...)
        return jnp.moveaxis(t, 3, 2).reshape(b * nc * nh, chunk, *t.shape[4:])

    cs_flat = jnp.moveaxis(cs, 3, 2).reshape(b * nc * nh, chunk, 1)
    # first pass with zero states to get chunk contributions
    zeroS = jnp.zeros((b * nc * nh, hp, n), jnp.float32)
    _, Sc = ssd_chunk(flat(xc), cs_flat, flat(Bh), flat(Ch), zeroS)
    Sc = Sc.reshape(b, nc, nh, n, hp).transpose(0, 1, 2, 4, 3)      # (b, nc, nh, hp, n)
    combine = lambda la, ea: (la[0] * ea[0], la[0][..., None, None] * ea[1] + la[1])
    act = lambda m, s: m[0][..., None, None] * s + m[1]
    entries = exclusive_entries(
        combine, act,
        (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(Sc, 1, 0)),
        jnp.zeros((b, nh, hp, n), jnp.float32),
    )                                                                # (nc, b, nh, hp, n)
    S_prev = jnp.moveaxis(entries, 0, 1).reshape(b * nc * nh, hp, n)
    y, _ = ssd_chunk(flat(xc), cs_flat, flat(Bh), flat(Ch), S_prev)
    y = y.reshape(b, nc, nh, chunk, hp).transpose(0, 1, 3, 2, 4).reshape(b, l, nh, hp)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4, atol=3e-4)


def test_kernels_compose_to_full_parse():
    """reach + join (host) + build&merge kernels == the serial parser."""
    art = ParallelArtifacts.generate("(a|b|ab)+")
    t = EngineTables.from_matrices(art.matrices, lane_pad=128)
    eng = ParserEngine(art.matrices, lane_pad=128)
    text = "abababab"
    classes = eng.classes_of_text(text)
    chunks = eng.pad_chunks(classes, 2)
    P = jnp.stack([ops.reach_chunk_product(t.N, jnp.asarray(ch)) for ch in chunks])
    from repro.core.engine import _entries_from_products

    Jf, Jb = _entries_from_products(P, t.I, t.F)
    M = jnp.stack(
        [
            ops.build_merge_chunk(t.N, jnp.asarray(ch), Jf[i], Jb[i])
            for i, ch in enumerate(chunks)
        ]
    )
    # columns 1..n from the kernels; compare against serial oracle
    ref = parse_serial_matrix(art.matrices, text)
    got_cols = np.asarray(M.reshape(-1, t.ell_pad))[: len(classes), : t.ell]
    assert np.array_equal(got_cols.astype(bool), ref.columns[1:])
