"""Mesh-native distributed parse runtime (core/distributed.py).

Two tiers:
  * 1-device-mesh tests — the full shard_map routes (chunk-sharded parse,
    batch × chunk parse_batch, sharded streaming join) run degenerately on
    whatever single device the plain suite has; bit-identity always checked.
  * 8-device tests — require a host mesh with real collectives; they run
    in-process when the interpreter was launched with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (scripts/ci.sh
    does), and otherwise via the slow subprocess test at the bottom.
"""

import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core.distributed import DistributedEngine
from repro.core.engine import ParserEngine
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.core.stream import StreamingParser
from repro.launch.mesh import make_mesh_compat, make_parse_mesh
from repro.serve.parse_service import ParseService

AMBIG = "(a|b|ab)+"
# mixed-length, empty, and ambiguous inputs (acceptance criteria set)
TEXTS = ["abab", "", "b", "ab" * 13, "a" * 17, "ba" * 3, "aabb" * 5, "x"]

multi = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


@pytest.fixture(scope="module")
def art():
    return ParallelArtifacts.generate(AMBIG)


@pytest.fixture(scope="module")
def ref_engine(art):
    return ParserEngine(art.matrices)


def _mesh_8():
    return make_mesh_compat((2, 4), ("pod", "data"))


# ------------------------------------------------------------ legacy gone


def test_legacy_sharded_path_is_gone():
    """One distribution-aware runtime: the pre-phases path no longer exists."""
    assert not hasattr(engine_mod, "make_sharded_parser")
    assert not hasattr(engine_mod, "sharded_parse_step")


# --------------------------------------------------- 1-device mesh routes


def test_mesh_route_parse_batch_matches_engine(art, ref_engine):
    eng = ParserEngine(art.matrices, mesh=make_parse_mesh())
    got = eng.parse_batch(TEXTS)
    base = ref_engine.parse_batch(TEXTS)
    for t, g, b in zip(TEXTS, got, base):
        srl = parse_serial_matrix(art.matrices, t)
        assert np.array_equal(g.columns, srl.columns), t
        assert np.array_equal(g.pack(), b.pack()), t
        assert g.count_trees() == b.count_trees(), t


def test_mesh_route_single_parse_matches_engine(art, ref_engine):
    eng = ParserEngine(art.matrices, mesh=make_parse_mesh())
    for t in TEXTS:
        got = eng.parse(t)
        assert np.array_equal(
            got.columns, parse_serial_matrix(art.matrices, t).columns
        ), t
        assert got.count_trees() == ref_engine.parse(t).count_trees(), t


def test_mesh_route_pallas_backend(art, ref_engine):
    eng = ParserEngine(art.matrices, mesh=make_parse_mesh(), backend="pallas")
    for t in ["abab", "ba"]:
        assert np.array_equal(
            eng.parse_batch([t])[0].columns, ref_engine.parse(t).columns
        ), t


def test_streaming_on_mesh_engine(art, ref_engine):
    """Sharded streaming: every incremental state bit-identical to cold."""
    eng = ParserEngine(art.matrices, mesh=make_parse_mesh())
    sp = StreamingParser(eng, first_seal_len=4)
    prefix = ""
    for piece in ["ab", "ab", "", "abab", "ba", "ab" * 8, "x"]:
        sp.append(piece)
        prefix += piece
        cold = ref_engine.parse(prefix)
        assert np.array_equal(sp.current_slpf().pack(), cold.pack()), piece


def test_stream_edit_on_mesh_engine(art, ref_engine):
    """Mid-text splices on a mesh engine: the segment tree's flattened leaf
    frontier stays the all-gather payload, so post-edit queries route
    through the same sharded join — bit-identical to cold."""
    eng = ParserEngine(art.matrices, mesh=make_parse_mesh())
    sp = StreamingParser(eng, first_seal_len=4, max_seal_len=8)
    text = "ab" * 14
    sp.append(text)
    for lo, hi, repl in [(5, 9, "ba"), (0, 2, ""), (10, 10, "abab")]:
        text = text[:lo] + repl + text[hi:]
        assert sp.edit(lo, hi, repl) == len(text)
        cold = ref_engine.parse(text)
        assert np.array_equal(sp.current_slpf().pack(), cold.pack()), (lo, hi)
        assert sp.accepted == cold.accepted, (lo, hi)


def test_standalone_distributed_engine(art, ref_engine):
    dist = DistributedEngine(art.matrices, make_parse_mesh())
    got = dist.parse_batch(TEXTS[:4])
    for t, g in zip(TEXTS[:4], got):
        assert np.array_equal(g.columns, ref_engine.parse(t).columns), t


def test_prebuilt_engine_rejects_mesh_kwarg(art, ref_engine):
    with pytest.raises(ValueError):
        ParseService(ref_engine, mesh=make_parse_mesh())


# ------------------------------------------------------- 8-device routes


@multi
def test_chunk_sharded_parse_8dev(art, ref_engine):
    eng = ParserEngine(art.matrices, mesh=_mesh_8())
    assert eng.dist.chunk_axes == ("pod", "data")
    for t in TEXTS:
        got = eng.parse(t)
        assert np.array_equal(
            got.columns, parse_serial_matrix(art.matrices, t).columns
        ), t
        assert got.count_trees() == ref_engine.parse(t).count_trees(), t


@multi
def test_batch_times_chunk_sharded_parse_batch_8dev(art, ref_engine):
    eng = ParserEngine(art.matrices, mesh=_mesh_8())
    assert eng.dist.batch_axes == ("data",)
    assert eng.dist.batch_chunk_axes == ("pod",)
    got = eng.parse_batch(TEXTS)
    base = ref_engine.parse_batch(TEXTS)
    for t, g, b in zip(TEXTS, got, base):
        assert np.array_equal(g.pack(), b.pack()), t
        assert np.array_equal(
            g.columns, parse_serial_matrix(art.matrices, t).columns
        ), t
        assert g.count_trees() == b.count_trees(), t


@multi
def test_sharded_streaming_append_8dev(art, ref_engine):
    eng = ParserEngine(art.matrices, mesh=_mesh_8())
    sp = StreamingParser(eng, first_seal_len=4)
    prefix = ""
    for piece in ["ab", "ab", "abab", "ba", "ab" * 10, ""]:
        sp.append(piece)
        prefix += piece
        cold = ref_engine.parse(prefix)
        assert np.array_equal(sp.current_slpf().pack(), cold.pack()), piece
        assert sp.accepted == cold.accepted, piece


@multi
def test_parse_service_serves_sharded_batched_8dev(art, ref_engine):
    svc = ParseService(art.matrices, mesh=_mesh_8(), max_batch=8, n_chunks=4)
    rids = [svc.submit(t) for t in TEXTS]
    done = {r.rid: r for r in svc.run()}
    for rid, t in zip(rids, TEXTS):
        assert np.array_equal(
            done[rid].slpf.columns, parse_serial_matrix(art.matrices, t).columns
        ), t


@multi
def test_batched_program_collective_footprint_8dev(art):
    """The batched route's only collective is the product-stack all-gather."""
    import re
    from collections import Counter

    eng = ParserEngine(art.matrices, mesh=_mesh_8())
    t = eng.tables
    hlo = (
        eng.dist.batched_program.lower(
            t.N, t.I, t.F, jax.ShapeDtypeStruct((8, 8, 16), np.int32)
        )
        .compile()
        .as_text()
    )
    c = Counter(re.findall(r"(all-gather|all-reduce|all-to-all|reduce-scatter)", hlo))
    assert c["all-gather"] >= 1, c
    assert c["all-to-all"] == 0 and c["reduce-scatter"] == 0, c


# ------------------------------------------------------- subprocess cover


@pytest.mark.slow
def test_distributed_multidevice_subprocess():
    """8-device coverage for plain single-device suite runs (device count is
    locked at jax init, so a fresh process sets the flag first)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core.reference import ParallelArtifacts
from repro.core.serial import parse_serial_matrix
from repro.core.engine import ParserEngine
from repro.core.stream import StreamingParser
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("pod", "data"))
art = ParallelArtifacts.generate("(a|b|ab)+")
ref = ParserEngine(art.matrices)
eng = ParserEngine(art.matrices, mesh=mesh)
texts = ["abab", "", "b", "ab"*13, "a"*17, "x"]
for t, g in zip(texts, eng.parse_batch(texts)):
    assert np.array_equal(g.columns, parse_serial_matrix(art.matrices, t).columns), t
    assert np.array_equal(g.pack(), ref.parse(t).pack()), t
assert np.array_equal(eng.parse("ab"*17).columns, ref.parse("ab"*17).columns)
sp = StreamingParser(eng, first_seal_len=4)
prefix = ""
for piece in ["ab", "abab", "ba"*4]:
    sp.append(piece); prefix += piece
    cold = ref.parse(prefix)
    assert np.array_equal(sp.current_slpf().pack(), cold.pack()), piece
    assert sp.accepted == cold.accepted
print("DISTRIBUTED-OK")
"""
    env = {"PYTHONPATH": str(Path(__file__).parents[1] / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DISTRIBUTED-OK" in out.stdout
